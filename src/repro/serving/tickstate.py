"""The jitted tick state — one explicit, shardable pytree for every engine.

Before this module the continuous and speculative engines each carried an
untyped ``Dict[str, jax.Array]`` through their jitted steps, copied and
mutated with ``dict(st); st.update(...)`` in three near-identical places.
:class:`TickState` replaces all of them: a frozen dataclass registered as a
JAX pytree, so it traces/donates/shards exactly like the dict did, but the
field set is CLOSED (a typo becomes an ``AttributeError`` at trace time, not
a silently-ignored extra dict key) and every leaf declares its mesh placement
up front.

Sharding contract (the field-by-field table lives in
``repro.serving.engine``'s module docstring): every TickState leaf is
REPLICATED (``PartitionSpec()``).  The tick state is the scheduler's device
mirror — slot occupancy, per-slot positions, sampling streams, block-table
rows — and every mesh shard needs all of it to mask its own portion of the
batched decode.  What actually shards over the mesh is what the state
*indexes into*: the page pools / KV caches (heads → ``model``, dense slot
axis → ``data``) and the weights (tensor/expert-parallel via
``repro.distributed.sharding.param_specs``).  Replication is still a
declaration, not an omission — ``tests/test_tickstate_spec.py`` fails any
field added without one.

Optional fields (``block_table``, ``spec``, ``max_new``) are ``None`` when a
given engine does not use them; ``None`` is an empty pytree, so the plain
dense engine's jitted tick never sees (or pays for) the speculative fields.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


def _leaf(pspec: P, doc: str, default=dataclasses.MISSING):
    """A TickState field with its declared mesh placement.

    The ``pspec`` metadata is the single source of truth for the leaf's
    sharding — :meth:`TickState.shardings` builds device placements from it
    and the pytree lint (tests/test_tickstate_spec.py) walks it."""
    return dataclasses.field(default=default,
                             metadata={"pspec": pspec, "doc": doc})


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TickState:
    """Per-slot device state threaded through every jitted serving step.

    All vectors are indexed by slot (``ServeConfig.max_slots``); shapes never
    change after construction, so every consumer compiles exactly once.
    """

    # -- slot metadata ------------------------------------------------------
    last_tok: Array = _leaf(P(), "(S,) i32 — last emitted token per slot")
    pos: Array = _leaf(P(), "(S,) i32 — next decode position per slot")
    active: Array = _leaf(P(), "(S,) bool — slot occupancy mask")
    adapter_ids: Array = _leaf(
        P(), "(S,) i32 — adapter-bank ROW per slot (0 = base route), "
             "resolved at admission by the residency gate")
    # -- sampling state -----------------------------------------------------
    temps: Array = _leaf(P(), "(S,) f32 — per-request temperature")
    seeds: Array = _leaf(P(), "(S,) i32 — per-request PRNG seed")
    gen_idx: Array = _leaf(P(), "(S,) i32 — tokens generated so far")
    # -- output accumulation ------------------------------------------------
    out_buf: Array = _leaf(P(), "(S, max_new) i32 — on-device token buffer")
    # -- paged-cache state (None on dense engines) --------------------------
    block_table: Optional[Array] = _leaf(
        P(), "(S, n_tbl) i32 — page ids per slot; zeros route to trash page",
        default=None)
    # -- speculative / draft state (None on non-speculative engines) --------
    spec: Optional[Array] = _leaf(
        P(), "(S,) bool — per-request speculative opt-in", default=None)
    max_new: Optional[Array] = _leaf(
        P(), "(S,) i32 — per-request budget (γ-round emit cap)", default=None)

    # -- construction -------------------------------------------------------

    @classmethod
    def zeros(cls, n_slots: int, max_new_tokens: int, *, n_tbl: int = 0,
              speculative: bool = False) -> "TickState":
        """The all-free initial state.  ``n_tbl > 0`` adds the paged block
        table (all-zero rows route garbage writes to the trash page);
        ``speculative=True`` adds the draft-round fields."""
        S = n_slots
        return cls(
            last_tok=jnp.zeros((S,), jnp.int32),
            pos=jnp.zeros((S,), jnp.int32),
            active=jnp.zeros((S,), bool),
            adapter_ids=jnp.zeros((S,), jnp.int32),
            temps=jnp.zeros((S,), jnp.float32),
            seeds=jnp.zeros((S,), jnp.int32),
            gen_idx=jnp.zeros((S,), jnp.int32),
            out_buf=jnp.zeros((S, max_new_tokens), jnp.int32),
            block_table=(jnp.zeros((S, n_tbl), jnp.int32) if n_tbl else None),
            spec=(jnp.zeros((S,), bool) if speculative else None),
            max_new=(jnp.zeros((S,), jnp.int32) if speculative else None),
        )

    # -- functional update --------------------------------------------------

    def replace(self, **kw) -> "TickState":
        """``dataclasses.replace`` spelled as a method — the one mutation
        idiom, in jitted ticks and host-side bookkeeping alike."""
        return dataclasses.replace(self, **kw)

    # -- declared sharding --------------------------------------------------

    @classmethod
    def field_specs(cls) -> Dict[str, P]:
        """{field name: declared PartitionSpec} — every field MUST appear."""
        return {f.name: f.metadata["pspec"] for f in dataclasses.fields(cls)}

    def specs(self) -> "TickState":
        """A TickState-shaped pytree of PartitionSpecs (``None`` where the
        corresponding leaf is absent) — feed to ``sharding.to_shardings``."""
        declared = self.field_specs()
        return dataclasses.replace(self, **{
            name: (None if getattr(self, name) is None else spec)
            for name, spec in declared.items()})

    def shardings(self, mesh: Mesh) -> "TickState":
        """NamedShardings for ``jax.device_put`` onto ``mesh``."""
        return jax.tree.map(lambda s: NamedSharding(mesh, s), self.specs(),
                            is_leaf=lambda x: isinstance(x, P))

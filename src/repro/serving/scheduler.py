"""Host-side continuous-batching scheduler: request queue + fixed slot table.

Pure bookkeeping, no device work — the engine owns the arrays.  Separating
the two keeps the policy unit-testable and keeps every decode step
shape-stable: the slot count never changes, free slots simply decode masked
garbage that nothing reads.

Policy (vLLM-style admit-on-free-slot, FCFS):

  * ``submit`` appends to a FIFO queue.
  * Before every decode tick the engine drains ``next_admission()`` — one
    (slot, request) pair per free slot — and prefetches each request's prompt
    directly into its slot's cache row while the other slots are untouched.
  * A slot may be admitted in PREFILLING state (chunked prefill: long
    prompts stream into the cache one page-aligned chunk per engine step,
    interleaved with decode ticks).  A prefilling slot occupies its slot and
    tracks ``prefill_pos`` (prompt tokens committed so far) but neither
    ticks nor counts as decodable until the engine calls
    :meth:`start_decode` after the final chunk produced token #1.
  * A slot is evicted the moment its request has produced all its tokens;
    the freed slot is eligible for admission before the very next tick.

Completion is tracked with host counters only (every decode tick yields
exactly one token per active slot), so the hot loop never blocks on a
device→host read; generated tokens stay on device until eviction.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S_prompt,) int32
    max_new_tokens: int
    adapter: Optional[str] = None      # registry name; None → base model
    adapter_id: int = 0                # resolved by the engine
    temperature: float = 0.0
    seed: int = 0
    speculative: bool = True           # opt-out honored by the spec engine
    prefix_id: Optional[str] = None    # shared-prefix handle (COW paging)
    prefix_len: int = 0                # prompt tokens covered by the prefix


@dataclasses.dataclass
class RequestResult:
    uid: int
    tokens: np.ndarray                 # (n_generated,) int32
    adapter: Optional[str]
    prompt_len: int
    n_generated: int
    ttft_s: float = 0.0                # submit → first-token DISPATCH (host
                                       # wall time; the engine never syncs)
    latency_s: float = 0.0             # submit → eviction (host wall time)
    status: str = "ok"                 # terminal taxonomy — one of
                                       # resilience.STATUSES: ok | timeout |
                                       # shed | cancelled | failed


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    steps_left: int = 0                # decode ticks until completion
    generated: int = 0                 # tokens produced so far (incl. prefill's)
    prefilling: bool = False           # chunked prefill still streaming
    prefill_pos: int = 0               # prompt tokens committed so far

    @property
    def free(self) -> bool:
        return self.request is None


class Scheduler:
    def __init__(self, max_slots: int,
                 on_event: Optional[Callable[[str, int, Request],
                                             None]] = None):
        assert max_slots >= 1
        self.max_slots = max_slots
        self._queue: Deque[Request] = deque()
        self._slots: List[_Slot] = [_Slot() for _ in range(max_slots)]
        self._next_uid = 0
        # observation hook, fired AFTER each slot-table transition:
        # ("admit", slot, request), ("preempt", slot, request) and
        # ("evict", slot, request) — eviction covers EVERY terminal slot
        # transition (completion, cancel, deadline, failure), so hook
        # consumers see the full request lifecycle.  Keeping it here — not
        # at the engines' call sites — guarantees every admission/eviction
        # path (monolithic, chunked, speculative) reports identically.
        # Plain attribute so the engine can attach it after construction;
        # policy never reads it.
        self.on_event = on_event

    # -- intake -------------------------------------------------------------

    def submit(self, request: Request) -> int:
        self._queue.append(request)
        return request.uid

    def new_uid(self) -> int:
        uid = self._next_uid
        self._next_uid += 1
        return uid

    @property
    def uid_watermark(self) -> int:
        """Next uid to be issued (snapshot/restore carries it across)."""
        return self._next_uid

    def set_uid_floor(self, n: int) -> None:
        """Never issue a uid below ``n`` (restore into a fresh scheduler)."""
        self._next_uid = max(self._next_uid, n)

    # -- queue surgery (admission control / cancel / deadlines) -------------

    def queued_requests(self) -> List[Request]:
        """FCFS view of the queue (head first).  Read-only by convention."""
        return list(self._queue)

    def drop_queued(self, uid: int) -> Optional[Request]:
        """Remove one queued request by uid (cancel / deadline-expiry /
        impossible-admission paths).  Returns it, or None if not queued."""
        for req in self._queue:
            if req.uid == uid:
                self._queue.remove(req)
                return req
        return None

    def shed_oldest(self) -> Optional[Request]:
        """Pop the OLDEST queued request (the head — under overload it is
        the most deadline-doomed); admission control's "shed-oldest"
        policy.  Returns None when the queue is empty."""
        return self._queue.popleft() if self._queue else None

    # -- admission ----------------------------------------------------------

    def next_admission(
        self, gate=None,
        prefill: Optional[Callable[[Request], bool]] = None,
    ) -> Optional[Tuple[int, Request]]:
        """Pop the next queued request and assign it the lowest free slot.
        Returns None when the queue is empty or all slots are busy.

        ``gate(request) -> bool`` lets the engine veto the admission on
        resources the scheduler can't see: free KV pages, and — under the
        paged adapter bank — the request's adapter being RESIDENT in
        device rows (a miss stages an async host→HBM upload and gates
        False until the transfer commits).  Admission stays strictly FCFS:
        if the HEAD request is gated out, nothing behind it is considered —
        skipping ahead would starve big prompts (or cold adapters)
        forever.

        ``prefill(request) -> bool`` marks the slot PREFILLING instead of
        decodable (chunked prefill): the engine streams the prompt in via
        :meth:`advance_prefill` and flips the slot live with
        :meth:`start_decode` once the final chunk produced token #1."""
        if not self._queue:
            return None
        for i, slot in enumerate(self._slots):
            if slot.free:
                if gate is not None and not gate(self._queue[0]):
                    return None
                req = self._queue.popleft()
                slot.request = req
                slot.prefill_pos = 0
                if prefill is not None and prefill(req):
                    slot.prefilling = True
                    slot.generated = 0
                    slot.steps_left = req.max_new_tokens
                else:
                    # prefill itself yields token #1; the remaining tokens
                    # come one per decode tick
                    slot.prefilling = False
                    slot.generated = 1
                    slot.steps_left = req.max_new_tokens - 1
                if self.on_event is not None:
                    self.on_event("admit", i, req)
                return i, req
        return None

    def preempt(self, slot: int) -> Request:
        """Evict a mid-flight request and requeue it at the HEAD of the
        queue (paged engines preempt the newest slot on page-pool
        exhaustion).  The request restarts from its prompt on re-admission —
        generation is deterministic per (seed, index), so it re-produces the
        same tokens it lost."""
        s = self._slots[slot]
        assert s.request is not None, f"preempting free slot {slot}"
        req = s.request
        s.request = None
        s.steps_left = 0
        s.generated = 0
        s.prefilling = False
        s.prefill_pos = 0
        self._queue.appendleft(req)
        if self.on_event is not None:
            self.on_event("preempt", slot, req)
        return req

    # -- chunked prefill ----------------------------------------------------

    def advance_prefill(self, slot: int, n: int) -> None:
        """Account ``n`` prompt tokens committed into the slot's cache by a
        prefill chunk (or mapped from a shared prefix)."""
        s = self._slots[slot]
        assert s.request is not None and s.prefilling, slot
        s.prefill_pos += n

    def start_decode(self, slot: int) -> None:
        """Flip a PREFILLING slot live: the final chunk just produced token
        #1, decode ticks take it from here."""
        s = self._slots[slot]
        assert s.request is not None and s.prefilling, slot
        assert s.prefill_pos == len(s.request.prompt), (
            slot, s.prefill_pos, len(s.request.prompt))
        s.prefilling = False
        s.generated = 1
        s.steps_left = s.request.max_new_tokens - 1

    def slot_prefill_pos(self, slot: int) -> int:
        return self._slots[slot].prefill_pos

    def prefilling_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots)
                if not s.free and s.prefilling]

    # -- decode ticks -------------------------------------------------------

    def occupied_slots(self) -> List[int]:
        """Slots holding a request — decodable OR still prefilling (the
        preemption victim pool)."""
        return [i for i, s in enumerate(self._slots) if not s.free]

    def active_slots(self) -> List[int]:
        """Decodable slots (occupied and past prefill)."""
        return [i for i, s in enumerate(self._slots)
                if not s.free and not s.prefilling]

    def completed_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots)
                if not s.free and not s.prefilling and s.steps_left <= 0]

    def tick(self) -> List[int]:
        """Account one decode step for every active slot; returns the slots
        that just finished (ready for eviction)."""
        done = []
        for i, s in enumerate(self._slots):
            if s.free or s.prefilling or s.steps_left <= 0:
                continue
            s.steps_left -= 1
            s.generated += 1
            if s.steps_left <= 0:
                done.append(i)
        return done

    def advance(self, slot: int, k: int) -> bool:
        """Account ``k`` decode tokens for one active slot (speculative
        rounds emit a variable 1..γ tokens per round); returns True when the
        request just finished (ready for eviction)."""
        s = self._slots[slot]
        assert s.request is not None, f"advancing free slot {slot}"
        assert k >= 0, k
        s.steps_left -= k
        s.generated += k
        return s.steps_left <= 0

    def evict(self, slot: int) -> Request:
        """Release a slot at a TERMINAL transition (completion, cancel,
        deadline expiry, failure).  Fires ``on_event("evict", ...)`` — the
        one choke point every terminal slot transition passes through, so
        the event log can never undercount terminal states."""
        s = self._slots[slot]
        assert s.request is not None, f"evicting free slot {slot}"
        req = s.request
        s.request = None
        s.steps_left = 0
        s.generated = 0
        s.prefilling = False
        s.prefill_pos = 0
        if self.on_event is not None:
            self.on_event("evict", slot, req)
        return req

    def reset(self) -> None:
        """Silently drop the queue and every slot (no hooks fire) — the
        snapshot-and-restart path clears state it has already serialized.
        The uid watermark survives so restored uids never collide."""
        self._queue.clear()
        for s in self._slots:
            s.request = None
            s.steps_left = 0
            s.generated = 0
            s.prefilling = False
            s.prefill_pos = 0

    # -- introspection ------------------------------------------------------

    def slot_generated(self, slot: int) -> int:
        return self._slots[slot].generated

    def slot_steps_left(self, slot: int) -> int:
        return self._slots[slot].steps_left

    def slot_request(self, slot: int) -> Optional[Request]:
        return self._slots[slot].request

    def slot_prefilling(self, slot: int) -> bool:
        return self._slots[slot].prefilling

    @property
    def queued(self) -> int:
        return len(self._queue)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(not s.free for s in self._slots)

    def utilization(self) -> float:
        return sum(not s.free for s in self._slots) / self.max_slots

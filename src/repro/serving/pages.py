"""Host-side paged KV-cache memory management.

The device holds a global pool of fixed-size K/V pages per attention layer
(:func:`repro.models.model.init_paged_cache`) plus one block table mapping
``(slot, logical page)`` → pool page (part of the jitted tick state, so the
tick's shapes never change).  THIS module is the pure-python brain that
decides which pool pages back which slot:

  * page 0 is the reserved TRASH page — free slots' block-table rows are all
    zeros, so the garbage their masked decode writes every tick lands there
    and can never corrupt a live slot;
  * admission is gated on FREE PAGES, not free slots: a request is admitted
    only when its (bucketed) prompt fits in the free list;
  * decode growth allocates one page each time a slot's sequence crosses a
    page boundary; on exhaustion the engine preempts the NEWEST admitted
    slot (its pages return to the free list, its request is requeued at the
    queue head), so the OLDEST request always keeps its pages and the engine
    can never deadlock;
  * eviction returns all of a slot's pages to the free list.

Separating policy from device state keeps the allocator unit-testable and
the accounting honest: :attr:`PageAllocator.peak_in_use` is the real
high-water HBM demand of a workload, which is what the serving benchmark
reports against the dense engine's ``max_slots × max_seq_len`` reservation.
"""
from __future__ import annotations

from typing import List

TRASH_PAGE = 0


class PoolExhausted(Exception):
    """No free pages — the caller should preempt (or queue) and retry."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to back ``n_tokens`` positions."""
    return -(-n_tokens // page_size)


def bucket_len(n: int, page_size: int, max_seq_len: int) -> int:
    """Prompt-length bucket: the smallest power of two >= n (and >= one
    page), page-aligned, capped at max_seq_len.  Distinct buckets number
    O(log max_seq_len), so prefill compiles O(log) variants instead of one
    per distinct prompt length — and every bucket is a whole number of
    pages, so bucketed prefill scatters into pages without partial pages."""
    assert 1 <= n <= max_seq_len, (n, max_seq_len)
    b = max(page_size, 1)
    while b < n:
        b *= 2
    b = -(-b // max(page_size, 1)) * max(page_size, 1)   # page-align
    return min(b, -(-max_seq_len // max(page_size, 1)) * max(page_size, 1))


class PageAllocator:
    """Free-list allocator over pool pages 1..n_pages-1 (0 is trash)."""

    def __init__(self, n_pages: int, page_size: int, max_pages_per_slot: int,
                 max_slots: int):
        assert n_pages >= 2, "pool needs the trash page plus >= 1 usable page"
        self.n_pages = n_pages
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot
        # LIFO free list: recently-freed pages are re-used first (friendlier
        # to whatever cache locality the pool enjoys on device)
        self._free: List[int] = list(range(n_pages - 1, TRASH_PAGE, -1))
        self._slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
        self.peak_in_use = 0

    # -- introspection -------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._slot_pages[slot])

    def n_slot_pages(self, slot: int) -> int:
        return len(self._slot_pages[slot])

    # -- allocation ----------------------------------------------------------

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, slot: int, n: int) -> List[int]:
        """Append ``n`` fresh pages to ``slot``; raises :class:`PoolExhausted`
        if the free list is short (nothing is partially allocated)."""
        owned = self._slot_pages[slot]
        assert len(owned) + n <= self.max_pages_per_slot, (slot, len(owned), n)
        if len(self._free) < n:
            raise PoolExhausted(f"need {n} pages, {len(self._free)} free")
        ids = [self._free.pop() for _ in range(n)]
        owned.extend(ids)
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return ids

    def ensure(self, slot: int, n_logical: int) -> List[int]:
        """Grow ``slot`` to at least ``n_logical`` pages; returns the NEWLY
        allocated ids (empty if already covered)."""
        n_logical = min(n_logical, self.max_pages_per_slot)
        short = n_logical - len(self._slot_pages[slot])
        if short <= 0:
            return []
        return self.alloc(slot, short)

    def release(self, slot: int) -> int:
        """Return all of a slot's pages to the free list (eviction or
        preemption); returns how many were freed."""
        owned = self._slot_pages[slot]
        n = len(owned)
        self._free.extend(reversed(owned))
        owned.clear()
        return n

"""Host-side paged KV-cache memory management.

The device holds a global pool of fixed-size K/V pages per attention layer
(:func:`repro.models.model.init_paged_cache`) plus one block table mapping
``(slot, logical page)`` → pool page (part of the jitted tick state, so the
tick's shapes never change).  THIS module is the pure-python brain that
decides which pool pages back which slot:

  * page 0 is the reserved TRASH page — free slots' block-table rows are all
    zeros, so the garbage their masked decode writes every tick lands there
    and can never corrupt a live slot;
  * admission is gated on FREE PAGES, not free slots: a request is admitted
    only when its (bucketed) prompt fits in the free list;
  * decode growth allocates one page each time a slot's sequence crosses a
    page boundary; on exhaustion the engine preempts the NEWEST admitted
    slot (its pages return to the free list, its request is requeued at the
    queue head), so the OLDEST request always keeps its pages and the engine
    can never deadlock;
  * eviction returns all of a slot's pages to the free list.

Every page carries a REFCOUNT so pages can be shared copy-on-write across
slots (system-prompt prefix sharing): :meth:`PageAllocator.share` maps an
existing page into another slot's table (refcount + 1, zero new pages),
:meth:`PageAllocator.retain` lets a non-slot owner (a prefix cache entry)
keep pages alive across evictions, and :meth:`PageAllocator.fork` backs a
slot's logical entry with a fresh private copy before a divergent write.
A page returns to the free list only when its refcount reaches zero, so a
shared prefix survives every sharer's eviction.

Separating policy from device state keeps the allocator unit-testable and
the accounting honest: :attr:`PageAllocator.peak_in_use` is the real
high-water HBM demand of a workload (shared pages count ONCE — that is the
prefix-sharing saving), which is what the serving benchmark reports against
the dense engine's ``max_slots × max_seq_len`` reservation.
"""
from __future__ import annotations

from typing import Iterable, List, Tuple

TRASH_PAGE = 0


class PoolExhausted(Exception):
    """No free pages — the caller should preempt (or queue) and retry."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to back ``n_tokens`` positions."""
    return -(-n_tokens // page_size)


def bucket_len(n: int, page_size: int, max_seq_len: int) -> int:
    """Prompt-length bucket: the smallest power of two >= n (and >= one
    page), page-aligned, capped at max_seq_len.  Distinct buckets number
    O(log max_seq_len), so prefill compiles O(log) variants instead of one
    per distinct prompt length — and every bucket is a whole number of
    pages, so bucketed prefill scatters into pages without partial pages."""
    assert 1 <= n <= max_seq_len, (n, max_seq_len)
    b = max(page_size, 1)
    while b < n:
        b *= 2
    b = -(-b // max(page_size, 1)) * max(page_size, 1)   # page-align
    return min(b, -(-max_seq_len // max(page_size, 1)) * max(page_size, 1))


def auto_pool_pages(max_slots: int, max_seq_len: int, page_size: int,
                    reduction: float = 2.2) -> int:
    """Auto-size a page pool ``reduction``× below the dense engine's
    ``max_slots × max_seq_len`` reservation.  The floor is one max-length
    request plus the trash page — below that the engine would preempt
    forever.

    γ-lookahead audit (speculative serving): the pool needs NO extra margin
    for speculative rounds.  A round's committed rows past a request's final
    ``prompt + max_new_tokens`` land on the trash page through the block
    table's all-zero tail, so the engine's growth pass caps its per-slot
    reservation at that limit (see ``ContinuousServeEngine._ensure_growth``)
    — a pool that fits the workload's true footprint never preempts
    mid-round, which ``tests/test_prefix.py`` regression-checks."""
    n_tbl = pages_for(max_seq_len, page_size)
    return max(n_tbl + 1, int(max_slots * n_tbl / reduction) + 1)


class PageAllocator:
    """Refcounting free-list allocator over pool pages 1..n_pages-1 (0 is
    the trash page, never handed out and never freed)."""

    def __init__(self, n_pages: int, page_size: int, max_pages_per_slot: int,
                 max_slots: int):
        assert n_pages >= 2, "pool needs the trash page plus >= 1 usable page"
        self.n_pages = n_pages
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot
        # LIFO free list: recently-freed pages are re-used first (friendlier
        # to whatever cache locality the pool enjoys on device)
        self._free: List[int] = list(range(n_pages - 1, TRASH_PAGE, -1))
        self._ref: List[int] = [0] * n_pages
        self._slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
        self.peak_in_use = 0

    # -- introspection -------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._slot_pages[slot])

    def n_slot_pages(self, slot: int) -> int:
        return len(self._slot_pages[slot])

    def refcount(self, page: int) -> int:
        return self._ref[page]

    # -- snapshot (resilience) -----------------------------------------------

    def state(self) -> dict:
        """JSON-compatible dump of the allocator: geometry + free list +
        refcounts + per-slot page lists.  Engine snapshots carry it so a
        restore can validate pool geometry and audits can reconstruct
        exactly which pages were live at the kill point (the restore path
        itself rebuilds a clean pool — re-queued requests re-prefill)."""
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "max_pages_per_slot": self.max_pages_per_slot,
            "free": list(self._free),
            "ref": list(self._ref),
            "slot_pages": [list(p) for p in self._slot_pages],
            "peak_in_use": self.peak_in_use,
        }

    def load_state(self, state: dict) -> None:
        """Restore a :meth:`state` dump into an allocator with identical
        geometry (exact-resume paths and allocator round-trip tests)."""
        assert state["n_pages"] == self.n_pages
        assert state["page_size"] == self.page_size
        assert state["max_pages_per_slot"] == self.max_pages_per_slot
        assert len(state["slot_pages"]) == len(self._slot_pages)
        self._free = list(state["free"])
        self._ref = list(state["ref"])
        self._slot_pages = [list(p) for p in state["slot_pages"]]
        self.peak_in_use = state["peak_in_use"]

    # -- allocation ----------------------------------------------------------

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def _take(self) -> int:
        if not self._free:
            raise PoolExhausted("no free pages")
        pid = self._free.pop()
        assert self._ref[pid] == 0, (pid, self._ref[pid])
        self._ref[pid] = 1
        return pid

    def _bump_peak(self):
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)

    def alloc(self, slot: int, n: int) -> List[int]:
        """Append ``n`` fresh pages to ``slot``; raises :class:`PoolExhausted`
        if the free list is short (nothing is partially allocated)."""
        owned = self._slot_pages[slot]
        assert len(owned) + n <= self.max_pages_per_slot, (slot, len(owned), n)
        if len(self._free) < n:
            raise PoolExhausted(f"need {n} pages, {len(self._free)} free")
        ids = [self._take() for _ in range(n)]
        owned.extend(ids)
        self._bump_peak()
        return ids

    def ensure(self, slot: int, n_logical: int) -> List[int]:
        """Grow ``slot`` to at least ``n_logical`` pages; returns the NEWLY
        allocated ids (empty if already covered)."""
        n_logical = min(n_logical, self.max_pages_per_slot)
        short = n_logical - len(self._slot_pages[slot])
        if short <= 0:
            return []
        return self.alloc(slot, short)

    # -- sharing / copy-on-write ---------------------------------------------

    def share(self, slot: int, ids: Iterable[int]) -> None:
        """Map already-allocated pages into ``slot``'s logical table (appended
        in order) WITHOUT copying: each page's refcount rises by one.  The
        caller must treat shared pages (refcount > 1) as read-only and
        :meth:`fork` before any divergent write."""
        ids = list(ids)
        owned = self._slot_pages[slot]
        assert len(owned) + len(ids) <= self.max_pages_per_slot
        for pid in ids:
            assert pid != TRASH_PAGE and self._ref[pid] >= 1, (pid, self._ref[pid])
            self._ref[pid] += 1
        owned.extend(ids)

    def retain(self, ids: Iterable[int]) -> None:
        """Take a non-slot reference on pages (a prefix cache entry keeping
        its pages alive across sharer evictions)."""
        for pid in ids:
            assert pid != TRASH_PAGE and self._ref[pid] >= 1, (pid, self._ref[pid])
            self._ref[pid] += 1

    def release_ids(self, ids: Iterable[int]) -> int:
        """Drop one reference per page (the inverse of :meth:`retain`);
        pages reaching refcount zero return to the free list.  Returns how
        many were actually freed."""
        freed = 0
        for pid in ids:
            assert self._ref[pid] >= 1, (pid, self._ref[pid])
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                self._free.append(pid)
                freed += 1
        return freed

    def fork(self, slot: int, logical: int) -> Tuple[int, int]:
        """Copy-on-write: back ``slot``'s ``logical`` table entry with a fresh
        private page.  The old page loses one reference (it stays alive for
        its other sharers); the caller must device-copy old → new before the
        divergent write lands.  Returns ``(old_id, new_id)``."""
        owned = self._slot_pages[slot]
        old = owned[logical]
        assert self._ref[old] >= 2, (slot, logical, old, self._ref[old])
        new = self._take()
        self._ref[old] -= 1
        owned[logical] = new
        self._bump_peak()
        return old, new

    # -- release -------------------------------------------------------------

    def release(self, slot: int) -> int:
        """Drop the slot's reference on all its pages (eviction or
        preemption); pages reaching refcount zero return to the free list.
        Returns how many were freed."""
        owned = self._slot_pages[slot]
        freed = self.release_ids(reversed(owned))
        owned.clear()
        return freed

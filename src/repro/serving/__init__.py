from repro.serving.adapters import AdapterRegistry  # noqa: F401
from repro.serving.draft import (DraftModel, build_draft,  # noqa: F401
                                 draft_from_setup)
from repro.serving.engine import (ContinuousServeEngine,  # noqa: F401
                                  GenerationResult, ServeEngine)
from repro.serving.scheduler import (Request, RequestResult,  # noqa: F401
                                     Scheduler)
from repro.serving.speculative import (SpeculativeConfig,  # noqa: F401
                                       SpeculativeServeEngine, commit_cache,
                                       commit_draft_cache, speculative_accept)

from repro.serving.adapters import AdapterRegistry  # noqa: F401
from repro.serving.engine import (ContinuousServeEngine,  # noqa: F401
                                  GenerationResult, ServeEngine)
from repro.serving.scheduler import (Request, RequestResult,  # noqa: F401
                                     Scheduler)

from repro.serving.adapters import (BASE_ADAPTER,  # noqa: F401
                                    AdapterBankFull, AdapterError,
                                    AdapterRegistry, AdapterResidency,
                                    AdapterStructureError, StaleAdapter)
from repro.serving.draft import (DraftModel, build_draft,  # noqa: F401
                                 draft_from_setup)
from repro.serving.engine import (ContinuousServeEngine,  # noqa: F401
                                  GenerationResult, PrefixEntry, ServeEngine)
from repro.serving.pages import (PageAllocator, PoolExhausted,  # noqa: F401
                                 auto_pool_pages, bucket_len, pages_for)
from repro.serving.resilience import (STATUSES,  # noqa: F401
                                      DegradationController, engine_restore,
                                      engine_snapshot)
from repro.serving.scheduler import (Request, RequestResult,  # noqa: F401
                                     Scheduler)
from repro.serving.speculative import (GammaController,  # noqa: F401
                                       SpeculativeConfig,
                                       SpeculativeServeEngine, commit_cache,
                                       commit_cache_paged, commit_draft_cache,
                                       commit_draft_cache_paged,
                                       speculative_accept)
from repro.serving.tickstate import TickState  # noqa: F401

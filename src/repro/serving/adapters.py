"""Multi-adapter registry for LoRAM serving — the "one base, many adapters"
deployment the paper motivates: adapters are trained cheaply on the pruned
model, recovered to full rank, and K of them are served simultaneously
against a single copy of the large base model.

The registry stacks K recovered adapter trees into ONE bank tree whose
leaves carry an extra ``K`` axis:

  * stacked-block leaves  (n_rep, r, d)   → (n_rep, K, r, d)   (axis 1 — the
    leading ``n_rep`` axis must stay outermost so ``lax.scan`` over depth
    still slices it)
  * shared-block / lm_head leaves (r, d)  → (K, r, d)          (axis 0)

``repro.models.layers.dense`` detects the extra axis and routes each batch
row through ``adapter_ids`` with a gather — so one jitted decode step serves
all K adapters at once and never recompiles when adapters are added or
swapped (the bank is a plain argument, not a closure constant).

Unused bank rows are zeros; LoRA deltas are ``B·A`` with ``B = 0`` → a zero
row is exactly the base model, which doubles as the built-in "no adapter"
route (:data:`BASE_ADAPTER`).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp

PyTree = Any

BASE_ADAPTER = "__base__"     # reserved name: zero delta == plain base model


def _stage_axes(stage_tree: dict) -> dict:
    return {
        "stacked": jax.tree.map(lambda _: 1, stage_tree.get("stacked", {})),
        "shared": jax.tree.map(lambda _: 0, stage_tree.get("shared", {})),
    }


def stack_axes(template: PyTree) -> PyTree:
    """Tree of ints matching ``template``: the axis at which the K (adapter)
    dimension is inserted for each leaf."""
    axes: Dict[str, Any] = {}
    for key in ("stages", "enc_stages"):
        if key in template:
            axes[key] = {stn: _stage_axes(st)
                         for stn, st in template[key].items()}
    if "lm_head" in template:
        axes["lm_head"] = jax.tree.map(lambda _: 0, template["lm_head"])
    return axes


class AdapterRegistry:
    """Named slots in a stacked adapter bank.

    ``template`` is any adapter tree with the target structure (e.g. the
    output of ``loram.finalize`` or ``init_lora`` on the FULL plan); its
    leaf values are not used, only shapes/dtypes.
    """

    def __init__(self, template: PyTree, max_adapters: int):
        assert max_adapters >= 1
        self.max_adapters = max_adapters
        self._template_struct = jax.tree.structure(template)
        self._template_shapes = [x.shape for x in jax.tree.leaves(template)]
        self._axes = stack_axes(template)
        self._bank = jax.tree.map(
            lambda leaf, ax: jnp.zeros(
                leaf.shape[:ax] + (max_adapters,) + leaf.shape[ax:],
                leaf.dtype),
            template, self._axes)
        self._names: Dict[str, int] = {}
        self._trees: List[Optional[PyTree]] = [None] * max_adapters
        # id 0 is reserved for the base-model (zero-delta) route
        self._names[BASE_ADAPTER] = 0

    # -- registration -------------------------------------------------------

    def add(self, name: str, lora: PyTree) -> int:
        """Register ``lora`` under ``name``; returns its adapter id.
        Re-registering a name overwrites its bank row (hot-swap)."""
        assert name != BASE_ADAPTER, "reserved name"
        struct = jax.tree.structure(lora)
        assert struct == self._template_struct, (
            f"adapter tree structure mismatch:\n{struct}\n"
            f"!=\n{self._template_struct}")
        shapes = [x.shape for x in jax.tree.leaves(lora)]
        assert shapes == self._template_shapes, "adapter leaf shape mismatch"

        if name in self._names:
            aid = self._names[name]
        else:
            aid = len(self._names)
            if aid >= self.max_adapters:
                raise RuntimeError(
                    f"adapter bank full ({self.max_adapters} slots; "
                    f"slot 0 is the reserved base route)")
            self._names[name] = aid

        def write(bank_leaf, leaf, ax):
            idx = (slice(None),) * ax + (aid,)
            return bank_leaf.at[idx].set(leaf.astype(bank_leaf.dtype))

        self._bank = jax.tree.map(write, self._bank, lora, self._axes)
        self._trees[aid] = lora
        return aid

    # -- lookup -------------------------------------------------------------

    def resolve(self, adapter: Union[str, int, None]) -> int:
        if adapter is None:
            return 0
        if isinstance(adapter, int):
            # ids are assigned densely from 0 (base) upward; an in-range but
            # unregistered id would silently gather a zero (= base) bank row
            if not 0 <= adapter < len(self._names):
                raise KeyError(
                    f"adapter id {adapter} not registered "
                    f"(have ids 0..{len(self._names) - 1})")
            return adapter
        if adapter not in self._names:
            known = sorted(n for n in self._names if n != BASE_ADAPTER)
            raise KeyError(
                f"unknown adapter {adapter!r}; registered: {known} "
                f"(None routes to the base model)")
        return self._names[adapter]

    def name_of(self, aid: int) -> Optional[str]:
        for n, i in self._names.items():
            if i == aid:
                return None if n == BASE_ADAPTER else n
        return None

    def adapter_tree(self, adapter: Union[str, int, None]) -> Optional[PyTree]:
        """The single (unstacked) adapter tree — the prefill-into-slot path
        runs one request at a time, so it uses the plain LoRA fast path."""
        return self._trees[self.resolve(adapter)]

    @property
    def bank(self) -> PyTree:
        return self._bank

    @property
    def names(self) -> Dict[str, int]:
        return dict(self._names)

    def __len__(self) -> int:
        return len(self._names) - 1   # exclude the reserved base route

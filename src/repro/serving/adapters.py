"""Two-tier multi-adapter store for LoRAM serving — the "one base, many
adapters" deployment the paper motivates at FLEET scale: adapters are
trained cheaply on the pruned model, recovered to full rank, and *many*
of them are served against a single copy of the large base model.

Tier 1 (host): an UNBOUNDED registry of recovered adapter trees.
Registration (:meth:`AdapterRegistry.add`) never fails on capacity — a
recovered adapter is a host-memory artifact until traffic needs it.

Tier 2 (device): ONE stacked bank tree with a fixed ``bank_slots`` row
axis, managed by an LRU :class:`AdapterResidency` allocator (free list +
refcounts from active slots, mirroring
:class:`repro.serving.pages.PageAllocator`).  The engine gates admission
on residency exactly like it gates on free KV pages: a miss enqueues an
async ``jax.device_put`` upload (committed into the bank between decode
ticks — a miss costs queue time, not tick time), rows are evicted LRU and
only at refcount zero, and an evicted row is ZEROED so a stray gather of
it serves the base model, never a stale adapter.

Bank layout (unchanged from the dense registry this replaced):

  * stacked-block leaves  (n_rep, r, d)   → (n_rep, A, r, d)   (axis 1 — the
    leading ``n_rep`` axis must stay outermost so ``lax.scan`` over depth
    still slices it)
  * shared-block / lm_head leaves (r, d)  → (A, r, d)          (axis 0)

with ``A = bank_slots`` device rows.  ``repro.models.layers.dense``
detects the extra axis and routes each batch row through ``adapter_ids``
(which now carry bank ROWS, resolved at admission) with a gather — so one
jitted decode step serves every resident adapter at once and NEVER
recompiles across uploads, evictions, or hot-swaps: the bank is a plain
argument with fixed shapes, and every row write is a functional
``.at[row].set``.

Rank heterogeneity: mixed-rank adapters share the one bank through
zero-padded rank buckets (``rank_buckets``).  An adapter whose leaves
undershoot the template on their rank axis is zero-padded up to its
bucket's rank; the device row write zeroes the row first and writes the
(possibly partial-rank) block, so the remaining tail is zeros.  Padded
rank rows of ``A`` and columns of ``B`` contribute exactly ``B·A = 0`` to
the delta — zero-padding is zero-delta through the serving einsum
(verified in ``tests/test_adapters.py``).

Unused/evicted bank rows are zeros; LoRA deltas are ``B·A`` with
``B = 0`` → a zero row is exactly the base model, which doubles as the
built-in "no adapter" route (:data:`BASE_ADAPTER`, pinned to row 0).

Under a mesh the bank stays REPLICATED (rank-r factors are tiny) —
``repro.distributed.sharding.adapter_bank_specs`` declares the placement;
engines leave bank rows uncommitted so jit places them against the
committed operands.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

PyTree = Any

BASE_ADAPTER = "__base__"     # reserved name: zero delta == plain base model
BASE_ROW = 0                  # bank row 0 is pinned to the base route


# ---------------------------------------------------------------------------
# typed exceptions (exported from repro.serving)
# ---------------------------------------------------------------------------

class AdapterError(Exception):
    """Base class for adapter-store errors."""


class AdapterStructureError(AdapterError):
    """A registered tree does not match the template: wrong structure, or a
    leaf that differs from the template on anything but a (smaller) rank
    axis."""


class AdapterBankFull(AdapterError, RuntimeError):
    """The device bank cannot host the adapter: every row is pinned by an
    active slot (or the bank has no adapter rows at all).  Subclasses
    RuntimeError for continuity with the dense registry's capacity error."""


class StaleAdapter(AdapterError, KeyError):
    """``resolve()`` of a REMOVED adapter id.  Subclasses KeyError: a stale
    id must fail loudly, never silently gather a zeroed row (i.e. serve the
    base model for what the caller believes is a real adapter)."""


# ---------------------------------------------------------------------------
# bank geometry helpers
# ---------------------------------------------------------------------------

def _stage_axes(stage_tree: dict) -> dict:
    return {
        "stacked": jax.tree.map(lambda _: 1, stage_tree.get("stacked", {})),
        "shared": jax.tree.map(lambda _: 0, stage_tree.get("shared", {})),
    }


def stack_axes(template: PyTree) -> PyTree:
    """Tree of ints matching ``template``: the axis at which the bank-row
    dimension is inserted for each leaf."""
    axes: Dict[str, Any] = {}
    for key in ("stages", "enc_stages"):
        if key in template:
            axes[key] = {stn: _stage_axes(st)
                         for stn, st in template[key].items()}
    if "lm_head" in template:
        axes["lm_head"] = jax.tree.map(lambda _: 0, template["lm_head"])
    return axes


def _rank_axis(shape: Tuple[int, ...],
               template: Tuple[int, ...]) -> Optional[int]:
    """The single axis on which ``shape`` undershoots ``template`` — the
    leaf's LoRA rank axis (``A`` carries rank at -2, ``B`` at -1, but the
    detection is shape-driven, not name-driven).  None when the shapes
    match exactly; :class:`AdapterStructureError` for anything else."""
    if shape == template:
        return None
    if len(shape) != len(template):
        raise AdapterStructureError(
            f"adapter leaf rank mismatch: {shape} vs template {template}")
    diff = [i for i, (s, t) in enumerate(zip(shape, template)) if s != t]
    if len(diff) != 1 or shape[diff[0]] > template[diff[0]]:
        raise AdapterStructureError(
            f"adapter leaf shape {shape} does not match template "
            f"{template} (only the rank axis may be smaller)")
    return diff[0]


def bucket_rank(r: int, r_template: int, n_buckets: int) -> int:
    """The padded rank for a rank-``r`` leaf: the smallest of ``n_buckets``
    even steps up to the template rank that covers ``r``.  One bucket →
    everything pads to the template rank."""
    assert 1 <= r <= r_template, (r, r_template)
    for i in range(1, n_buckets + 1):
        b = -(-r_template * i // n_buckets)
        if b >= r:
            return b
    return r_template


# ---------------------------------------------------------------------------
# residency: LRU row allocator over the device bank
# ---------------------------------------------------------------------------

class AdapterResidency:
    """LRU allocator over bank rows ``1..bank_slots-1`` (row 0 is the base
    route, never handed out), mirroring
    :class:`repro.serving.pages.PageAllocator`: a LIFO free list, per-id
    refcounts held by active slots, and eviction restricted to
    refcount-zero rows in least-recently-used order.

    One residency instance can drive SEVERAL attached stores (the target
    registry and the draft's pruned-width registry): every row decision —
    assignment, upload, eviction-zeroing — is applied to each attached
    bank, so target and draft stay in lockstep and one ``adapter_ids``
    row indexes both.

    Uploads are two-phase so a miss never stalls the decode tick:
    :meth:`acquire` (the admission gate) stages an async
    ``jax.device_put`` of the host tree and returns False; the engine's
    next :meth:`poll` commits the staged arrays into the bank with
    functional ``.at[row].set`` updates (device work, no host sync) and
    the request admits on the following gate check.
    """

    _EVENT_CAP = 512          # bounded upload/evict backlog (drop-oldest)

    def __init__(self, bank_slots: int):
        if bank_slots < 1:
            raise ValueError(f"bank_slots must be >= 1, got {bank_slots}")
        self.bank_slots = bank_slots
        # LIFO free list: recently-freed rows are re-used first
        self._free: List[int] = list(range(bank_slots - 1, BASE_ROW, -1))
        self._row_of: Dict[int, int] = {}      # aid → row (incl. uploading)
        self._aid_of: Dict[int, int] = {}      # row → aid
        self._ref: Dict[int, int] = {}         # aid → active-slot refcount
        self._lru: Dict[int, int] = {}         # aid → last-touch clock
        self._clock = 0
        # aid → (per-store staged device trees, total staged bytes)
        self._uploading: Dict[int, Tuple[list, int]] = {}
        self._stores: List["AdapterRegistry"] = []
        # monotonic telemetry (engines bind gauges to these; reset_stats()
        # is the benchmark warm-up boundary)
        self.n_hits = 0
        self.n_misses = 0
        self.n_uploads = 0
        self.n_evictions = 0
        self.upload_bytes = 0
        self._events: List[tuple] = []   # ("upload"|"evict", aid, row, bytes)

    # -- store attachment ----------------------------------------------------

    def attach(self, store: "AdapterRegistry") -> None:
        if store not in self._stores:
            self._stores.append(store)

    def detach(self, store: "AdapterRegistry") -> None:
        if store in self._stores:
            self._stores.remove(store)

    # -- introspection -------------------------------------------------------

    @property
    def in_use(self) -> int:
        """Adapter rows currently assigned (resident + mid-upload)."""
        return len(self._row_of)

    @property
    def free_rows(self) -> int:
        return len(self._free)

    @property
    def hit_rate(self) -> float:
        total = self.n_hits + self.n_misses
        return self.n_hits / total if total else 1.0

    def resident(self, aid: int) -> bool:
        return aid == 0 or (aid in self._row_of
                            and aid not in self._uploading)

    def refcount(self, aid: int) -> int:
        return self._ref.get(aid, 0)

    def assignments(self) -> List[Tuple[int, int]]:
        """Committed (aid, row) pairs — what a follower bank must mirror."""
        return sorted((a, r) for a, r in self._row_of.items()
                      if a not in self._uploading)

    def row(self, aid: int) -> int:
        """The bank row backing a RESIDENT adapter (touches LRU).  KeyError
        for anything not resident — the engine resolves rows only at
        admission, after the gate proved residency."""
        if aid == 0:
            return BASE_ROW
        if not self.resident(aid):
            raise KeyError(f"adapter id {aid} is not resident "
                           f"(rows in use: {self.in_use}/{self.bank_slots - 1})")
        self._touch(aid)
        return self._row_of[aid]

    def state(self) -> dict:
        """JSON-compatible dump (snapshots / audits)."""
        return {
            "bank_slots": self.bank_slots,
            "free": list(self._free),
            "rows": self.assignments(),
            "ref": sorted((a, c) for a, c in self._ref.items() if c),
            "uploading": sorted(self._uploading),
            "hits": self.n_hits, "misses": self.n_misses,
            "uploads": self.n_uploads, "evictions": self.n_evictions,
            "upload_bytes": self.upload_bytes,
        }

    def reset_stats(self) -> None:
        self.n_hits = self.n_misses = self.n_uploads = self.n_evictions = 0
        self.upload_bytes = 0

    # -- refcounts (active slots) --------------------------------------------

    def retain(self, aid: int) -> None:
        """One active slot now routes through ``aid`` (engine admit hook)."""
        if aid == 0:
            return
        assert aid in self._row_of, f"retain of non-resident adapter {aid}"
        self._ref[aid] = self._ref.get(aid, 0) + 1
        self._touch(aid)

    def release(self, aid: int) -> None:
        """Inverse of :meth:`retain` (slot eviction/preemption hook)."""
        if aid == 0:
            return
        assert self._ref.get(aid, 0) >= 1, \
            f"release of unretained adapter {aid}"
        self._ref[aid] -= 1

    def clear_refcounts(self) -> None:
        """Engine runtime-state reset: the slot table was wiped without
        per-slot evictions, so every slot-held reference drops at once."""
        self._ref.clear()

    # -- allocation ----------------------------------------------------------

    def _touch(self, aid: int) -> None:
        self._clock += 1
        self._lru[aid] = self._clock

    def _event(self, kind: str, aid: int, row: int, nbytes: int) -> None:
        self._events.append((kind, aid, row, nbytes))
        if len(self._events) > self._EVENT_CAP:
            del self._events[:-self._EVENT_CAP]

    def drain_events(self) -> List[tuple]:
        out, self._events = self._events, []
        return out

    def _victim(self) -> Optional[int]:
        """LRU refcount-zero resident id (in-flight uploads are exempt)."""
        cands = [a for a in self._row_of
                 if not self._ref.get(a, 0) and a not in self._uploading]
        if not cands:
            return None
        return min(cands, key=lambda a: self._lru.get(a, 0))

    def can_host(self, aid: int) -> bool:
        """Could ``aid`` be made resident right now (already in, a free
        row, or an evictable victim)?  False only while every row is
        pinned by active slots — admission blocks until a release."""
        return (aid == 0 or aid in self._row_of or bool(self._free)
                or self._victim() is not None)

    def _evict(self, aid: int) -> int:
        """Drop a refcount-zero resident; its row is ZEROED in every
        attached bank (a stray gather now serves the base model, never a
        stale adapter) and returned to the caller."""
        row = self._row_of.pop(aid)
        del self._aid_of[row]
        self._lru.pop(aid, None)
        self._ref.pop(aid, None)
        for store in self._stores:
            store._zero_row(row)
        self.n_evictions += 1
        self._event("evict", aid, row, 0)
        return row

    def evict(self, aid: int) -> bool:
        """Explicitly evict ``aid`` (host tree untouched — it re-uploads on
        next use).  False if not assigned; :class:`AdapterError` while an
        active slot still routes through it."""
        if aid not in self._row_of:
            return False
        if self._ref.get(aid, 0):
            raise AdapterError(
                f"adapter {aid} is routed by {self._ref[aid]} active "
                f"slot(s) — drain them first")
        self._uploading.pop(aid, None)
        self._free.append(self._evict(aid))
        return True

    def _assign_row(self, aid: int) -> Optional[int]:
        if self._free:
            row = self._free.pop()
        else:
            victim = self._victim()
            if victim is None:
                return None
            row = self._evict(victim)
        self._row_of[aid] = row
        self._aid_of[row] = aid
        self._touch(aid)
        return row

    def acquire(self, aid: int) -> bool:
        """THE admission gate: True iff ``aid`` is resident NOW.

        A miss assigns a row (free list first, else LRU refcount-zero
        eviction), stages an async ``jax.device_put`` upload from every
        attached store, and returns False — the request waits in queue
        while the transfer overlaps decode ticks; the engine's next
        :meth:`poll` commits it.  With every row pinned by active slots
        nothing is staged and the gate stays False until a slot releases
        its reference (FCFS admission blocks, never corrupts)."""
        if aid == 0:
            return True
        if self.resident(aid):
            self._touch(aid)
            self.n_hits += 1
            return True
        if aid in self._uploading:
            return False              # transfer in flight — commit at poll()
        row = self._assign_row(aid)
        if row is None:
            return False              # all rows pinned by active slots
        self.n_misses += 1
        staged, nbytes = [], 0
        for store in self._stores:
            tree = store._stage_upload(aid)
            staged.append(tree)
            if tree is not None:
                nbytes += sum(x.nbytes for x in jax.tree.leaves(tree))
        self._uploading[aid] = (staged, nbytes)
        return False

    def poll(self) -> None:
        """Commit every staged upload into the attached banks (functional
        ``.at[row].set`` — device work, the host never syncs).  Engines
        call this once per step, before the admission pass."""
        if not self._uploading:
            return
        for aid, (staged, nbytes) in list(self._uploading.items()):
            row = self._row_of[aid]
            for store, tree in zip(self._stores, staged):
                store._commit_row(aid, row, staged=tree)
            del self._uploading[aid]
            self.n_uploads += 1
            self.upload_bytes += nbytes
            self._event("upload", aid, row, nbytes)

    def populate(self, aid: int) -> Optional[int]:
        """Registration-time residency (synchronous commit): a hot-swap
        rewrites its existing row in place; a NEW adapter takes a free row
        if one exists — registration never evicts, so it cannot disturb
        the serving working set.  Returns the row, or None when the tree
        stays host-only until first use."""
        row = self._row_of.get(aid)
        if row is None:
            if not self._free:
                return None
            row = self._free.pop()
            self._row_of[aid] = row
            self._aid_of[row] = aid
            self._touch(aid)
        # a fresh registration supersedes any in-flight staged upload
        self._uploading.pop(aid, None)
        nbytes = 0
        for store in self._stores:
            nbytes += store._commit_row(aid, row)
        self.n_uploads += 1
        self.upload_bytes += nbytes
        self._event("upload", aid, row, nbytes)
        return row


# ---------------------------------------------------------------------------
# registry: unbounded host tier + device bank
# ---------------------------------------------------------------------------

class AdapterRegistry:
    """Two-tier named adapter store.

    ``template`` is any adapter tree with the target structure (e.g. the
    output of ``loram.finalize`` or ``init_lora`` on the FULL plan); its
    leaf values are not used, only shapes/dtypes.  Registered adapters may
    undershoot the template on their rank axes (zero-padded per
    ``rank_buckets`` — exactly zero-delta through the serving einsum).

    ``bank_slots`` (default: ``max_adapters``, the dense registry's old
    capacity knob — kept as an alias so existing call sites behave
    identically) sizes the DEVICE bank only; the host tier is unbounded.
    With ``bank_slots`` >= registered adapters every adapter gets a row at
    registration and the store degenerates to the dense bank (token-
    identical, pinned by tests); with fewer rows the engine streams
    adapters in on demand through :attr:`residency`.
    """

    def __init__(self, template: PyTree, max_adapters: int = 4, *,
                 bank_slots: Optional[int] = None, rank_buckets: int = 1,
                 residency: Optional[AdapterResidency] = None):
        bank_slots = max_adapters if bank_slots is None else bank_slots
        if bank_slots < 1:
            raise ValueError(f"bank_slots must be >= 1, got {bank_slots}")
        if rank_buckets < 1:
            raise ValueError(f"rank_buckets must be >= 1, got {rank_buckets}")
        self.bank_slots = bank_slots
        self.rank_buckets = rank_buckets
        self._template_struct = jax.tree.structure(template)
        self._template_shapes = [x.shape for x in jax.tree.leaves(template)]
        self._axes = stack_axes(template)
        self._bank = jax.tree.map(
            lambda leaf, ax: jnp.zeros(
                leaf.shape[:ax] + (bank_slots,) + leaf.shape[ax:],
                leaf.dtype),
            template, self._axes)
        self._names: Dict[str, int] = {BASE_ADAPTER: 0}
        self._ids: Dict[int, str] = {0: BASE_ADAPTER}   # O(1) reverse map
        self._trees: Dict[int, PyTree] = {}             # host tier (padded)
        self._retired: set = set()                      # removed ids
        self._next_id = 1
        self.residency = residency or AdapterResidency(bank_slots)
        self.residency.attach(self)

    @property
    def max_adapters(self) -> int:
        """Dense-registry alias for :attr:`bank_slots` (device rows)."""
        return self.bank_slots

    # -- registration -------------------------------------------------------

    def _pad_to_bucket(self, leaf, template_shape: Tuple[int, ...]):
        """Zero-pad a (possibly smaller-rank) leaf up to its rank bucket.
        Padded A-rows/B-columns contribute ``B·A = 0`` — exactly the base
        route for the padded tail."""
        ax = _rank_axis(tuple(leaf.shape), tuple(template_shape))
        if ax is None:
            return leaf
        target = bucket_rank(leaf.shape[ax], template_shape[ax],
                             self.rank_buckets)
        if target == leaf.shape[ax]:
            return leaf
        pad = [(0, 0)] * leaf.ndim
        pad[ax] = (0, target - leaf.shape[ax])
        return jnp.pad(leaf, pad)

    def add(self, name: str, lora: PyTree) -> int:
        """Register ``lora`` under ``name`` in the HOST tier; returns its
        (stable) adapter id.  Re-registering a name hot-swaps it: the host
        tree is replaced and, if resident, its bank row is rewritten in
        place — live traffic picks the new weights up on the next tick,
        with no recompile (fixed bank shapes).  A new adapter becomes
        resident immediately when a free bank row exists; otherwise it
        stays host-only until the admission gate streams it in."""
        if name == BASE_ADAPTER:
            raise AdapterError(f"{BASE_ADAPTER!r} is the reserved base route")
        struct = jax.tree.structure(lora)
        if struct != self._template_struct:
            raise AdapterStructureError(
                f"adapter tree structure mismatch:\n{struct}\n"
                f"!=\n{self._template_struct}")
        leaves = jax.tree.leaves(lora)
        padded = [self._pad_to_bucket(x, t)
                  for x, t in zip(leaves, self._template_shapes)]
        tree = jax.tree.unflatten(self._template_struct, padded)
        if name in self._names:
            aid = self._names[name]
        else:
            aid = self._next_id
            self._next_id += 1
            self._names[name] = aid
            self._ids[aid] = name
        self._trees[aid] = tree
        self.residency.populate(aid)
        return aid

    def remove(self, name: str) -> int:
        """Unregister ``name`` from the host tier and free its bank row
        (zeroed).  Refuses (:class:`AdapterError`) while an active slot
        still routes through it.  The id is RETIRED: ``resolve()`` of it
        raises :class:`StaleAdapter` from then on — a stale id must never
        silently serve the base model."""
        if name == BASE_ADAPTER or name not in self._names:
            raise KeyError(f"unknown adapter {name!r}")
        aid = self._names[name]
        if self.residency.refcount(aid):
            raise AdapterError(
                f"adapter {name!r} is routed by "
                f"{self.residency.refcount(aid)} active slot(s)")
        self.residency.evict(aid)
        del self._names[name]
        del self._ids[aid]
        self._trees.pop(aid, None)
        self._retired.add(aid)
        return aid

    # -- lookup -------------------------------------------------------------

    def resolve(self, adapter: Union[str, int, None]) -> int:
        """Name/id/None → host adapter id (NOT a bank row — rows are
        resolved at admission via :meth:`bank_row`)."""
        if adapter is None:
            return 0
        if isinstance(adapter, int):
            if adapter in self._retired:
                raise StaleAdapter(
                    f"adapter id {adapter} was removed — stale ids do not "
                    f"silently route to the base model")
            if adapter not in self._ids:
                raise KeyError(
                    f"adapter id {adapter} not registered "
                    f"(have {sorted(self._ids)})")
            return adapter
        if adapter not in self._names:
            known = sorted(n for n in self._names if n != BASE_ADAPTER)
            raise KeyError(
                f"unknown adapter {adapter!r}; registered: {known} "
                f"(None routes to the base model)")
        return self._names[adapter]

    def name_of(self, aid: int) -> Optional[str]:
        """O(1) reverse lookup (None for the base route / unknown ids)."""
        name = self._ids.get(aid)
        return None if name in (None, BASE_ADAPTER) else name

    def has_id(self, aid: int) -> bool:
        return aid in self._ids

    def adapter_tree(self, adapter: Union[str, int, None]) -> Optional[PyTree]:
        """The single (unstacked, bucket-padded) host tree — the
        prefill-into-slot path runs one request at a time, so it uses the
        plain LoRA fast path."""
        return self._trees.get(self.resolve(adapter))

    # -- residency surface (engine admission path) --------------------------

    def resident(self, adapter: Union[str, int, None]) -> bool:
        return self.residency.resident(self.resolve(adapter))

    def acquire(self, adapter: Union[str, int, None]) -> bool:
        """Admission gate: True iff resident now; a miss stages an async
        upload (see :meth:`AdapterResidency.acquire`)."""
        return self.residency.acquire(self.resolve(adapter))

    def bank_row(self, adapter: Union[str, int, None]) -> int:
        """The device bank row for a RESIDENT adapter — what admission
        writes into ``TickState.adapter_ids``."""
        return self.residency.row(self.resolve(adapter))

    def upload(self, adapter: Union[str, int, None]) -> int:
        """Force-make an adapter resident NOW (synchronous commit);
        returns its row.  :class:`AdapterBankFull` when every row is
        pinned by an active slot (or the bank has no adapter rows)."""
        aid = self.resolve(adapter)
        if self.residency.resident(aid):
            return self.residency.row(aid)
        if not self.residency.acquire(aid) \
                and aid not in self.residency._uploading:
            raise AdapterBankFull(
                f"adapter bank full ({self.bank_slots} rows; row 0 is the "
                f"reserved base route and every other row is pinned by an "
                f"active slot)")
        self.residency.poll()
        return self.residency.row(aid)

    def follow(self, leader: "AdapterRegistry") -> None:
        """Adopt ``leader``'s residency manager (draft-bank lockstep): row
        assignment, refcounts, LRU and upload scheduling are decided ONCE
        and applied to both banks, so the one ``adapter_ids`` row a slot
        carries indexes target and draft alike.  This bank is rebuilt to
        mirror the leader's current assignments; ids must have been
        registered in the same order on both stores."""
        if leader.residency is self.residency:
            return
        if leader.bank_slots != self.bank_slots:
            raise ValueError(
                f"follower bank_slots={self.bank_slots} != leader's "
                f"{leader.bank_slots} — lockstep banks must be congruent")
        self.residency.detach(self)
        self.residency = leader.residency
        self.residency.attach(self)
        self._bank = jax.tree.map(jnp.zeros_like, self._bank)
        for aid, row in self.residency.assignments():
            self._commit_row(aid, row)

    # -- device-bank row writes (driven by the residency manager) -----------

    def _stage_upload(self, aid: int) -> Optional[PyTree]:
        """Async host→device transfer of the adapter's padded tree (None
        when this store has no tree for the id — e.g. a draft bank that
        lags the target; its zeroed row serves the pruned base)."""
        tree = self._trees.get(aid)
        return None if tree is None else jax.device_put(tree)

    def _zero_row(self, row: int) -> None:
        def zero(bank_leaf, ax):
            idx = (slice(None),) * ax + (row,)
            return bank_leaf.at[idx].set(0)
        self._bank = jax.tree.map(zero, self._bank, self._axes)

    def _commit_row(self, aid: int, row: int,
                    staged: Optional[PyTree] = None) -> int:
        """Write ``aid``'s tree into bank row ``row`` (zeroing it first so
        a previous occupant — or the rank tail past a bucket-padded block
        — can never leak through).  Returns the bytes written."""
        tree = staged if staged is not None else self._trees.get(aid)
        self._zero_row(row)
        if tree is None:
            return 0        # no tree in this store: zero row = base route

        def write(bank_leaf, leaf, ax):
            # the leaf may sit BELOW the template rank (bucket padding):
            # write the sub-block; the zeroed tail supplies the rest
            idx = (tuple(slice(0, s) for s in leaf.shape[:ax]) + (row,)
                   + tuple(slice(0, s) for s in leaf.shape[ax:]))
            return bank_leaf.at[idx].set(leaf.astype(bank_leaf.dtype))

        self._bank = jax.tree.map(write, self._bank, tree, self._axes)
        return sum(int(x.nbytes) for x in jax.tree.leaves(tree))

    # -- views --------------------------------------------------------------

    @property
    def bank(self) -> PyTree:
        return self._bank

    @property
    def names(self) -> Dict[str, int]:
        return dict(self._names)

    def __len__(self) -> int:
        return len(self._names) - 1   # exclude the reserved base route

"""Speculative decoding: the LoRAM-pruned model drafts, the full model
verifies — the paper's memory-saving artifact turned into a serving-latency
win.

Per continuous-batching round (one jitted dispatch, fixed shapes forever):

  1. **Draft**: the pruned small model proposes γ tokens per slot via a
     ``lax.scan`` of single-token decode steps, running its PRE-RECOVERY
     (pruned-width) adapters from the draft bank.
  2. **Verify**: the full model scores all γ tokens per slot in ONE batched
     forward (:func:`repro.models.model.verify_step`) — one weight pass for γ
     tokens, which is the entire economics of speculative decoding.
  3. **Accept**: greedy slots accept the longest prefix matching the target
     argmax (output is token-identical to non-speculative decoding);
     temperature>0 slots run standard acceptance-rejection sampling
     (Leviathan et al.; Chen et al. 2023): accept ``d ~ q`` with probability
     ``min(1, p(d)/q(d))``, else emit a sample from ``norm(max(p - q, 0))``
     — the emitted distribution is EXACTLY the target's ``p``.
  4. **Commit**: the verify pass never wrote the persistent caches; a fused
     scatter commits only the accepted prefix (attention K/V rows) / selects
     the accepted per-step state snapshot (SSM, conv), and the draft's
     rejected writes are rolled back from saved rows.  Nothing downstream
     ever observes a rejected token.

Rounds emit between 1 and γ tokens.  When all γ drafts are accepted the round
emits exactly γ (no bonus token): the draft then sits exactly ONE token
behind the target — the same lag as after a rejection — so every round has
identical shapes and neither model ever recompiles mid-flight.

Per-slot ``speculative=False`` requests share the same round with all
accepts masked off; their correction token is sampled from the raw target
logits with the plain engine's exact ``(seed, generation index)`` key, so
plain traffic through this engine is bit-identical to
:class:`~repro.serving.engine.ContinuousServeEngine` output.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ServeConfig
from repro.distributed import sharding
from repro.models.model import init_cache, init_paged_cache, ring_pages
from repro.quant import kv as qkv
from repro.runtime.steps import (attn_window_map, make_copy_page,
                                 make_draft_loop, make_paged_draft_loop,
                                 make_paged_prefill_chunk,
                                 make_paged_prefill_into_slot,
                                 make_prefill_into_slot, make_state_ops,
                                 make_verify_step, request_key)
from repro.serving.adapters import AdapterError, AdapterRegistry
from repro.serving.draft import DraftModel
from repro.serving.resilience import DEGRADE_SHRINK_GAMMA
from repro.serving.engine import (ContinuousServeEngine, _counter_property,
                                  _null)
from repro.serving.pages import pages_for
from repro.serving.scheduler import RequestResult
from repro.serving.tickstate import TickState

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SpeculativeConfig:
    """Knobs of the draft-then-verify subsystem.

    gamma:       draft proposals per round (verify scores γ tokens at once).
    draft_stage: which pruned artifact proposes — "trained" runs the pruned
                 base WITH the pruned-width adapters (best acceptance),
                 "base" runs the pruned base alone (one draft for all
                 adapter streams; correct, lower acceptance).
    """

    gamma: int = 4
    draft_stage: str = "trained"

    def __post_init__(self):
        assert self.gamma >= 1, "draft_gamma must be >= 1"
        assert self.draft_stage in ("trained", "base"), self.draft_stage

    @classmethod
    def from_serve(cls, cfg: ServeConfig) -> "SpeculativeConfig":
        if cfg.draft_gamma < 1:
            # 0 means "speculation disabled" — don't silently pick a default
            raise ValueError(
                "ServeConfig.draft_gamma=0 disables speculation; set "
                "draft_gamma >= 1 (or pass an explicit SpeculativeConfig) "
                "to use SpeculativeServeEngine")
        return cls(gamma=cfg.draft_gamma, draft_stage=cfg.draft_stage)


# ---------------------------------------------------------------------------
# γ auto-tuning (pure host-side math — unit-tested directly)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GammaController:
    """Adapts the draft length γ to the MEASURED acceptance rate.

    Cost model (in units of one plain decode tick): a round costs
    ``γ·c_draft + c_verify`` and emits ``E[tokens] = (1-α^γ)/(1-α)`` tokens
    when each proposal is accepted i.i.d. with probability α (the geometric
    prefix-accept expectation; rounds emit accepted drafts plus one
    correction, capped at γ).  The controller keeps an EMA of α from the
    engine's (accepted, proposed) counters and proposes the γ maximizing
    expected tokens/cost — with hysteresis: it only moves when the predicted
    throughput gain exceeds ``hysteresis`` (each distinct γ compiles its own
    round, so flapping is expensive).
    """

    gamma_min: int = 1
    gamma_max: int = 8
    c_draft: float = 0.3       # draft decode tick cost / plain tick cost
    c_verify: float = 1.75     # γ-token verify cost / plain tick cost
    ema: float = 0.8           # weight on the running estimate per update
    hysteresis: float = 0.10   # min predicted gain before switching
    min_samples: int = 32      # proposals before trusting the estimate

    def __post_init__(self):
        assert 1 <= self.gamma_min <= self.gamma_max
        self._alpha = 0.75     # optimistic prior — don't collapse γ on boot
        self._seen = 0

    @property
    def acceptance(self) -> float:
        return self._alpha

    @staticmethod
    def expected_tokens(gamma: int, alpha: float) -> float:
        """E[tokens emitted per round] at per-proposal acceptance alpha."""
        if alpha >= 1.0:
            return float(gamma)
        return (1.0 - alpha ** gamma) / (1.0 - alpha)

    def throughput(self, gamma: int, alpha: Optional[float] = None) -> float:
        """Expected tokens per plain-tick-equivalent of compute."""
        a = self._alpha if alpha is None else alpha
        return (self.expected_tokens(gamma, a)
                / (gamma * self.c_draft + self.c_verify))

    def best_gamma(self, alpha: Optional[float] = None) -> int:
        return max(range(self.gamma_min, self.gamma_max + 1),
                   key=lambda g: self.throughput(g, alpha))

    def update(self, accepted: int, proposed: int) -> None:
        if proposed <= 0:
            return
        rate = accepted / proposed
        self._alpha = self.ema * self._alpha + (1.0 - self.ema) * rate
        self._seen += proposed

    def propose(self, current: int) -> int:
        """The γ to use next round — ``current`` unless the best γ's
        predicted throughput beats it by more than the hysteresis margin."""
        if self._seen < self.min_samples:
            return current
        best = self.best_gamma()
        if best == current:
            return current
        cur_tp = self.throughput(current)
        if self.throughput(best) > (1.0 + self.hysteresis) * cur_tp:
            return best
        return current


# ---------------------------------------------------------------------------
# acceptance-rejection (pure math — property-tested directly)
# ---------------------------------------------------------------------------

def speculative_accept(p, q, drafts, uniforms, *, greedy_ok=None, temps=None,
                       spec=None):
    """Leading-accept count + residual distribution at the first rejection.

    p, q: (B, T, V) target/draft distributions per position; drafts (B, T)
    proposed tokens; uniforms (B, T) accept draws in [0, 1).  Position i is
    accepted iff ``u_i · q_i(d_i) < p_i(d_i)`` (for greedy rows, iff the draft
    matches ``greedy_ok``); ``spec=False`` rows reject everything, and their
    residual collapses to the raw target distribution (q treated as 0) — that
    is what makes non-speculative slots inside a speculative round emit
    exactly plain-engine tokens.

    Returns ``(n, m, resid)``: n (B,) leading accepts, m = min(n, T-1) the
    correction position, resid (B, V) the normalized ``max(p_m - q_m, 0)``
    residual.  Emitting drafts[:, :n] then (when n < T) a resid sample yields
    EXACTLY the target distribution at every position.
    """
    B, T, _ = p.shape
    bidx = jnp.arange(B)
    p_d = jnp.take_along_axis(p, drafts[..., None], axis=-1)[..., 0]
    q_d = jnp.take_along_axis(q, drafts[..., None], axis=-1)[..., 0]
    acc = uniforms * q_d < p_d
    if greedy_ok is not None:
        acc = jnp.where(temps[:, None] > 0.0, acc, greedy_ok)
    if spec is not None:
        acc = acc & spec[:, None]
    n = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
    m = jnp.minimum(n, T - 1)
    q_eff = q if spec is None else jnp.where(spec[:, None, None], q, 0.0)
    resid = jnp.maximum(p[bidx, m] - q_eff[bidx, m], 0.0)
    resid = resid / jnp.maximum(jnp.sum(resid, axis=-1, keepdims=True), 1e-30)
    return n, m, resid


# ---------------------------------------------------------------------------
# cache commit / rollback
# ---------------------------------------------------------------------------

def _commit_kv(big, pend, pos, n_keep):
    """Scatter pending K/V rows j < n_keep[b] into the ring cache; rows at or
    beyond the accept boundary keep their pre-round values."""
    S, T = big.shape[2], pend.shape[2]
    B = pos.shape[0]
    bidx = jnp.arange(B)
    slots = (pos[:, None] + jnp.arange(T)[None, :]) % S         # (B, T)
    old = big[:, bidx[:, None], slots]                          # (r, B, T, ...)
    keep = jnp.arange(T)[None, :] < n_keep[:, None]
    mixed = jnp.where(keep[None, :, :, None, None], pend.astype(big.dtype), old)
    return big.at[:, bidx[:, None], slots].set(mixed)


def _commit_kv_all(big, pend, pos):
    """Full-length caches (slot == position, the ring never wraps): write ALL
    pending rows.  Rows past the accept boundary are semantically stale but
    harmless — every reader masks positions beyond the committed ``pos`` and
    resumed decoding overwrites them in order — so the masked read-modify-
    write of :func:`_commit_kv` is unnecessary.  Rows past the END of the
    cache (a slot in its final tokens) are dropped, not wrapped: position
    ``max_seq_len`` does not exist, and wrapping would corrupt position 0."""
    T = pend.shape[2]
    B = pos.shape[0]
    bidx = jnp.arange(B)
    slots = pos[:, None] + jnp.arange(T)[None, :]       # deliberately un-modded
    return big.at[:, bidx[:, None], slots].set(pend.astype(big.dtype),
                                               mode="drop")


def _restore_kv(big, old, pos, n_keep):
    """Inverse of a draft loop's writes: rows j >= n_keep[b] are rolled back
    to the saved pre-write values (old: (γ, n_rep, B, kv, hd))."""
    G = old.shape[0]
    S = big.shape[2]
    B = pos.shape[0]
    bidx = jnp.arange(B)
    slots = (pos[:, None] + jnp.arange(G)[None, :]) % S
    cur = big[:, bidx[:, None], slots]
    oldt = jnp.moveaxis(old, 0, 2)                              # (r, B, γ, ...)
    keep = jnp.arange(G)[None, :] < n_keep[:, None]
    mixed = jnp.where(keep[None, :, :, None, None], cur, oldt.astype(big.dtype))
    return big.at[:, bidx[:, None], slots].set(mixed)


def _commit_state(cur, snaps, n_keep):
    """Recurrent state (SSM/conv): select the snapshot after the last kept
    token; n_keep == 0 rows keep ``cur`` (free slots are reset at admission
    anyway)."""
    B = n_keep.shape[0]
    idx = jnp.clip(n_keep - 1, 0, snaps.shape[2] - 1)
    sel = snaps[:, jnp.arange(B), idx]                          # (r, B, ...)
    mask = (n_keep > 0).reshape((1, B) + (1,) * (sel.ndim - 2))
    return jnp.where(mask, sel.astype(cur.dtype), cur)


def commit_cache(cache, pending, pos, n_keep, full_len: int = 0):
    """Apply a verify pass's accepted prefix to the target cache.  ``pending``
    is :func:`repro.models.model.verify_step`'s second output.  Attention
    caches of size ``full_len`` (= the engine's max_seq_len: slot index ==
    position) take the cheap unconditional-write path; windowed rings and
    recurrent state commit exactly at the accept boundary."""
    out = {}
    for stn, stc in cache.items():
        out[stn] = {}
        for bn, bc in stc.items():
            pend = pending[stn][bn]
            if "k" in bc:
                if bc["k"].shape[2] == full_len:
                    out[stn][bn] = {
                        "k": _commit_kv_all(bc["k"], pend["k"], pos),
                        "v": _commit_kv_all(bc["v"], pend["v"], pos),
                    }
                else:
                    out[stn][bn] = {
                        "k": _commit_kv(bc["k"], pend["k"], pos, n_keep),
                        "v": _commit_kv(bc["v"], pend["v"], pos, n_keep),
                    }
            else:
                out[stn][bn] = {
                    "conv": _commit_state(bc["conv"], pend["conv"], n_keep),
                    "ssm": _commit_state(bc["ssm"], pend["ssm"], n_keep),
                }
    return out


def commit_draft_cache(cache, undo, pos, n_keep):
    """Roll the draft cache back to the accept boundary.  ``undo`` is
    :func:`repro.runtime.steps.make_draft_loop`'s fourth output: per-step
    state snapshots for mamba, pre-write K/V rows for windowed attention.
    Attention blocks absent from ``undo`` (full-length caches) keep the
    loop's writes — stale rows there are masked and later overwritten."""
    out = {}
    for stn, stc in cache.items():
        out[stn] = {}
        for bn, bc in stc.items():
            ud = undo.get(stn, {}).get(bn)
            if "k" in bc:
                if ud is None:
                    out[stn][bn] = bc
                else:
                    out[stn][bn] = {
                        "k": _restore_kv(bc["k"], ud["k"], pos, n_keep),
                        "v": _restore_kv(bc["v"], ud["v"], pos, n_keep),
                    }
            else:
                out[stn][bn] = {
                    "conv": _commit_state(
                        bc["conv"], jnp.moveaxis(ud["conv"], 0, 2), n_keep),
                    "ssm": _commit_state(
                        bc["ssm"], jnp.moveaxis(ud["ssm"], 0, 2), n_keep),
                }
    return out


# ---------------------------------------------------------------------------
# paged cache commit / rollback (pool + block-table indirection)
# ---------------------------------------------------------------------------

def _paged_pg_off(table, pos, n_steps, window, page_size, n_tbl):
    """(pg, off, in_ring) for positions pos..pos+n_steps-1 through the block
    table.  Windowed rings wrap (intended); position-linear caches do NOT —
    rows past the table's span come back with ``in_ring=False`` and a
    CLAMPED index that is only safe to read through, never to write (a
    clamped write would collide with the genuine last-position row and the
    scatter winner is implementation-defined — writers must redirect
    ``~in_ring`` rows out of bounds and use ``mode='drop'``, mirroring the
    dense engine's :func:`_commit_kv_all`)."""
    B = pos.shape[0]
    bidx = jnp.arange(B)
    idx = pos[:, None] + jnp.arange(n_steps)[None, :]           # (B, T)
    ring_len = ring_pages(window, n_tbl, page_size) * page_size
    if window:
        ridx = idx % ring_len
        in_ring = jnp.ones_like(idx, bool)
    else:
        in_ring = idx < ring_len
        ridx = jnp.minimum(idx, ring_len - 1)
    pg = table[bidx[:, None], ridx // page_size]
    return pg, ridx % page_size, in_ring


def _commit_kv_paged(pool, pend, pos, n_keep, table, window, page_size,
                     n_tbl):
    """Paged :func:`_commit_kv`: scatter pending rows j < n_keep[b] into the
    slot's pages; rows at or beyond the accept boundary keep the pool's
    pre-round values.  Inactive slots (n_keep == 0, all-zero table rows)
    read-modify-write the trash page — harmless by construction (every
    colliding writer carries the identical gathered value).  Out-of-ring
    rows (a round straddling the last position) are redirected past the
    pool and dropped — a clamped in-bounds write could race the genuine
    last-position row."""
    T = pend.shape[2]
    pg, off, in_ring = _paged_pg_off(table, pos, T, window, page_size, n_tbl)
    old = pool[:, pg, off]                                      # (r, B, T, ...)
    keep = (jnp.arange(T)[None, :] < n_keep[:, None]) & in_ring
    mixed = jnp.where(keep[None, :, :, None, None], pend.astype(pool.dtype),
                      old)
    pg_w = jnp.where(in_ring, pg, pool.shape[1])                # OOB → drop
    return pool.at[:, pg_w, off].set(mixed, mode="drop")


def _commit_kv_paged_quant(pool, sc_pool, pend, pos, n_keep, table, window,
                           page_size, n_tbl):
    """:func:`_commit_kv_paged` for an int8 pool: the accepted fp pending
    rows quantize at the commit (the one shared quantizer — a row gets the
    same codes here as from any other writer) and codes + per-row scales
    land together.  Returns (new_pool, new_sc_pool)."""
    T = pend.shape[2]
    pg, off, in_ring = _paged_pg_off(table, pos, T, window, page_size, n_tbl)
    codes, sc = qkv.quantize_rows(pend)
    old = pool[:, pg, off]
    old_sc = sc_pool[:, pg, off]
    keep = (jnp.arange(T)[None, :] < n_keep[:, None]) & in_ring
    keep = keep[None, :, :, None, None]
    mixed = jnp.where(keep, codes, old)
    mixed_sc = jnp.where(keep, sc.astype(sc_pool.dtype), old_sc)
    pg_w = jnp.where(in_ring, pg, pool.shape[1])                # OOB → drop
    return (pool.at[:, pg_w, off].set(mixed, mode="drop"),
            sc_pool.at[:, pg_w, off].set(mixed_sc, mode="drop"))


def _restore_kv_paged(pool, old, pos, n_keep, table, window, page_size,
                      n_tbl):
    """Paged :func:`_restore_kv`: roll a windowed ring's draft-loop writes at
    rows j >= n_keep[b] back to their saved pre-write values.  Works
    unchanged on int8 code and scale pools — the saved rows restore
    byte-for-byte."""
    G = old.shape[0]
    pg, off, _ = _paged_pg_off(table, pos, G, window, page_size, n_tbl)
    cur = pool[:, pg, off]
    oldt = jnp.moveaxis(old, 0, 2)                              # (r, B, γ, ...)
    keep = jnp.arange(G)[None, :] < n_keep[:, None]
    mixed = jnp.where(keep[None, :, :, None, None], cur, oldt.astype(pool.dtype))
    return pool.at[:, pg, off].set(mixed)


def commit_cache_paged(cache, pending, pos, n_keep, table, windows,
                       page_size, n_tbl):
    """Paged :func:`commit_cache`: pending K/V rows from the verify pass land
    in the slot's PAGES (accepted prefix only, windowed rings at the exact
    accept boundary); recurrent state commits identically to the dense
    path.  ``windows`` is :func:`repro.runtime.steps.attn_window_map` of the
    plan the cache belongs to."""
    out = {}
    for stn, stc in cache.items():
        out[stn] = {}
        for bn, bc in stc.items():
            pend = pending[stn][bn]
            if "k" in bc:
                w = windows[stn][bn]
                if qkv.quant_cache_keys(bc):
                    nk, nks = _commit_kv_paged_quant(
                        bc["k"], bc["k_sc"], pend["k"], pos, n_keep, table,
                        w, page_size, n_tbl)
                    nv, nvs = _commit_kv_paged_quant(
                        bc["v"], bc["v_sc"], pend["v"], pos, n_keep, table,
                        w, page_size, n_tbl)
                    out[stn][bn] = {"k": nk, "v": nv,
                                    "k_sc": nks, "v_sc": nvs}
                else:
                    out[stn][bn] = {
                        "k": _commit_kv_paged(bc["k"], pend["k"], pos, n_keep,
                                              table, w, page_size, n_tbl),
                        "v": _commit_kv_paged(bc["v"], pend["v"], pos, n_keep,
                                              table, w, page_size, n_tbl),
                    }
            else:
                out[stn][bn] = {
                    "conv": _commit_state(bc["conv"], pend["conv"], n_keep),
                    "ssm": _commit_state(bc["ssm"], pend["ssm"], n_keep),
                }
    return out


def commit_draft_cache_paged(cache, undo, pos, n_keep, table, windows,
                             page_size, n_tbl):
    """Paged :func:`commit_draft_cache`: only windowed rings carry undo rows
    (position-linear pooled caches never wrap within a request — stale
    writes are masked and overwritten in order, the same argument as the
    dense full-length fast path)."""
    out = {}
    for stn, stc in cache.items():
        out[stn] = {}
        for bn, bc in stc.items():
            ud = undo.get(stn, {}).get(bn)
            if "k" in bc:
                if ud is None:
                    out[stn][bn] = bc
                else:
                    # the undo snapshot carries every pool leaf the block
                    # holds (codes AND scales for int8 pools)
                    w = windows[stn][bn]
                    out[stn][bn] = {
                        n: _restore_kv_paged(bc[n], ud[n], pos, n_keep,
                                             table, w, page_size, n_tbl)
                        for n in bc}
            else:
                out[stn][bn] = {
                    "conv": _commit_state(
                        bc["conv"], jnp.moveaxis(ud["conv"], 0, 2), n_keep),
                    "ssm": _commit_state(
                        bc["ssm"], jnp.moveaxis(ud["ssm"], 0, 2), n_keep),
                }
    return out


# ---------------------------------------------------------------------------
# one fused draft → verify → accept → commit round
# ---------------------------------------------------------------------------

def _keys(seeds, idx, tag):
    return jax.vmap(lambda s, i: request_key(s, i, tag))(seeds, idx)


def _uniforms(seeds, gen, gamma):
    def one(s, i):
        return jax.random.uniform(request_key(s, i, 2), ())
    si = jnp.repeat(seeds[:, None], gamma, axis=1)
    gi = gen[:, None] + jnp.arange(gamma)[None, :]
    return jax.vmap(jax.vmap(one))(si, gi)


def make_spec_round(plan, draft_plan, gamma: int, *, lora_scale: float = 2.0,
                    draft_lora_scale: float = 2.0, full_len: int = 0,
                    sampling: bool = True, paged: bool = False,
                    page_size: int = 0, n_tbl: int = 0):
    """Build the whole-round function: (params, bank, draft_params,
    draft_bank, cache, draft_cache, st) → (cache, draft_cache, st, info).
    One jit, shape-stable in every argument — compiled exactly once.
    ``full_len`` is the engine's max_seq_len; attention caches of that size
    skip rollback bookkeeping entirely (see :func:`commit_cache`).
    ``sampling=False`` is the all-greedy fast path: no draft distributions,
    no target softmax, no PRNG work — acceptance is pure argmax matching.
    ``paged=True``: both models' caches are page pools sharing ONE block
    table / page-id space (``st.block_table``) — the draft's pool is
    physically smaller because its pruned pages are narrower; accepted
    pending K/V commits into pages, windowed rings roll back exactly."""
    if paged:
        draft_loop = make_paged_draft_loop(draft_plan, gamma, page_size,
                                           n_tbl,
                                           lora_scale=draft_lora_scale,
                                           sampling=sampling)
    else:
        draft_loop = make_draft_loop(draft_plan, gamma,
                                     lora_scale=draft_lora_scale,
                                     full_len=full_len, sampling=sampling)
    verify = make_verify_step(plan, lora_scale=lora_scale, paged=paged)
    windows_t = attn_window_map(plan)
    windows_d = attn_window_map(draft_plan)

    def round_fn(params, bank, dparams, dbank, cache, dcache, st):
        B = st.pos.shape[0]
        bidx = jnp.arange(B)
        pos, gen = st.pos, st.gen_idx
        temps, seeds = st.temps, st.seeds
        act, spec = st.active, st.spec
        temp = jnp.maximum(temps, 1e-6)

        if paged:
            tbl = st.block_table
            dcache, drafts_t, qs_t, undo = draft_loop(
                dparams, dbank, dcache, st.last_tok, pos,
                st.adapter_ids, temps, seeds, gen, tbl)
        else:
            dcache, drafts_t, qs_t, undo = draft_loop(
                dparams, dbank, dcache, st.last_tok, pos,
                st.adapter_ids, temps, seeds, gen)
        drafts = drafts_t.T                              # (B, γ): d_1..d_γ

        # verify block: the already-emitted last token + the first γ-1 drafts;
        # logits[:, i] is the target distribution that judges drafts[:, i]
        u_tok = jnp.concatenate(
            [st.last_tok[:, None], drafts[:, :gamma - 1]], axis=1)
        if paged:
            logits, pending = verify(params, bank, u_tok, cache, pos,
                                     st.adapter_ids, tbl)
        else:
            logits, pending = verify(params, bank, u_tok, cache, pos,
                                     st.adapter_ids)
        tgt_greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        if sampling:
            p = jax.nn.softmax(logits / temp[:, None, None], axis=-1)
            qs = jnp.moveaxis(qs_t, 0, 1)                # (B, γ, V)
            u = _uniforms(seeds, gen, gamma)
            n, m, resid = speculative_accept(
                p, qs, drafts, u, greedy_ok=drafts == tgt_greedy, temps=temps,
                spec=spec)
            # correction token at the first rejected position (unused when
            # n == γ).  Plain slots sample the RAW target logits under the
            # plain engine's exact (seed, gen_idx) key — bit-identical to
            # non-speculative serving.
            corr_logits = jnp.where(spec[:, None], jnp.log(resid + 1e-30),
                                    logits[bidx, m] / temp[:, None])
            key_corr = jnp.where(spec[:, None], _keys(seeds, gen + m, 3),
                                 _keys(seeds, gen, None))
            t_samp = jax.vmap(jax.random.categorical)(
                key_corr, corr_logits).astype(jnp.int32)
            t = jnp.where(temps > 0.0, t_samp, tgt_greedy[bidx, m])
        else:
            acc = (drafts == tgt_greedy) & spec[:, None]
            n = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
            m = jnp.minimum(n, gamma - 1)
            t = tgt_greedy[bidx, m]
        n_keep = jnp.minimum(n + 1, gamma)

        last_new = jnp.where(n >= gamma, drafts[:, gamma - 1], t)
        remaining = st.max_new - gen
        e_eff = jnp.where(act, jnp.minimum(n_keep, remaining), 0)
        keep_c = jnp.where(act, n_keep, 0)

        emit = jnp.where(jnp.arange(gamma)[None, :] < n[:, None], drafts,
                         t[:, None])
        # masked rows are redirected OUT OF BOUNDS and dropped — clamping
        # them to the last column would duplicate a kept row's index in the
        # scatter and the winner is implementation-defined (observed: a
        # request whose final round straddles the buffer end lost its last
        # token to the stale clamped row).  Kept rows never clamp:
        # gen + e_eff <= max_new <= buffer width.
        cols = gen[:, None] + jnp.arange(gamma)[None, :]
        wmask = jnp.arange(gamma)[None, :] < e_eff[:, None]
        cols = jnp.where(wmask, cols, st.out_buf.shape[1])
        out_buf = st.out_buf.at[bidx[:, None], cols].set(emit, mode="drop")

        if paged:
            cache = commit_cache_paged(cache, pending, pos, keep_c, tbl,
                                       windows_t, page_size, n_tbl)
            dcache = commit_draft_cache_paged(dcache, undo, pos, keep_c, tbl,
                                              windows_d, page_size, n_tbl)
        else:
            cache = commit_cache(cache, pending, pos, keep_c, full_len)
            dcache = commit_draft_cache(dcache, undo, pos, keep_c)

        new_st = st.replace(
            last_tok=jnp.where(act, last_new, st.last_tok),
            pos=pos + keep_c,
            gen_idx=gen + e_eff,
            out_buf=out_buf)
        info = {
            "emitted": e_eff,
            # position advance can exceed the emit count in a request's final
            # round (emits are capped at the remaining budget, committed
            # cache rows are not) — the paged engine tracks write positions
            # host-side off this
            "kept": keep_c,
            "accepted": jnp.where(act & spec, n, 0),
            "proposed": jnp.where(act & spec, gamma, 0),
        }
        return cache, dcache, new_st, info

    return round_fn


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _min_attn_ring(plan, max_seq_len: int) -> int:
    """Smallest attention cache ring in the plan (windowed layers reserve
    only ``window`` slots)."""
    sizes = [min(b.window, max_seq_len) if b.window else max_seq_len
             for st in plan.stages for b in st.superblock if b.kind == "attn"]
    return min(sizes, default=max_seq_len)


class SpeculativeServeEngine(ContinuousServeEngine):
    """Continuous-batching engine with a pruned-draft speculative mode.

    Same submit/step/stream surface as :class:`ContinuousServeEngine`; each
    request may opt out via ``submit(..., speculative=False)`` (such requests
    produce bit-identical tokens to the plain engine while sharing slots with
    speculative traffic).  The only device→host sync is the accept counts
    the scheduler needs, read once per BATCH of rounds (see :meth:`step`).
    """

    def __init__(self, plan, params, cfg: ServeConfig,
                 registry: Optional[AdapterRegistry] = None,
                 draft: Optional[DraftModel] = None, *,
                 spec: Optional[SpeculativeConfig] = None,
                 lora_scale: float = 2.0,
                 draft_lora_scale: Optional[float] = None, mesh=None):
        if draft is None:
            raise ValueError("SpeculativeServeEngine requires a DraftModel "
                             "(see repro.serving.draft)")
        spec = spec or SpeculativeConfig.from_serve(cfg)
        super().__init__(plan, params, cfg, registry, lora_scale=lora_scale,
                         mesh=mesh)
        if draft_lora_scale is None:
            draft_lora_scale = lora_scale
        self.draft = draft
        self.spec_cfg = spec
        self.gamma = spec.gamma          # LIVE draft length (γ)
        self._gamma_target = spec.gamma  # autotune target, ignores the ladder
        self._gamma_cap = None           # degradation cap (level 1+)
        # a round touches γ consecutive ring slots per layer; γ larger than
        # the smallest windowed ring would alias slots ((pos+j) % window
        # repeats) and make the commit/rollback scatters silently corrupt it
        ring = min(_min_attn_ring(plan, cfg.max_seq_len),
                   _min_attn_ring(draft.plan, cfg.max_seq_len))
        if spec.gamma > ring:
            raise ValueError(
                f"draft_gamma={spec.gamma} exceeds the smallest attention "
                f"cache ring ({ring}) — a speculative round may not span "
                f"more slots than the shortest sliding window")
        # draft_stage="base": propose with the pruned base only (one draft
        # for every adapter stream); the bank and per-request trees are
        # simply never consulted
        self._draft_base_only = spec.draft_stage == "base"
        if (registry is not None and draft.registry is not None
                and not self._draft_base_only):
            # draft-bank lockstep: the pruned-width bank adopts the TARGET
            # registry's residency manager, so one admission decision
            # assigns/uploads/evicts the same row in both banks and the
            # single bank row a slot carries indexes target and draft alike
            draft.registry.follow(registry)
        self._draft_lora_scale = draft_lora_scale
        S = cfg.max_slots
        if self.paged:
            # the draft shares the target's block table and page-id space —
            # one allocator decision covers both pools.  The draft's pool is
            # physically smaller anyway: its pruned pages are narrower.
            self.draft_cache = init_paged_cache(
                draft.plan, S, self.pages.n_pages, self._page,
                jnp.dtype(cfg.kv_cache_dtype),
                quant_kv=cfg.quant.kv == "int8")
            # the draft loop writes through the SAME block table — its ring
            # patterns join the pre-write COW sweep, and a forked page id
            # must be cloned in the draft's pools too
            wmap_d = attn_window_map(draft.plan)
            self._write_windows = sorted(
                set(self._write_windows)
                | {w for stw in wmap_d.values() for w in stw.values()})
            if self._sharing:
                self._copy_page_fn_d = make_copy_page(draft.plan)
            if self._sharing or self._chunking:
                self._cap_fn_d, self._res_fn_d = make_state_ops(draft.plan)
            else:
                self._cap_fn_d = self._res_fn_d = None
            self._chunk_pair_steps: Dict[int, Any] = {}
        else:
            self.draft_cache = init_cache(draft.plan, S, cfg.max_seq_len,
                                          jnp.dtype(cfg.kv_cache_dtype))
        if self.mesh is not None:
            # the draft runs on the SAME mesh: its pruned widths re-run the
            # shape-driven divisibility checks inside param_specs /
            # serve_cache_specs — any non-divisible axis simply replicates
            dparams, dcache = sharding.shard_serving(
                self.mesh, draft.params, self.draft_cache, paged=self.paged)
            self.draft = draft.with_params(dparams)
            self.draft_cache = dcache
        # each distinct γ compiles its own round pair; the autotuner walks
        # through a handful of values and then settles
        self._rounds = {}
        self._round_greedy, self._round_sample = self._get_rounds(spec.gamma)
        self._gamma_ctl = None
        if cfg.gamma_autotune:
            self._gamma_ctl = GammaController(gamma_max=min(8, max(ring, 1)))

        # one dispatch per admission: target + draft prefill fused (a separate
        # draft prefill call would double the admission dispatch cost, which
        # dominates short-generation workloads)
        if self.paged:
            self._prefill_pair_steps = {}     # bucket → fused paged pair
        else:
            bucketed = cfg.prefill_buckets
            tgt_prefill = make_prefill_into_slot(plan, lora_scale=lora_scale,
                                                 bucketed=bucketed)
            dft_prefill = make_prefill_into_slot(draft.plan,
                                                 lora_scale=draft_lora_scale,
                                                 bucketed=bucketed)

            if bucketed:
                def prefill_both(params, tree, dparams, dtree, tokens, cache,
                                 dcache, slot, valid_len):
                    logits, cache = tgt_prefill(params, tree, tokens, cache,
                                                slot, valid_len)
                    _, dcache = dft_prefill(dparams, dtree, tokens, dcache,
                                            slot, valid_len)
                    return logits, cache, dcache
            else:
                def prefill_both(params, tree, dparams, dtree, tokens, cache,
                                 dcache, slot):
                    logits, cache = tgt_prefill(params, tree, tokens, cache,
                                                slot)
                    _, dcache = dft_prefill(dparams, dtree, tokens, dcache,
                                            slot)
                    return logits, cache, dcache

            self._prefill_both = jax.jit(prefill_both, donate_argnums=(5, 6))

        # admission reuses the base engine's jitted
        # repro.runtime.steps.admit_update verbatim: the TickState built by
        # _init_tick_state carries spec/max_new leaves, so the shared trace
        # updates them too — no speculative admission closure exists anymore
        # speculation telemetry (the registry itself was built by the base
        # constructor's _init_obs with engine="speculative")
        m = self.metrics
        self._c_rounds = m.counter(
            "spec_rounds_total", "draft→verify→commit rounds",
            unit="rounds").labels()
        self._c_proposed = m.counter(
            "spec_tokens_proposed_total", "draft tokens proposed",
            unit="tokens").labels()
        self._c_accepted = m.counter(
            "spec_tokens_accepted_total", "draft tokens the target accepted",
            unit="tokens").labels()
        m.gauge("spec_gamma", "current draft length γ",
                unit="tokens").labels().set_fn(lambda: self.gamma)
        m.gauge("spec_acceptance_ema",
                "GammaController EMA acceptance (lifetime accepted/proposed "
                "when autotune is off)", unit="ratio").labels().set_fn(
            lambda: (self._gamma_ctl.acceptance
                     if self._gamma_ctl is not None
                     else self.acceptance_rate))

    _obs_engine = "speculative"       # registry constant label value

    # legacy speculation counters, registry-backed like the base engine's
    n_rounds = _counter_property(
        "_c_rounds", "draft→verify→commit rounds")
    n_proposed = _counter_property(
        "_c_proposed", "draft tokens proposed")
    n_accepted = _counter_property(
        "_c_accepted", "draft tokens the target accepted")

    def _hbm_components(self):
        comps = super()._hbm_components()
        comps["weights"].append(self.draft.params)
        comps["kv_cache"].append(self.draft_cache)
        if not self._draft_base_only:
            comps.setdefault("adapter_bank", []).append(self.draft.bank)
        return comps

    def _init_tick_state(self, S, cfg):
        """The speculative leaves (per-request opt-in + γ-round emit budget)
        join the ONE tick state the base constructor places."""
        return TickState.zeros(S, cfg.max_new_tokens,
                               n_tbl=self._n_tbl if self.paged else 0,
                               speculative=True)

    def _get_rounds(self, gamma: int):
        """(greedy, sampled) jitted round fns for ``gamma`` — built once per
        distinct γ.  All-greedy traffic skips draft distributions / softmax /
        PRNG work entirely, same split as the plain engine's ticks."""
        pair = self._rounds.get(gamma)
        if pair is None:
            pair = tuple(
                jax.jit(make_spec_round(self.plan, self.draft.plan, gamma,
                                        lora_scale=self._lora_scale,
                                        draft_lora_scale=self._draft_lora_scale,
                                        full_len=self.cfg.max_seq_len,
                                        sampling=sampling, paged=self.paged,
                                        page_size=self._page,
                                        n_tbl=self._n_tbl),
                        donate_argnums=(4, 5, 6))
                for sampling in (False, True))
            self._rounds[gamma] = pair
        return pair

    def _prefill_pair_step(self, bucket: int):
        step = self._prefill_pair_steps.get(bucket)
        if step is None:
            tgt = make_paged_prefill_into_slot(
                self.plan, bucket, self._page, self._n_tbl,
                lora_scale=self._lora_scale)
            dft = make_paged_prefill_into_slot(
                self.draft.plan, bucket, self._page, self._n_tbl,
                lora_scale=self._draft_lora_scale)

            def both(params, tree, dparams, dtree, tokens, cache, dcache,
                     pids, slot, valid_len):
                logits, cache = tgt(params, tree, tokens, cache, pids, slot,
                                    valid_len)
                _, dcache = dft(dparams, dtree, tokens, dcache, pids, slot,
                                valid_len)
                return logits, cache, dcache

            step = jax.jit(both, donate_argnums=(5, 6))
            self._prefill_pair_steps[bucket] = step
        return step

    def _chunk_pair_step(self, chunk_len: int):
        """Fused target + draft chunk prefill (one dispatch per chunk, same
        economics as the fused admission prefill)."""
        step = self._chunk_pair_steps.get(chunk_len)
        if step is None:
            tgt = make_paged_prefill_chunk(self.plan, chunk_len, self._page,
                                           self._n_tbl,
                                           lora_scale=self._lora_scale)
            dft = make_paged_prefill_chunk(self.draft.plan, chunk_len,
                                           self._page, self._n_tbl,
                                           lora_scale=self._draft_lora_scale)

            def both(params, tree, dparams, dtree, tokens, cache, dcache,
                     state_t, state_d, row, pos0, valid):
                logits, cache, ns_t = tgt(params, tree, tokens, cache,
                                          state_t, row, pos0, valid)
                _, dcache, ns_d = dft(dparams, dtree, tokens, dcache,
                                      state_d, row, pos0, valid)
                return logits, cache, dcache, ns_t, ns_d

            step = jax.jit(both, donate_argnums=(5, 6))
            self._chunk_pair_steps[chunk_len] = step
        return step

    # -- chunked prefill / prefix sharing hooks (draft cache rides along) ----

    def _init_chunk_state(self):
        zt = super()._init_chunk_state()
        zd = None
        if self._cap_fn_d is not None:
            if getattr(self, "_zero_state_d", None) is None:
                self._zero_state_d = jax.tree.map(
                    jnp.zeros_like, self._cap_fn_d(self.draft_cache, 0))
            zd = self._zero_state_d
        if zt is None and zd is None:
            return None
        return {"t": zt, "d": zd}

    def _chunk_dispatch(self, req, slot, tokens, row, pos0, valid, state):
        tree = (None if self.registry is None
                else self.registry.adapter_tree(req.adapter_id))
        dtree = (None if self._draft_base_only
                 else self.draft.adapter_tree(req.adapter_id))
        state = state or {"t": None, "d": None}
        step = self._chunk_pair_step(tokens.shape[1])
        logits, self.cache, self.draft_cache, ns_t, ns_d = step(
            self.params, tree, self.draft.params, dtree, tokens, self.cache,
            self.draft_cache, state["t"] or {}, state["d"] or {},
            row, pos0, valid)
        if not ns_t and not ns_d:
            return logits, None
        return logits, {"t": ns_t or None, "d": ns_d or None}

    def _state_restore(self, slot, state):
        if state is None:
            return
        if state["t"] is not None:
            self.cache = self._res_fn(self.cache, state["t"], slot)
        if state["d"] is not None:
            self.draft_cache = self._res_fn_d(self.draft_cache, state["d"],
                                              slot)

    def _copy_page(self, src, dst):
        self.cache = self._copy_page_fn(self.cache, jnp.int32(src),
                                        jnp.int32(dst))
        self.draft_cache = self._copy_page_fn_d(self.draft_cache,
                                                jnp.int32(src),
                                                jnp.int32(dst))

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals the target accepted (speculative
        traffic only)."""
        return self.n_accepted / max(self.n_proposed, 1)

    def register_adapter(self, name: str, lora, *,
                         draft_lora=None) -> int:
        """Hot-register into the running engine: the recovered full-rank
        tree into the target bank and (optionally) its pruned-width twin
        into the draft bank — SAME id, same bank row, committed in
        lockstep by the shared residency manager.  Omitting ``draft_lora``
        leaves the draft row zeroed for this adapter (the draft proposes
        from its pruned base; verification still guarantees the target
        distribution)."""
        if self.registry is None:
            raise ValueError(
                "engine was built without an adapter registry — construct "
                "it with registry=AdapterRegistry(template, ...)")
        aid = self.registry.add(name, lora)
        if draft_lora is not None:
            if self.draft.registry is None:
                raise ValueError(
                    "draft_lora given but the DraftModel has no adapter "
                    "bank (build_draft(..., adapter_template=, "
                    "max_adapters=))")
            did = self.draft.add(name, draft_lora)
            if did != aid:
                raise AdapterError(
                    f"draft/target adapter ids diverged ({did} != {aid}) — "
                    f"register every adapter through register_adapter() or "
                    f"in the same order on both banks")
        return aid

    # -- internals ----------------------------------------------------------

    def _admit(self, slot: int, req):
        tree = (None if self.registry is None
                else self.registry.adapter_tree(req.adapter_id))
        dtree = (None if self._draft_base_only
                 else self.draft.adapter_tree(req.adapter_id))
        if self.paged:
            tokens, valid = self._bucketed_prompt(req)
            sb = tokens.shape[1]
            ids = self.pages.alloc(slot, pages_for(sb, self._page))
            self._set_table_row(slot, ids)
            self._slot_pos[slot] = valid
            self._admit_seq[slot] = self._next_seq()
            step = self._prefill_pair_step(sb)
            logits, self.cache, self.draft_cache = step(
                self.params, tree, self.draft.params, dtree, tokens,
                self.cache, self.draft_cache, jnp.asarray(ids, jnp.int32),
                slot, valid)
        elif self.cfg.prefill_buckets:
            tokens, valid = self._bucketed_prompt(req)
            logits, self.cache, self.draft_cache = self._prefill_both(
                self.params, tree, self.draft.params, dtree, tokens,
                self.cache, self.draft_cache, slot, valid)
        else:
            tokens = jnp.asarray(req.prompt[None])
            logits, self.cache, self.draft_cache = self._prefill_both(
                self.params, tree, self.draft.params, dtree, tokens,
                self.cache, self.draft_cache, slot)
        first = self._first_token(logits[0], req)
        self._activate(slot, req, first)
        self._c_prefill_tokens.inc(len(req.prompt))
        self._stamp_first_token(req)

    def step(self) -> List[RequestResult]:
        """Admit whatever fits, run a batch of draft→verify→commit rounds,
        return newly completed requests.  Each round advances every active
        slot by 1..γ tokens (accepted drafts + correction).  The
        resilience preamble mirrors the base engine's exactly."""
        done: List[RequestResult] = []
        if self._pending_results:
            done.extend(self._pending_results)
            self._pending_results.clear()
        if self._want_restart:
            self._self_restart()
        ctx = (sharding.use_mesh(self.mesh, head_shard=True)
               if self.mesh is not None else _null())
        progressive = self.paged and (self._chunking or self._sharing)
        with ctx:
            if self._resil.enabled:
                done.extend(self._enforce_deadlines())
                done.extend(self._break_admission_stall())
            if self._degrade_ctl is not None:
                self._degrade_tick()
            if self.paged:
                # grow existing slots one round's worth before admitting, so
                # a fresh admission isn't the first preemption victim of its
                # own step (wasting the fused target+draft prefill)
                self._ensure_growth(lookahead=self.gamma)
            with self.tracer.span("admit"):
                self._admit_pass(done, progressive)
            if progressive:
                # one bounded prefill chunk per streaming slot between
                # speculative rounds — rounds never stall behind a prompt
                with self.tracer.span("chunk"):
                    self._prefill_tick()
            for slot in self._sched.completed_slots():
                done.append(self._finalize(slot))
            active = self._sched.active_slots()
            if active:
                bank = None if self.registry is None else self.registry.bank
                # Acceptance is only knowable on device, but a round advances
                # each slot by AT MOST γ tokens — so while every active slot
                # has more than γ·(k-1) tokens left, k rounds can be queued
                # back-to-back with ONE host sync at the end.  This restores
                # the dispatch pipelining the plain engine gets from its
                # host-side token counting.
                min_rem = min(self._sched.slot_steps_left(s) for s in active)
                k = max(1, -(-min_rem // self.gamma))
                if self.paged:
                    # every committed row of the k-round batch needs a real
                    # page behind it BEFORE the batch runs (acceptance is
                    # unknowable on host, so back the worst case k·γ,
                    # capped per slot at its final length)
                    self._ensure_growth(lookahead=k * self.gamma)
                    active = self._sched.active_slots()
                if self._sharing:
                    # verify commits and draft-loop writes must never land
                    # on a shared page — fork every shared entry the batch's
                    # worst-case k·γ positions (incl. windowed rings) touch
                    with self.tracer.span("cow"):
                        for slot in list(active):
                            if self._sched.slot_request(slot) is not None:
                                self._cow_range(
                                    slot, self._slot_pos[slot],
                                    self._slot_pos[slot] + k * self.gamma)
                    active = self._sched.active_slots()
                if not active:
                    return done
                rnd = (self._round_sample if self._n_hot
                       else self._round_greedy)
                dbank = None if self._draft_base_only else self.draft.bank
                infos = []
                if self._watchdog is not None:
                    self._watchdog.start()
                if not self._pre_dispatch_guard():
                    # retry budget exhausted — the whole k-round batch is
                    # skipped (no accounting either); a restart runs at
                    # the top of the next step
                    return done
                with self.tracer.span("round"):
                    for _ in range(k):
                        self.cache, self.draft_cache, self._st, info = rnd(
                            self.params, bank, self.draft.params, dbank,
                            self.cache, self.draft_cache, self._st)
                        infos.append(info)
                if self._watchdog is not None:
                    self._watchdog.stop(self._n_ticks)
                self._n_ticks += k
                self._c_ticks.inc(k)
                self._c_rounds.inc(k)
                if self._sched.prefilling_slots():
                    self._c_ticks_during_prefill.inc(k)
                batch_accepted = batch_proposed = 0
                for info in jax.device_get(infos):
                    batch_proposed += int(info["proposed"].sum())
                    batch_accepted += int(info["accepted"].sum())
                    for slot in active:
                        if self.paged:
                            self._slot_pos[slot] += int(info["kept"][slot])
                        if (self._sched.slot_request(slot) is not None
                                and self._sched.advance(
                                    slot, int(info["emitted"][slot]))):
                            done.append(self._finalize(slot))
                self._c_proposed.inc(batch_proposed)
                self._c_accepted.inc(batch_accepted)
                if self._gamma_ctl is not None:
                    self._gamma_ctl.update(batch_accepted, batch_proposed)
                    # the autotuner steers the UNCAPPED target; the ladder
                    # cap is applied on top, so recovery from degradation
                    # resumes exactly where the tuner left off
                    self._gamma_target = self._gamma_ctl.propose(
                        self._gamma_target)
                    self._refresh_gamma()
        return done

    # -- graceful degradation (γ rungs) --------------------------------------

    def _apply_degradation(self, level: int) -> None:
        """Ladder level 1+ halves the draft length (floor 1); the live γ
        is min(autotune target, cap) and both directions re-apply
        immediately."""
        super()._apply_degradation(level)
        self._gamma_cap = (max(1, self.spec_cfg.gamma // 2)
                           if level >= DEGRADE_SHRINK_GAMMA else None)
        self._refresh_gamma()

    def _refresh_gamma(self) -> None:
        eff = self._gamma_target
        if self._gamma_cap is not None:
            eff = min(eff, self._gamma_cap)
        if eff != self.gamma:
            self.gamma = eff
            self._round_greedy, self._round_sample = self._get_rounds(eff)

"""Serving resilience: degradation ladder + engine snapshot/restore.

Policy knobs live in :class:`repro.configs.ResilienceConfig` (a frozen
sub-dataclass of ``ServeConfig``, mirroring ``QuantPolicy``); this module
holds the host-side machinery the engines thread it through:

* the request status taxonomy (``STATUSES``) — every submitted request
  terminates with exactly one of these in ``RequestResult.status``;
* :class:`DegradationController` — a debounced hysteresis controller
  mapping a scalar pressure signal (max of normalized queue depth,
  page-pool occupancy and recent watchdog stalls) onto the ladder
  level 0 (healthy) → 5 (shed load).  The controller is pure host
  state; the *actions* per level live in the engines
  (``_apply_degradation``);
* :func:`engine_snapshot` / :func:`engine_restore` — serialize the
  scheduler (queue + in-flight requests in admission order), the host
  allocator geometry, and the host mirror of ``TickState`` progress to
  a JSON-compatible dict.  Restore re-queues every in-flight request
  into a fresh (or reset) engine; because sampling depends only on
  ``(request seed, generation index)`` — the same invariant preemption
  relies on — the restored run completes every request token-identical
  to an uninterrupted run.  Submit/first-token stamps and absolute
  deadlines are preserved so restored results report true TTFT.

Everything here is strictly host-side: with the default (disabled)
policy the engines are bit-identical to a build without this module,
and ``TickState`` gains no leaves.
"""
from __future__ import annotations

import numpy as np

# Terminal status taxonomy for RequestResult.status.
STATUS_OK = "ok"                # completed normally
STATUS_TIMEOUT = "timeout"      # TTFT or end-to-end deadline expired
STATUS_SHED = "shed"            # dropped by admission control / load shedding
STATUS_CANCELLED = "cancelled"  # engine.cancel(uid)
STATUS_FAILED = "failed"        # impossible admission or injected failure
STATUSES = (STATUS_OK, STATUS_TIMEOUT, STATUS_SHED, STATUS_CANCELLED,
            STATUS_FAILED)

# status → lifecycle event kind emitted at the terminal choke point
# (obs.events.EVENT_KINDS and snapshot.schema.json carry the same names).
TERMINAL_EVENT = {
    STATUS_OK: "complete",
    STATUS_TIMEOUT: "timeout",
    STATUS_SHED: "shed",
    STATUS_CANCELLED: "cancel",
    STATUS_FAILED: "failed",
}

# Degradation ladder levels (actions applied cumulatively).
DEGRADE_HEALTHY = 0
DEGRADE_SHRINK_GAMMA = 1     # halve the speculative draft length
DEGRADE_NO_SPEC = 2          # admit new requests non-speculatively
DEGRADE_DROP_PREFIXES = 3    # proactively evict idle shared-prefix entries
DEGRADE_SHRINK_CHUNK = 4     # halve the prefill chunk (page-aligned)
DEGRADE_SHED = 5             # shed queued load on submit
DEGRADE_MAX = DEGRADE_SHED


class DegradationController:
    """Hysteresis ladder over a scalar pressure signal in [0, 1].

    ``observe(pressure)`` is called once per engine step.  The level
    steps UP one rung after ``up_ticks`` consecutive observations above
    ``high`` and DOWN one rung after ``down_ticks`` consecutive
    observations below ``low`` — the dead band between the thresholds
    plus the debounce keeps the ladder from flapping on noisy signals.
    ``force_up()`` (watchdog escalation) bumps the level immediately.
    """

    def __init__(self, *, high: float = 0.85, low: float = 0.50,
                 up_ticks: int = 2, down_ticks: int = 8,
                 max_level: int = DEGRADE_MAX):
        assert 0.0 < low <= high
        self.high, self.low = high, low
        self.up_ticks, self.down_ticks = max(1, up_ticks), max(1, down_ticks)
        self.max_level = max_level
        self.level = DEGRADE_HEALTHY
        self.peak_level = DEGRADE_HEALTHY
        self._above = 0
        self._below = 0

    def observe(self, pressure: float) -> int:
        if pressure > self.high:
            self._above += 1
            self._below = 0
            if self._above >= self.up_ticks and self.level < self.max_level:
                self.level += 1
                self._above = 0
        elif pressure < self.low:
            self._below += 1
            self._above = 0
            if self._below >= self.down_ticks and self.level > 0:
                self.level -= 1
                self._below = 0
        else:  # dead band — hold, reset both debounce counters
            self._above = self._below = 0
        self.peak_level = max(self.peak_level, self.level)
        return self.level

    def force_up(self, n: int = 1) -> int:
        """Immediate escalation (watchdog stall ladder)."""
        self.level = min(self.max_level, self.level + n)
        self.peak_level = max(self.peak_level, self.level)
        self._above = self._below = 0
        return self.level


# ---------------------------------------------------------------------------
# Engine snapshot / restore
# ---------------------------------------------------------------------------

SNAPSHOT_VERSION = 1


def serialize_request(req) -> dict:
    """Request → JSON-compatible dict (prompt devolves to a list of ints)."""
    return {
        "uid": int(req.uid),
        "prompt": [int(t) for t in np.asarray(req.prompt).tolist()],
        "max_new_tokens": int(req.max_new_tokens),
        "adapter": req.adapter,
        "adapter_id": int(req.adapter_id),
        "temperature": float(req.temperature),
        "seed": int(req.seed),
        "speculative": bool(req.speculative),
        "prefix_id": req.prefix_id,
        "prefix_len": int(req.prefix_len),
    }


def engine_snapshot(eng) -> dict:
    """Serialize everything a restarted engine needs to finish the work.

    Captured: the scheduler's queue and in-flight slots (requests in
    deterministic re-queue order — in-flight by admission order first,
    then the queue FCFS), the next uid watermark, per-request
    submit/first-token stamps and absolute deadlines, the host
    allocator's geometry + live state (diagnostic: restore rebuilds a
    clean pool, since re-queued requests re-prefill), and the host
    mirror of TickState progress (slot positions).  Completed results
    already returned to the caller are not the snapshot's problem.
    """
    sched = eng._sched
    # in-flight first, ordered by admission sequence (paged engines track
    # _admit_seq; dense engines fall back to slot order — their in-flight
    # requests are independent, so any stable order preserves tokens)
    occupied = sched.occupied_slots()
    seq = getattr(eng, "_admit_seq", None)   # list, paged engines only
    if seq is not None:
        occupied = sorted(occupied, key=lambda s: seq[s])
    inflight = [sched.slot_request(s) for s in occupied]
    queued = list(sched.queued_requests())
    reqs = [r for r in inflight + queued if r is not None]
    stamps = {}
    for r in reqs:
        u = r.uid
        stamps[str(u)] = {
            "t_submit": eng._t_submit.get(u),
            "t_first": eng._t_first.get(u),
            "deadline": eng._deadline_abs.get(u),
            "ttft_deadline": eng._ttft_deadline_abs.get(u),
        }
    snap = {
        "version": SNAPSHOT_VERSION,
        "engine": getattr(eng, "_obs_engine", "continuous"),
        "max_slots": sched.max_slots,
        "uid_next": sched.uid_watermark,
        "requests": [serialize_request(r) for r in reqs],
        "stamps": stamps,
        "tick_mirror": {  # host mirror of TickState progress (diagnostic)
            "slot_pos": {str(s): int(p)
                         for s, p in enumerate(getattr(eng, "_slot_pos",
                                                       ()))},
            "generated": {str(s): int(sched.slot_generated(s))
                          for s in sched.occupied_slots()},
        },
    }
    pages = getattr(eng, "pages", None)
    if pages is not None:
        snap["allocator"] = pages.state()
    registry = getattr(eng, "registry", None)
    if registry is not None:
        # diagnostic, like the page pool: restore rebuilds residency
        # lazily — re-queued requests re-resolve by NAME and the admission
        # gate re-streams any adapter the (possibly fresh) bank lost, so
        # the row assignments need not survive the restart
        snap["adapters"] = registry.residency.state()
    return snap


def engine_restore(eng, snap: dict) -> int:
    """Re-queue a snapshot's requests into ``eng``; returns the count.

    ``eng`` must be freshly constructed (or reset via
    ``eng._reset_runtime_state()``) with the same ``max_slots``; the
    requests re-run from their prompts, which by the determinism
    invariant reproduces their token streams exactly.  Adapter ids are
    re-resolved by name against the engine's registry (the bank may
    have been rebuilt in a new process).
    """
    assert snap.get("version") == SNAPSHOT_VERSION, snap.get("version")
    sched = eng._sched
    assert snap["max_slots"] == sched.max_slots, \
        (snap["max_slots"], sched.max_slots)
    assert not sched.has_work, "restore target must be idle"
    pool = snap.get("allocator")
    if pool is not None and getattr(eng, "pages", None) is not None:
        assert pool["n_pages"] == eng.pages.n_pages, \
            (pool["n_pages"], eng.pages.n_pages)
    sched.set_uid_floor(snap["uid_next"])
    n = 0
    for rd in snap["requests"]:
        stamps = snap["stamps"].get(str(rd["uid"]), {})
        eng._resubmit(rd, stamps)
        n += 1
    eng._note_restore(n)
    return n

"""Serving engines — the "infer large" half of LoRAM.

Serves the ORIGINAL (large) model with recovered adapters, either merged
(paper default, Eq. 7: W₀ + Bᴿ*Aᴿ*) or unmerged (multi-adapter serving: one
base, several LoRAM-trained adapters).

Two engines:

* :class:`ServeEngine` — the synchronous single-batch reference path: one
  prefill for the whole batch, then a lock-step decode loop.  Every request
  in the batch shares one adapter and one prompt length.

* :class:`ContinuousServeEngine` — continuous batching over a fixed slot
  table (``ServeConfig.max_slots``): requests are admitted into free slots
  the moment one opens (per-slot prefill insertion), every decode tick
  advances all active slots at their own positions, and each slot routes
  through its own adapter via the stacked bank
  (:class:`repro.serving.adapters.AdapterRegistry`).  The jitted one-token
  decode step has a fixed shape — slot count, cache, id/pos vectors — so XLA
  compiles it exactly once and never recompiles mid-flight; free slots decode
  masked garbage that nothing reads.  Generated tokens accumulate on device
  and transfer to the host once per request, at eviction.

Cache layouts (``ServeConfig.kv_paging``):

* dense (default): every slot reserves a ``max_seq_len`` K/V buffer per
  attention layer — HBM scales with ``max_slots × max_seq_len`` no matter
  how short the traffic actually is.
* paged: attention K/V lives in a global pool of fixed-size pages indexed
  through a per-slot block table (part of the jitted tick state — shapes
  still never change).  Admission is gated on free PAGES, decode growth
  allocates a page per crossed boundary, pool exhaustion preempts the
  newest slot (requeued at the queue head — deterministic generation makes
  the re-run emit identical tokens), and eviction returns pages to the free
  list.  See ``repro.serving.pages``.  SSM/conv state stays dense (O(1) per
  slot).

Prompts are padded to power-of-two buckets (``ServeConfig.prefill_buckets``)
so prefill compiles O(log max_seq_len) variants instead of one per distinct
prompt length; masked cache writes, frozen recurrent state and lossless MoE
routing past the real length keep bucketed output exactly equal to unpadded
(see :func:`repro.models.model.prefill`).

Two paged-only optimizations (PR 4):

* chunked prefill (``ServeConfig.prefill_chunk``): long prompts stream into
  their slot one fixed page-aligned chunk per engine step, interleaved with
  decode ticks — in-flight traffic never stalls behind a monolithic prefill
  dispatch.  Chunk attention reads the slot's committed pages through the
  block table (:func:`repro.kernels.ops.paged_chunk_attention`); recurrent
  state streams outside the cache until activation (the tick garbage-
  advances every slot's dense rows).
* copy-on-write prefix sharing (``ServeConfig.prefix_sharing``):
  ``submit(prefix_id=..., prefix_len=...)`` prefills a shared prompt head
  once per (prefix_id, adapter) and maps its refcounted pages read-only
  into every later sharer's block table; a host-side COW sweep forks any
  shared page a write would touch (the partially-filled boundary page, and
  windowed rings wrapping onto prefix pages), so output stays
  token-identical to unshared serving while prefill FLOPs and KV pages
  scale with the UNIQUE tokens only.

Tick state and mesh sharding (PR 6)
-----------------------------------

Every jitted serving step threads ONE explicit pytree of device state:
:class:`repro.serving.tickstate.TickState` (it replaced the untyped
``dict(st)`` that used to be copied in three places here and in
``speculative.py``).  Engines accept a ``jax.sharding.Mesh`` (or build one
from ``ServeConfig.mesh_data`` × ``ServeConfig.mesh_model``, see
``launch/serve.py --mesh``); with a mesh the tick runs under GSPMD with this
placement, declared leaf-by-leaf in ``TickState.field_specs()`` and
``sharding.serve_cache_specs`` / ``sharding.param_specs``:

====================  =========================  ===========================
device state          axes                       placement
====================  =========================  ===========================
TickState.last_tok    (S,)                       replicated
TickState.pos         (S,)                       replicated
TickState.active      (S,)                       replicated
TickState.adapter_ids (S,)                       replicated
TickState.temps       (S,)                       replicated
TickState.seeds       (S,)                       replicated
TickState.gen_idx     (S,)                       replicated
TickState.out_buf     (S, max_new)               replicated
TickState.block_table (S, n_tbl)                 replicated
TickState.spec        (S,)                       replicated
TickState.max_new     (S,)                       replicated
dense KV cache        (r, S, seq, K, hd)         S → data, K (else hd) → model
paged K/V pools       (r, n_pages, page, K, hd)  K (else hd) → model; pages
                                                 REPLICATED over data (page
                                                 ids are one global
                                                 namespace — the host
                                                 allocator stays
                                                 device-count-agnostic)
SSM / conv state      (r, S, ...)                replicated (O(1) per slot)
weights               per param_specs            tensor/expert-parallel over
                                                 model, replicated over data
adapter bank          stacked (A, ...)           replicated (rank-r factors
                                                 are tiny; arXiv:2106.09685)
activations           (B, S, D) / (B, S, H, hd)  B → data; heads → model
                                                 (head_shard scope flag)
====================  =========================  ===========================

Every TickState leaf is REPLICATED by design: it is the scheduler's device
mirror (slot occupancy, positions, sampling streams, block-table rows) and
each shard needs all of it to mask its portion of the batched decode.  What
shards is what the state INDEXES INTO — pools, caches, weights.  The host
side (Scheduler, PageAllocator, COW sweep, prefix registry) never sees the
mesh: admission, preemption, COW, and prefix sharing are device-count-
agnostic, and ``tests/test_mesh_serving.py`` pins sharded output
token-identical to single-device across model families.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterator, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.core.recovery import merge_lora
from repro.distributed import sharding
from repro.models.model import (Plan, init_cache, init_paged_cache,
                                ring_pages)
from repro.obs.events import EventLog
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TickTracer
from repro.quant import nf4
from repro.runtime.steps import (admit_update, attn_window_map,
                                 make_copy_page, make_decode_step,
                                 make_multi_adapter_decode_step,
                                 make_paged_prefill_chunk,
                                 make_paged_prefill_into_slot,
                                 make_prefill_into_slot, make_prefill_step,
                                 make_state_ops, request_key)
from repro.runtime.watchdog import StepWatchdog, StragglerAlarm
from repro.serving.adapters import BASE_ADAPTER, AdapterRegistry
from repro.serving.resilience import (DEGRADE_DROP_PREFIXES, DEGRADE_NO_SPEC,
                                      DEGRADE_SHED, DEGRADE_SHRINK_CHUNK,
                                      STATUS_CANCELLED, STATUS_FAILED,
                                      STATUS_OK, STATUS_SHED, STATUS_TIMEOUT,
                                      TERMINAL_EVENT, DegradationController,
                                      engine_restore, engine_snapshot)
from repro.serving.pages import (PageAllocator, PoolExhausted, bucket_len,
                                 pages_for)
from repro.serving.scheduler import Request, RequestResult, Scheduler
from repro.serving.tickstate import TickState
from repro.testing.faults import TransientFault


def _counter_property(child: str, doc: str) -> property:
    """Legacy counter accessor: ``eng.n_x`` reads the registry child,
    ``eng.n_x = 0`` is the benchmark warm-up reset hook (Counter.set)."""

    def fget(self):
        return int(getattr(self, child).value())

    def fset(self, value):
        getattr(self, child).set(value)

    return property(fget, fset, doc=doc)


def _resolve_mesh(cfg: ServeConfig, mesh):
    """The engine's mesh: an explicit one wins; otherwise build a
    ``data × model`` host mesh from the config axes (1×1 → no mesh at all —
    the entire sharding path compiles away)."""
    if mesh is not None:
        return mesh
    if cfg.mesh_data * cfg.mesh_model > 1:
        from repro.launch.mesh import make_serve_mesh
        return make_serve_mesh(cfg.mesh_data, cfg.mesh_model)
    return None


@dataclasses.dataclass
class PrefixEntry:
    """A cached shared prefix: the pages holding its K/V (refcount-retained
    so they survive every sharer's eviction), the recurrent-state snapshot
    at its boundary, and how many live slots currently map it.

    Entries are keyed by ``(prefix_id, adapter_id)``: the prefix K/V runs
    through the slot's LoRA adapter (wk/wv deltas), so one system prompt
    served under two adapters is two distinct caches — exactly the
    "system-prompt + adapter template" unit the multi-adapter pattern
    shares."""

    tokens: np.ndarray            # (n_tokens,) int32 — for submit validation
    n_tokens: int
    pages: list                   # pool page ids covering positions [0, n)
    state: Any = None             # dense SSM/conv rows at the boundary
    active: int = 0               # slots currently mapping the prefix


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_generated)
    prefill_s: float
    decode_s: float
    tokens_per_s: float           # end-to-end: all generated tokens / total time
    prefill_tokens_per_s: float   # prompt tokens through prefill
    decode_tokens_per_s: float    # decode-loop tokens over the decode window only


class ServeEngine:
    """Synchronous single-batch engine (the pre-scheduler reference path)."""

    def __init__(self, plan: Plan, params: Any, cfg: ServeConfig,
                 lora: Optional[Any] = None, *, lora_scale: float = 2.0,
                 mesh=None):
        self.plan = plan
        self.cfg = cfg
        self.mesh = _resolve_mesh(cfg, mesh)
        if lora is not None and cfg.merge_adapters:
            params = merge_lora(params, lora, lora_scale)
            lora = None
        if self.mesh is not None:
            sharding.install_residual_constraint()
            params = jax.device_put(params, sharding.to_shardings(
                sharding.param_specs(params, self.mesh, fsdp=False),
                self.mesh))
        self.params = params
        self.lora = lora
        self._prefill = jax.jit(make_prefill_step(
            plan, lora_scale=lora_scale, with_lora=lora is not None))
        self._decode = jax.jit(make_decode_step(
            plan, lora_scale=lora_scale, with_lora=lora is not None),
            donate_argnums=(2 if lora is None else 3,))
        # minimal obs surface (the continuous engines carry the full set)
        self.metrics = MetricsRegistry(constant_labels={"engine": "sync"})
        self.tracer = TickTracer(cfg.obs_trace_capacity, enabled=cfg.obs)
        self.events = EventLog(cfg.obs_event_capacity, enabled=cfg.obs)
        self._c_prefill_tokens = self.metrics.counter(
            "serve_prefill_tokens_total", "prompt tokens through prefill",
            unit="tokens").labels()
        self._c_decode_tokens = self.metrics.counter(
            "serve_decode_tokens_total", "tokens emitted by decode steps",
            unit="tokens").labels()
        self._c_completed = self.metrics.counter(
            "serve_requests_completed_total", "finished generate() batches",
            unit="requests").labels()

    def _call_prefill(self, tokens, cache, frontend=None):
        if self.lora is not None:
            return self._prefill(self.params, self.lora, tokens, cache,
                                 frontend)
        return self._prefill(self.params, tokens, cache, frontend)

    def _call_decode(self, token, cache, pos):
        if self.lora is not None:
            return self._decode(self.params, self.lora, token, cache, pos)
        return self._decode(self.params, token, cache, pos)

    def generate(
        self,
        prompts: np.ndarray,               # (B, S_prompt) int32
        *,
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        top_p: float = 0.95,
        seed: int = 0,
        frontend: Optional[np.ndarray] = None,
    ) -> GenerationResult:
        B, S_prompt = prompts.shape
        ctx = (sharding.use_mesh(self.mesh, head_shard=True)
               if self.mesh is not None else _null())
        with ctx:
            cache = init_cache(self.plan, B, self.cfg.max_seq_len,
                               jnp.dtype(self.cfg.kv_cache_dtype))
            if self.mesh is not None:
                cache = jax.device_put(cache, sharding.to_shardings(
                    sharding.serve_cache_specs(cache, self.mesh, paged=False),
                    self.mesh))
            t0 = time.perf_counter()
            with self.tracer.span("prefill"):
                logits, cache, pos = self._call_prefill(
                    jnp.asarray(prompts), cache,
                    None if frontend is None else jnp.asarray(frontend))
                jax.block_until_ready(logits)
            t1 = time.perf_counter()

            rng = jax.random.PRNGKey(seed)
            # tokens accumulate on device; one transfer at the end (a
            # per-token np.asarray would force a host sync every step)
            out_buf = jnp.zeros((B, max_new_tokens), jnp.int32)
            tok = _sample(logits, temperature, top_p, rng)
            out_buf = out_buf.at[:, 0].set(tok)
            with self.tracer.span("decode"):
                for i in range(1, max_new_tokens):
                    rng = jax.random.fold_in(rng, i)
                    logits, cache = self._call_decode(
                        tok, cache, jnp.asarray(pos + i - 1, jnp.int32))
                    tok = _sample(logits, temperature, top_p, rng)
                    out_buf = out_buf.at[:, i].set(tok)
                jax.block_until_ready(out_buf)
            t2 = time.perf_counter()
        gen = np.asarray(out_buf)
        # honest accounting: the first token comes out of prefill, so the
        # decode window covers only max_new_tokens - 1 steps
        decode_toks = B * max(max_new_tokens - 1, 0)
        self._c_prefill_tokens.inc(B * S_prompt)
        self._c_decode_tokens.inc(decode_toks)
        self._c_completed.inc(B)
        return GenerationResult(
            tokens=gen, prefill_s=t1 - t0, decode_s=t2 - t1,
            tokens_per_s=B * max_new_tokens / max(t2 - t0, 1e-9),
            prefill_tokens_per_s=B * S_prompt / max(t1 - t0, 1e-9),
            decode_tokens_per_s=decode_toks / max(t2 - t1, 1e-9))


class ContinuousServeEngine:
    """Continuous-batching, multi-adapter engine (``submit`` / ``step`` /
    ``stream``)."""

    def __init__(self, plan: Plan, params: Any, cfg: ServeConfig,
                 registry: Optional[AdapterRegistry] = None, *,
                 lora_scale: float = 2.0, mesh=None):
        if plan.cfg.family == "encdec":
            raise NotImplementedError(
                "continuous batching does not cover encoder-decoder "
                "frontends yet — use ServeEngine")
        self.plan = plan
        self.params = params
        self.cfg = cfg
        if cfg.quant.kv == "int8" and not cfg.kv_paging:
            raise ValueError(
                "quant.kv='int8' requires kv_paging=True — the int8 codes "
                "and per-row scales live in the page pool")
        self._quant_weights = cfg.quant.weights == "nf4"
        self._quant_kv = cfg.quant.kv == "int8"
        if self._quant_weights:
            # QLoRAM serving: the frozen base projections quantize ONCE at
            # engine load; the decode tick streams the packed codes through
            # the fused dequant-matmul kernel.  Embeddings, norms, lm_head
            # and every LoRA bank stay fp (see configs.base.QuantPolicy).
            self.params = nf4.quantize_by_name(
                params, targets=cfg.quant.targets, block=cfg.quant.block)
        self.registry = registry
        self.mesh = _resolve_mesh(cfg, mesh)
        if self.mesh is not None:
            # hooks are context-gated: installing them changes nothing until
            # step() opens its use_mesh scope
            sharding.install_residual_constraint()
        if registry is not None:
            want = cfg.adapter_bank_slots or cfg.max_adapters
            if registry.bank_slots != want:
                raise ValueError(
                    f"ServeConfig wants a {want}-row adapter bank "
                    f"(adapter_bank_slots={cfg.adapter_bank_slots}, "
                    f"max_adapters={cfg.max_adapters}) but the registry "
                    f"was built with bank_slots={registry.bank_slots}")
        S = cfg.max_slots
        self._sched = Scheduler(S)
        self._n_ticks = 0
        self._lora_scale = lora_scale

        # ---- resilience (ServeConfig.resilience; all host-side) ----
        r = cfg.resilience
        self._resil = r
        self._faults = None               # install_faults(FaultPlan)
        self._degrade_ctl = (DegradationController(
            high=r.degrade_high, low=r.degrade_low,
            up_ticks=r.degrade_up_ticks, down_ticks=r.degrade_down_ticks)
            if r.degradation else None)
        self._degrade_level = 0
        self._chunk_eff = cfg.prefill_chunk   # shrinks at ladder level 4
        self._deadline_abs: Dict[int, float] = {}       # uid → abs e2e
        self._ttft_deadline_abs: Dict[int, float] = {}  # uid → abs TTFT
        self._terminal_info: Dict[int, tuple] = {}      # uid → staged
                                                        # (status, n, t_end)
        self._pending_results: List[RequestResult] = []  # terminals produced
                                                         # outside step()
        self._stalls_seen = 0.0
        self._stall_streak = 0
        self._want_restart = False

        # ---- paged KV cache plumbing (ServeConfig.kv_paging) ----
        self.paged = cfg.kv_paging
        self._page = cfg.kv_page_size
        self._n_tbl = pages_for(cfg.max_seq_len, self._page) if self.paged else 0
        # chunked prefill + COW prefix sharing ride on the paged cache
        self._chunking = cfg.prefill_chunk > 0
        self._sharing = cfg.prefix_sharing
        if (self._chunking or self._sharing) and not self.paged:
            raise ValueError(
                "prefill_chunk / prefix_sharing require kv_paging=True — "
                "both work through the block table")
        if self._chunking and cfg.prefill_chunk % max(self._page, 1):
            raise ValueError(
                f"prefill_chunk={cfg.prefill_chunk} must be a multiple of "
                f"kv_page_size={self._page} (chunks are page-aligned)")
        if self.paged:
            n_pages = cfg.kv_pages or (S * self._n_tbl + 1)
            if n_pages - 1 < self._n_tbl:
                raise ValueError(
                    f"kv_pages={n_pages} cannot back one max-length request "
                    f"({self._n_tbl} pages + the trash page) — the paged "
                    f"engine would preempt forever")
            self.pages = PageAllocator(n_pages, self._page, self._n_tbl, S)
            self._prefill_steps: Dict[int, Any] = {}    # bucket → jitted step
            self._chunk_steps: Dict[int, Any] = {}      # chunk len → jitted
            self._slot_pos = [0] * S        # next write position per slot
            self._admit_seq = [-1] * S      # admission order (newest preempts)
            self._seq_counter = 0
            # chunked-prefill progress (slot → host-side context)
            self._prefill_ctx: Dict[int, Dict[str, Any]] = {}
            # prefix registry: (prefix_id, adapter_id) → PrefixEntry,
            # plus keys currently mid-construction and the per-id token
            # declaration used for submit-time validation
            self._prefix: Dict[Any, PrefixEntry] = {}
            self._prefix_pending: set = set()
            self._slot_prefix: Dict[int, Any] = {}
            self._prefix_tokens: Dict[str, np.ndarray] = {}
            # every distinct attention write pattern (full + each window)
            # for the pre-write COW sweep
            wmap = attn_window_map(plan)
            self._write_windows = sorted(
                {w for stw in wmap.values() for w in stw.values()})
            self._copy_page_fn = make_copy_page(plan) if self._sharing else None
            self._cap_fn, self._res_fn = (
                make_state_ops(plan) if (self._chunking or self._sharing)
                else (None, None))
            self._zero_state = None     # built lazily (cache exists later)
        else:
            self._prefill = jax.jit(
                make_prefill_into_slot(plan, lora_scale=lora_scale,
                                       bucketed=cfg.prefill_buckets),
                donate_argnums=(3,))

        decode = make_multi_adapter_decode_step(plan, lora_scale=lora_scale,
                                                paged=self.paged)
        paged = self.paged

        def make_tick(sampling: bool):
            def tick(params_, bank, cache, st: TickState):
                if paged:
                    logits, cache = decode(params_, bank, st.last_tok,
                                           cache, st.pos,
                                           st.adapter_ids,
                                           st.block_table)
                else:
                    logits, cache = decode(params_, bank, st.last_tok,
                                           cache, st.pos,
                                           st.adapter_ids)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                if sampling:
                    # key = (request seed, generation index): sampling is
                    # reproducible per request no matter how the scheduler
                    # interleaved it with other traffic
                    keys = jax.vmap(request_key)(st.seeds, st.gen_idx)
                    temp = jnp.maximum(st.temps, 1e-6)[:, None]
                    sampled = jax.vmap(jax.random.categorical)(
                        keys, logits / temp).astype(jnp.int32)
                    tok = jnp.where(st.temps > 0.0, sampled, tok)
                act = st.active
                tok = jnp.where(act, tok, st.last_tok)
                step1 = act.astype(jnp.int32)
                bidx = jnp.arange(S)
                gi = jnp.minimum(st.gen_idx, st.out_buf.shape[1] - 1)
                cur = st.out_buf[bidx, gi]
                out_buf = st.out_buf.at[bidx, gi].set(
                    jnp.where(act, tok, cur))
                return cache, st.replace(
                    last_tok=tok,
                    pos=st.pos + step1,
                    gen_idx=st.gen_idx + step1,
                    out_buf=out_buf,
                )

            return jax.jit(tick, donate_argnums=(2, 3))

        # all-greedy traffic skips the per-slot rng/categorical work entirely
        self._tick_greedy = make_tick(False)
        self._tick_sample = make_tick(True)
        self._n_hot = 0    # in-flight/queued requests with temperature > 0

        # one fused dispatch per admission instead of eight .at[].set calls;
        # the speculative subclass shares this exact jit — its extra fields
        # update iff the TickState carries them (a trace-time branch in
        # repro.runtime.steps.admit_update)
        self._admit_update = jax.jit(admit_update, donate_argnums=(0,))

        if self.paged:
            self.cache = init_paged_cache(plan, S, self.pages.n_pages,
                                          self._page,
                                          jnp.dtype(cfg.kv_cache_dtype),
                                          quant_kv=self._quant_kv)
        else:
            self.cache = init_cache(plan, S, cfg.max_seq_len,
                                    jnp.dtype(cfg.kv_cache_dtype))
        self._st: TickState = self._init_tick_state(S, cfg)
        if self.mesh is not None:
            # weights TP/EP-sharded + cache per serve_cache_specs; the tick
            # state lands replicated per its own declared leaf specs.
            # Adapter banks and host-built rows stay uncommitted — jit
            # places them against the committed operands.
            self.params, self.cache = sharding.shard_serving(
                self.mesh, self.params, self.cache, paged=self.paged)
            self._st = jax.device_put(self._st,
                                      self._st.shardings(self.mesh))
        # observability (repro.obs): metrics registry (backing the n_*
        # accessor properties below), span tracer, lifecycle event log,
        # optional tick watchdog — all host-side, never inside jit
        self._init_obs()
        # per-request wall-clock (submit → first token → eviction); results
        # carry ttft_s / latency_s computed from these.  First-token stamps
        # are taken at DISPATCH return — the engine never blocks its hot
        # loop — so they measure host-side scheduling; a latency harness
        # that wants device-complete timing must sync per step and re-stamp
        # at the barrier (benchmarks/serve_bench.run_latency does)
        self._t_submit: Dict[int, float] = {}
        self._t_first: Dict[int, float] = {}

    # -- observability ------------------------------------------------------

    _obs_engine = "continuous"        # registry constant label value

    def _init_obs(self) -> None:
        """Build the obs surface: ``self.metrics`` / ``self.tracer`` /
        ``self.events``.  Counters replace the old ad-hoc integer
        attributes (reachable through the n_* properties below); gauges
        bind to live scheduler/allocator/engine state and resolve only at
        snapshot time, so the hot loop never pays for them."""
        cfg = self.cfg
        self.metrics = MetricsRegistry(
            constant_labels={"engine": self._obs_engine})
        self.tracer = TickTracer(
            cfg.obs_trace_capacity, enabled=cfg.obs,
            sync_fn=((lambda: jax.block_until_ready(self._st))
                     if cfg.obs_device_sync else None))
        self.events = EventLog(cfg.obs_event_capacity, enabled=cfg.obs)
        self._sched.on_event = self._sched_event
        m = self.metrics

        def counter(name, help_, unit):
            return m.counter(name, help_, unit=unit).labels()

        self._c_prefill_tokens = counter(
            "serve_prefill_tokens_total",
            "prompt tokens through prefill (incl. re-prefill after "
            "preemption; prefix-hit tokens count when mapped)", "tokens")
        self._c_decode_tokens = counter(
            "serve_decode_tokens_total",
            "tokens emitted by decode ticks / accepted by verify", "tokens")
        self._c_completed = counter(
            "serve_requests_completed_total", "finalized requests",
            "requests")
        self._c_prefill_chunks = counter(
            "serve_prefill_chunks_total", "chunked-prefill dispatches",
            "chunks")
        self._c_ticks = counter(
            "serve_ticks_total", "jitted decode-tick dispatches", "ticks")
        self._c_ticks_during_prefill = counter(
            "serve_ticks_during_prefill_total",
            "decode ticks run while a prompt was still streaming in — the "
            "no-stall proof", "ticks")
        self._c_prefix_hits = counter(
            "serve_prefix_hits_total",
            "admissions that mapped a shared prefix", "requests")
        self._c_prefix_tokens_saved = counter(
            "serve_prefix_tokens_saved_total",
            "prompt tokens NOT recomputed thanks to prefix hits", "tokens")
        self._c_prefix_pages_shared = counter(
            "serve_prefix_pages_shared_total",
            "KV pages mapped copy-on-write instead of allocated", "pages")
        self._c_preemptions = counter(
            "serve_preemptions_total",
            "slots evicted under page pressure and requeued", "requests")
        self._c_stalls = counter(
            "serve_stalls_total", "watchdog-flagged straggler ticks",
            "ticks")
        # resilience terminal-status counters (repro.serving.resilience):
        # serve_requests_completed_total counts status="ok" only, so
        # completed + shed + deadline_miss + cancelled + failed covers
        # every submitted request exactly once
        self._c_shed = counter(
            "serve_shed_total",
            "requests dropped by admission control / load shedding",
            "requests")
        self._c_deadline_miss = counter(
            "serve_deadline_miss_total",
            "requests terminated at a TTFT or end-to-end deadline",
            "requests")
        self._c_cancelled = counter(
            "serve_cancelled_total", "requests cancelled via engine.cancel",
            "requests")
        self._c_failed = counter(
            "serve_failed_total",
            "requests failed (impossible admission / injected faults)",
            "requests")
        self._c_restores = counter(
            "serve_restores_total",
            "snapshot-and-restart cycles (watchdog/retry escalation or "
            "explicit restore)", "restores")
        self._h_retries = m.histogram(
            "serve_tick_retries",
            "retry attempts absorbed per transient-fault tick dispatch "
            "(observed only when a dispatch needed retries)",
            unit="retries").labels()
        self._h_ttft = m.histogram(
            "serve_ttft_seconds", "submit → first-token dispatch",
            unit="seconds").labels()
        self._h_e2e = m.histogram(
            "serve_e2e_latency_seconds", "submit → eviction",
            unit="seconds").labels()

        def gauge(name, help_, unit, fn):
            m.gauge(name, help_, unit=unit).labels().set_fn(fn)

        gauge("serve_slots_occupied", "slots holding any request", "slots",
              lambda: len(self._sched.occupied_slots()))
        gauge("serve_slots_active", "slots actively decoding", "slots",
              lambda: len(self._sched.active_slots()))
        gauge("serve_queue_depth", "submitted but not admitted", "requests",
              lambda: self._sched.queued)
        gauge("serve_degradation_level",
              "graceful-degradation ladder position (0 = healthy, "
              "5 = shedding)", "level",
              lambda: float(self._degrade_level))
        if self.paged:
            gauge("serve_pages_in_use", "pool pages currently mapped",
                  "pages", lambda: self.pages.pages_in_use)
            gauge("serve_pages_free", "pool pages on the free list",
                  "pages", lambda: self.pages.free_pages)
            gauge("serve_pages_peak_in_use",
                  "high-water mark of mapped pages", "pages",
                  lambda: self.pages.peak_in_use)
            gauge("serve_pages_pool_size",
                  "pool capacity incl. the trash page", "pages",
                  lambda: self.pages.n_pages)
        if self.registry is not None:
            # adapter-residency telemetry (the paged adapter bank): rows in
            # use, gate hit-rate, upload traffic and evictions.  All read
            # host-side residency counters at snapshot time — zero hot-path
            # cost, nothing enters jit.
            res = self.registry.residency
            gauge("serve_adapter_bank_slots",
                  "device bank rows incl. the reserved base row", "rows",
                  lambda: res.bank_slots)
            gauge("serve_adapter_bank_in_use",
                  "bank rows assigned to adapters (resident + uploading)",
                  "rows", lambda: res.in_use)
            gauge("serve_adapter_registered",
                  "adapters in the unbounded host tier", "adapters",
                  lambda: float(len(self.registry)))
            gauge("serve_adapter_hits",
                  "admission-gate checks answered by a resident row",
                  "checks", lambda: res.n_hits)
            gauge("serve_adapter_misses",
                  "admission-gate checks that staged a host->HBM upload",
                  "checks", lambda: res.n_misses)
            gauge("serve_adapter_evictions",
                  "refcount-0 bank rows zeroed to make room", "rows",
                  lambda: res.n_evictions)
            gauge("serve_adapter_uploads",
                  "adapter trees committed into the device bank", "uploads",
                  lambda: res.n_uploads)
            gauge("serve_adapter_upload_bytes",
                  "host->HBM adapter bytes streamed (incl. registration)",
                  "bytes", lambda: float(res.upload_bytes))
            gauge("serve_adapter_hit_rate",
                  "resident fraction of admission-gate checks (1.0 when "
                  "nothing ever missed)", "ratio", lambda: res.hit_rate)
        # serving-time quantization (ServeConfig.quant): packed-vs-logical
        # byte attribution.  hbm_bytes below already reports PACKED bytes
        # for quantized tensors (shard nbytes of int8/uint8 storage); these
        # gauges add the fp-equivalent numerator the reduction ratio needs.
        gauge("serve_weight_bytes_packed",
              "physical base-weight bytes (NF4 codes + scales when "
              "quant.weights='nf4')", "bytes",
              lambda: nf4.param_bytes(self.params))
        gauge("serve_weight_bytes_logical",
              "fp32-equivalent base-weight bytes", "bytes",
              lambda: nf4.param_bytes_logical(self.params))
        gauge("serve_kv_cache_bytes",
              "attention K/V reservation (pool + block table; int8 pools "
              "count their scale pools)", "bytes",
              lambda: float(self.kv_cache_bytes()))
        m.gauge("serve_adapter_active_slots",
                "active slots per adapter name", unit="slots",
                labelnames=("adapter",)).set_collector(
            self._adapter_slot_collector)
        m.gauge("hbm_bytes",
                "per-device HBM attribution for the serving working set",
                unit="bytes",
                labelnames=("component", "device")).set_collector(
            self._hbm_collector)
        self._watchdog = None
        if cfg.tick_watchdog:
            self._watchdog = StepWatchdog(on_alarm=self._on_stall)
            gauge("serve_tick_ewma_s", "EWMA of tick wall-clock", "seconds",
                  lambda: self._watchdog.ewma or 0.0)

    # legacy counter accessors — same names the engines exposed as plain
    # ints before the registry existed; assignment (the benchmark's warm-up
    # `eng.n_x = 0` idiom) resets the underlying counter
    n_prefill_tokens = _counter_property(
        "_c_prefill_tokens", "prompt tokens through prefill")
    n_decode_tokens = _counter_property(
        "_c_decode_tokens", "tokens emitted by decode ticks")
    n_completed = _counter_property(
        "_c_completed", "finalized requests")
    n_prefill_chunks = _counter_property(
        "_c_prefill_chunks", "chunked-prefill dispatches")
    n_ticks_during_prefill = _counter_property(
        "_c_ticks_during_prefill", "decode ticks overlapped with prefill")
    n_prefix_hits = _counter_property(
        "_c_prefix_hits", "admissions that mapped a shared prefix")
    n_prefix_tokens_saved = _counter_property(
        "_c_prefix_tokens_saved", "prompt tokens not recomputed")
    n_prefix_pages_shared = _counter_property(
        "_c_prefix_pages_shared", "KV pages mapped copy-on-write")
    n_preemptions = _counter_property(
        "_c_preemptions", "slots evicted under page pressure")
    n_stalls = _counter_property(
        "_c_stalls", "watchdog-flagged straggler ticks")

    def _sched_event(self, kind: str, slot: int, req: Request) -> None:
        """Scheduler transition hook — the one place every admission /
        preemption path reports through, regardless of which engine
        subclass or prefill mode performed it."""
        # adapter-residency refcounts ride the same hook: a slot holds one
        # reference on its adapter's bank row from admission to eviction /
        # preemption, so the LRU can never evict a row a live slot gathers
        if self.registry is not None:
            if kind == "admit":
                self.registry.residency.retain(req.adapter_id)
            else:                                  # "preempt" or "evict"
                self.registry.residency.release(req.adapter_id)
        if kind == "admit":
            self.events.emit("admit", req.uid, slot=slot,
                             adapter=req.adapter, n_prompt=len(req.prompt))
        elif kind == "preempt":
            # fired before the pages are released — the count is what the
            # preemption is about to hand back
            pages = (len(self.pages.slot_pages(slot)) if self.paged else 0)
            self.events.emit("preempt", req.uid, slot=slot,
                             pages_freed=pages)
        elif kind == "evict":
            # EVERY terminal slot transition funnels through
            # Scheduler.evict, so this is where the terminal event is
            # emitted — the engine stages (status, n_generated, t_end)
            # in _terminal_info just before evicting; a transition that
            # forgot to stage still reports (as a plain completion)
            status, n, t_end = self._terminal_info.pop(
                req.uid, (STATUS_OK, 0, time.perf_counter()))
            self._emit_terminal(req.uid, slot, status, n, t_end)

    def _emit_terminal(self, uid: int, slot: int, status: str, n: int,
                       t_end: float) -> None:
        """One terminal event + one terminal-status counter bump per
        request — completed + shed + deadline_miss + cancelled + failed
        partitions every submitted uid."""
        self.events.emit(TERMINAL_EVENT[status], uid, t=t_end, slot=slot,
                         n_generated=n)
        if status == STATUS_OK:
            self._c_completed.inc()
        elif status == STATUS_TIMEOUT:
            self._c_deadline_miss.inc()
        elif status == STATUS_SHED:
            self._c_shed.inc()
        elif status == STATUS_CANCELLED:
            self._c_cancelled.inc()
        else:
            self._c_failed.inc()

    def _result_for(self, req: Request, n: int, row: np.ndarray,
                    status: str, t_end: float) -> RequestResult:
        """Build the typed result and settle the request's host-side
        accounting (hot-slot count, wall-clock stamps, deadlines).
        Latency histograms record clean completions only — shed/timeout
        latencies would poison the SLO percentiles they feed."""
        if req.temperature > 0.0:
            self._n_hot -= 1
        name = (self.registry.name_of(req.adapter_id)
                if self.registry is not None else None)
        t_sub = self._t_submit.pop(req.uid, t_end)
        t_first = self._t_first.pop(req.uid, t_end)
        self._deadline_abs.pop(req.uid, None)
        self._ttft_deadline_abs.pop(req.uid, None)
        ttft = max(t_first - t_sub, 0.0)
        latency = max(t_end - t_sub, 0.0)
        if status == STATUS_OK:
            self._h_ttft.observe(ttft)
            self._h_e2e.observe(latency)
        return RequestResult(uid=req.uid, tokens=row, adapter=name,
                             prompt_len=len(req.prompt), n_generated=n,
                             ttft_s=ttft, latency_s=latency, status=status)

    def _queue_terminal(self, req: Request, status: str) -> RequestResult:
        """Terminate a request that never held a slot (shed at submit,
        deadline-expired in queue, cancelled while queued, impossible
        admission): emits the terminal event with slot=-1."""
        t_end = time.perf_counter()
        self._emit_terminal(req.uid, -1, status, 0, t_end)
        return self._result_for(req, 0, np.zeros(0, np.int32), status,
                                t_end)

    def _stamp_first_token(self, req: Request) -> None:
        """First-token wall-clock, written AT MOST ONCE per uid: a request
        preempted after its first token keeps its original stamp on
        re-admission (its TTFT already happened — the re-run only recovers
        lost decode progress)."""
        t = time.perf_counter()
        if self._t_first.setdefault(req.uid, t) is t:
            self.events.emit("first_token", req.uid, t=t)

    def _on_stall(self, alarm: StragglerAlarm) -> None:
        self._c_stalls.inc()
        self.events.emit("stall", -1, elapsed_s=alarm.elapsed,
                         ewma_s=alarm.ewma)
        # escalation ladder: repeated stalls force-degrade, a long streak
        # schedules snapshot-and-restart (ServeConfig.resilience)
        r = self._resil
        self._stall_streak += 1
        if (r.stall_degrade_after and self._degrade_ctl is not None
                and self._stall_streak % r.stall_degrade_after == 0):
            self._apply_degradation(self._degrade_ctl.force_up())
        if r.stall_restart_after and self._stall_streak >= r.stall_restart_after:
            self._want_restart = True
            self._stall_streak = 0

    def _adapter_slot_collector(self) -> Dict[tuple, float]:
        tally: Dict[tuple, float] = {}
        for slot in self._sched.active_slots():
            req = self._sched.slot_request(slot)
            if req is None:
                continue
            name = (self.registry.name_of(req.adapter_id)
                    if self.registry is not None else None) or BASE_ADAPTER
            tally[(name,)] = tally.get((name,), 0) + 1
        return tally

    def _hbm_components(self) -> Dict[str, list]:
        comps = {"weights": [self.params], "kv_cache": [self.cache]}
        if self.registry is not None:
            comps["adapter_bank"] = [self.registry.bank]
        return comps

    def _hbm_collector(self) -> Dict[tuple, float]:
        """Per-(component, device) bytes from each array's addressable
        shards — under a mesh this reports the actual per-device split,
        single-device it degenerates to logical sizes.  Shard enumeration
        reads layout metadata only (no transfers)."""
        out: Dict[tuple, float] = {}
        for comp, trees in self._hbm_components().items():
            for tree in trees:
                if tree is None:
                    continue
                for leaf in jax.tree.leaves(tree):
                    shards = getattr(leaf, "addressable_shards", None)
                    if shards is None:
                        continue
                    for sh in shards:
                        key = (comp, str(sh.device.id))
                        out[key] = out.get(key, 0) + sh.data.nbytes
        return out

    def reset_telemetry(self) -> None:
        """Zero counters/histograms and drop recorded spans + events
        (benchmark warm-up boundary).  Gauges are live-bound and need no
        reset; in-flight request stamps are untouched."""
        self.metrics.reset()
        self.tracer.clear()
        self.events.clear()

    # -- intake -------------------------------------------------------------

    def submit(self, prompt: np.ndarray, *, max_new_tokens: int = 32,
               adapter: Union[str, int, None] = None,
               temperature: float = 0.0, seed: int = 0,
               speculative: bool = True,
               prefix_id: Optional[str] = None, prefix_len: int = 0) -> int:
        """Enqueue one request; returns its uid.  Non-blocking — call
        :meth:`step` (or :meth:`run` / :meth:`stream`) to make progress.
        ``speculative`` is honored by :class:`SpeculativeServeEngine` only
        (per-request opt-out of draft-then-verify); this engine ignores it.

        ``prefix_id`` (requires ``ServeConfig.prefix_sharing``) marks the
        first ``prefix_len`` prompt tokens as a SHARED prefix: the first
        request under an id prefills it once, every later request with the
        same id maps those pages read-only into its block table and
        prefills only its suffix.  All requests under one id must carry
        byte-identical prefix tokens."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1 or max_new_tokens > self.cfg.max_new_tokens:
            raise ValueError(
                f"max_new_tokens must be in [1, {self.cfg.max_new_tokens}]")
        if len(prompt) + max_new_tokens > self.cfg.max_seq_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_seq_len={self.cfg.max_seq_len}")
        if prefix_id is not None:
            if not (self.paged and self._sharing):
                raise ValueError(
                    "prefix_id requires ServeConfig.prefix_sharing=True on "
                    "a paged engine (kv_paging=True)")
            if not 0 < prefix_len < len(prompt):
                raise ValueError(
                    f"prefix_len must be in (0, len(prompt)) — the suffix "
                    f"needs at least one real token (got {prefix_len} of "
                    f"{len(prompt)})")
            known = self._prefix_tokens.get(prefix_id)
            if known is None:
                self._prefix_tokens[prefix_id] = prompt[:prefix_len].copy()
            elif (prefix_len != len(known)
                    or not np.array_equal(prompt[:prefix_len], known)):
                raise ValueError(
                    f"prefix_id {prefix_id!r} is already registered with "
                    f"different tokens — shared prefixes must be identical")
        aid = 0
        resolve_err = False
        if self.registry is not None:
            try:
                aid = self.registry.resolve(adapter)
            except KeyError:
                # unknown or stale adapter: the request fails TYPED through
                # the terminal choke point (status "failed"), same as any
                # other unservable submission — never an engine-side raise
                resolve_err = True
        elif adapter is not None:
            raise ValueError("adapter given but engine has no registry")
        req = Request(uid=self._sched.new_uid(), prompt=prompt,
                      max_new_tokens=max_new_tokens, adapter=adapter
                      if isinstance(adapter, str) else None,
                      adapter_id=aid, temperature=temperature, seed=seed,
                      speculative=speculative, prefix_id=prefix_id,
                      prefix_len=prefix_len)
        if temperature > 0.0:
            self._n_hot += 1
        t = time.perf_counter()
        self._t_submit[req.uid] = t
        self.events.emit("submit", req.uid, t=t, n_prompt=len(prompt),
                         adapter=req.adapter)
        # ---- admission control (ServeConfig.resilience) ----
        r = self._resil
        if r.deadline_s > 0.0:
            self._deadline_abs[req.uid] = t + r.deadline_s
        if r.ttft_deadline_s > 0.0:
            self._ttft_deadline_abs[req.uid] = t + r.ttft_deadline_s
        if resolve_err or self._impossible(req):
            # the request can NEVER be served — unresolvable adapter, page
            # demand beyond the whole pool, or an adapter bank with no
            # adapter rows — fail it typed instead of letting the
            # preempt-newest loop livelock on it
            self._pending_results.append(
                self._queue_terminal(req, STATUS_FAILED))
            return req.uid
        if self._degrade_level >= DEGRADE_SHED and self._sched.queued > 0:
            # ladder top: shed new arrivals while a backlog exists
            self._pending_results.append(
                self._queue_terminal(req, STATUS_SHED))
            return req.uid
        if r.queue_limit and self._sched.queued >= r.queue_limit:
            if r.queue_policy == "reject":
                self._pending_results.append(
                    self._queue_terminal(req, STATUS_SHED))
                return req.uid
            # shed-oldest: the head has waited longest and is the most
            # deadline-doomed — drop it, admit the newcomer
            victim = self._sched.shed_oldest()
            if victim is not None:
                self._pending_results.append(
                    self._queue_terminal(victim, STATUS_SHED))
        return self._sched.submit(req)

    def register_adapter(self, name: str, lora, *,
                         draft_lora=None) -> int:
        """Hot-register (or hot-swap) an adapter into the RUNNING engine —
        the paper's production loop: prune → train at pruned width →
        recover → serve, without a restart.  The bank's shapes are fixed
        at construction, so no tick ever recompiles: a free bank row is
        committed synchronously, otherwise the tree waits host-side and
        streams in on first use.  Returns the adapter id to submit under.

        ``draft_lora`` (the pruned-width twin for the draft bank) requires
        a :class:`SpeculativeServeEngine`."""
        if self.registry is None:
            raise ValueError(
                "engine was built without an adapter registry — construct "
                "it with registry=AdapterRegistry(template, ...)")
        if draft_lora is not None:
            raise ValueError(
                "draft_lora requires a SpeculativeServeEngine with a "
                "draft adapter bank")
        return self.registry.add(name, lora)

    def cancel(self, uid: int) -> Optional[RequestResult]:
        """Terminate one request wherever it lives — queued (dropped in
        place) or in-flight (finalized with its partial tokens; pages,
        prefix refcounts and block-table row release exactly as at
        completion).  Returns the typed result (``status="cancelled"``)
        directly, or None if the uid is not live."""
        req = self._sched.drop_queued(uid)
        if req is not None:
            return self._queue_terminal(req, STATUS_CANCELLED)
        for slot in self._sched.occupied_slots():
            r = self._sched.slot_request(slot)
            if r is not None and r.uid == uid:
                ctx = (sharding.use_mesh(self.mesh, head_shard=True)
                       if self.mesh is not None else _null())
                with ctx:
                    return self._finalize(slot, STATUS_CANCELLED)
        return None

    # -- progress -----------------------------------------------------------

    def step(self) -> List[RequestResult]:
        """Admit whatever fits, stream at most one prefill chunk per
        still-prefilling slot, run one decode tick, return newly completed
        requests (empty list if nothing finished this tick).  With
        resilience configured the step also drains out-of-band terminals
        (shed/failed at submit), enforces deadlines, observes the
        degradation controller, and honors a pending snapshot-and-restart
        escalation — all host-side, nothing new inside jit."""
        done: List[RequestResult] = []
        if self._pending_results:
            done.extend(self._pending_results)
            self._pending_results.clear()
        if self._want_restart:
            self._self_restart()
        ctx = (sharding.use_mesh(self.mesh, head_shard=True)
               if self.mesh is not None else _null())
        progressive = self.paged and (self._chunking or self._sharing)
        with ctx:
            if self._resil.enabled:
                done.extend(self._enforce_deadlines())
                done.extend(self._break_admission_stall())
            if self._degrade_ctl is not None:
                self._degrade_tick()
            if self.paged:
                # grow EXISTING slots before admitting: otherwise a freshly
                # admitted request is always the newest slot and the first
                # preemption victim, wasting its just-run prefill
                self._ensure_growth(lookahead=1)
            with self.tracer.span("admit"):
                self._admit_pass(done, progressive)
            if progressive:
                # one bounded chunk per prefilling slot, oldest first — the
                # decode tick below runs regardless, so a long prompt never
                # stalls in-flight traffic
                with self.tracer.span("chunk"):
                    self._prefill_tick()
            # single-token requests finish at prefill, before any tick
            for slot in self._sched.completed_slots():
                done.append(self._finalize(slot))
            if self.paged:
                # back the next write position of every active slot —
                # including a just-admitted slot whose prompt filled its
                # bucket exactly — with a real page BEFORE the tick
                self._ensure_growth(lookahead=1)
            if self._sharing:
                # decode writes (incl. windowed ring wraps) must never land
                # on a shared page — fork any such entry first.  Only slots
                # that mapped a prefix can hold shared pages, so plain
                # traffic skips the sweep entirely
                with self.tracer.span("cow"):
                    for slot in self._sched.active_slots():
                        if (slot in self._slot_prefix
                                and self._sched.slot_request(slot)
                                is not None):
                            self._cow_range(slot, self._slot_pos[slot],
                                            self._slot_pos[slot] + 1)
            active = self._sched.active_slots()
            if active:
                tick = self._tick_sample if self._n_hot else self._tick_greedy
                # read the bank through the registry every tick so add() /
                # hot-swap after construction takes effect (same shapes →
                # no recompile)
                bank = None if self.registry is None else self.registry.bank
                if self._watchdog is not None:
                    self._watchdog.start()
                if self._pre_dispatch_guard():
                    with self.tracer.span("tick"):
                        self.cache, self._st = tick(
                            self.params, bank, self.cache, self._st)
                    if self._watchdog is not None:
                        self._watchdog.stop(self._n_ticks)
                    self._n_ticks += 1
                    self._c_ticks.inc()
                    if self._sched.prefilling_slots():
                        self._c_ticks_during_prefill.inc()
                    if self.paged:
                        for slot in active:
                            self._slot_pos[slot] += 1
                    for slot in self._sched.tick():
                        done.append(self._finalize(slot))
                # guard False: the dispatch was skipped wholesale (retry
                # budget exhausted; a restart runs next step) — host
                # counters and device state both saw nothing, so they
                # stay consistent
        return done

    def _admit_pass(self, done: List[RequestResult],
                    progressive: bool) -> None:
        """Drain admissions into free slots (FCFS).  Consults the fault
        plan's ``adapter`` site and the degradation ladder per admission.
        With a registry attached the gate also requires the request's
        adapter to be RESIDENT in the device bank — a miss stages an async
        upload and the request waits in queue (the transfer overlaps the
        decode ticks below), admitting on a later pass once committed."""
        if self.registry is not None:
            self._drain_adapter_events()
        gated = self.paged or self.registry is not None
        while True:
            adm = self._sched.next_admission(
                gate=self._admission_gate if gated else None,
                prefill=self._chunked_path if progressive else None)
            if adm is None:
                break
            slot, req = adm
            if (self._faults is not None
                    and self._faults.adapter_load_fails()):
                done.append(self._fail_admission(slot, req))
                continue
            if self._degrade_level >= DEGRADE_NO_SPEC:
                # draft-then-verify off under pressure; base engines pin
                # non-speculative slots to the identical decode path, so
                # greedy output is unchanged
                req.speculative = False
            if progressive and self._chunked_path(req):
                self._admit_chunked(slot, req)
            else:
                self._admit(slot, req)

    def _fail_admission(self, slot: int, req: Request) -> RequestResult:
        """Adapter-load failure at admission: the slot was claimed but no
        model work ran yet — release it and terminate the request typed."""
        if self.paged:
            self._release_slot_pages(slot)
        t_end = time.perf_counter()
        self._terminal_info[req.uid] = (STATUS_FAILED, 0, t_end)
        self._sched.evict(slot)
        return self._result_for(req, 0, np.zeros(0, np.int32),
                                STATUS_FAILED, t_end)

    def _drain_adapter_events(self) -> None:
        """Commit any staged adapter uploads into the bank (async device
        work issued between ticks) and report residency transitions to the
        event log."""
        res = self.registry.residency
        res.poll()
        for kind, aid, row, nbytes in res.drain_events():
            name = self.registry.name_of(aid) or str(aid)
            if kind == "upload":
                self.events.emit("adapter_upload", -1, adapter=name,
                                 row=row, n_bytes=nbytes)
            else:
                self.events.emit("adapter_evict", -1, adapter=name, row=row)

    def _pre_dispatch_guard(self) -> bool:
        """Consult the fault plan immediately BEFORE a jitted dispatch
        (injection pre-dispatch means donated buffers are never left
        half-consumed).  Transient tick faults are absorbed by bounded
        retry-with-backoff; exhausting the budget schedules a
        snapshot-and-restart and skips this dispatch entirely."""
        if self._faults is None:
            return True
        self._faults.maybe_stall()
        attempts = 0
        while True:
            try:
                self._faults.raise_if_tick()
                if attempts:
                    self._h_retries.observe(float(attempts))
                return True
            except TransientFault:
                attempts += 1
                if attempts > self._resil.tick_retries:
                    self._h_retries.observe(float(attempts))
                    self._want_restart = True
                    return False
                if self._resil.retry_backoff_s:
                    time.sleep(self._resil.retry_backoff_s * attempts)

    def _enforce_deadlines(self) -> List[RequestResult]:
        """Expire requests past their absolute deadlines, queued first.
        An in-flight request times out on its e2e deadline, or on its
        TTFT deadline while it still has no first-token stamp; partial
        tokens ship with the timeout result."""
        out: List[RequestResult] = []
        if not (self._deadline_abs or self._ttft_deadline_abs):
            return out
        now = time.perf_counter()

        def expired(uid: int, in_flight: bool) -> bool:
            dl = self._deadline_abs.get(uid)
            if dl is not None and now >= dl:
                return True
            tdl = self._ttft_deadline_abs.get(uid)
            return (tdl is not None and now >= tdl
                    and not (in_flight and uid in self._t_first))

        for req in self._sched.queued_requests():
            if expired(req.uid, in_flight=False):
                self._sched.drop_queued(req.uid)
                out.append(self._queue_terminal(req, STATUS_TIMEOUT))
        for slot in list(self._sched.occupied_slots()):
            req = self._sched.slot_request(slot)
            if req is not None and expired(req.uid, in_flight=True):
                out.append(self._finalize(slot, STATUS_TIMEOUT))
        return out

    def _impossible(self, req: Request) -> bool:
        """A request whose page demand exceeds the entire usable pool can
        never be admitted no matter what gets preempted.  The engine
        constructor guarantees one max-length request fits, so this only
        trips on config drift — the live variant of the same livelock
        (pages pinned outside slots) is caught by
        :meth:`_break_admission_stall`."""
        if (self.registry is not None and req.adapter_id != 0
                and self.registry.bank_slots < 2):
            # row 0 is the reserved base route: a 1-row bank can never
            # host ANY adapter, so the residency gate would block forever
            return True
        if not self.paged:
            return False
        sb = bucket_len(len(req.prompt), self._page, self.cfg.max_seq_len)
        limit = min(len(req.prompt) + req.max_new_tokens,
                    self.cfg.max_seq_len)
        need = max(pages_for(sb, self._page), pages_for(limit, self._page))
        return need > self.pages.n_pages - 1

    def _fits_alone(self, req: Request) -> bool:
        """Can the request run to completion with the whole free list to
        itself?  (The strongest guarantee reclaim can ever deliver.)"""
        sb = bucket_len(len(req.prompt), self._page, self.cfg.max_seq_len)
        limit = min(len(req.prompt) + req.max_new_tokens,
                    self.cfg.max_seq_len)
        need = max(pages_for(sb, self._page), pages_for(limit, self._page))
        return need <= self.pages.free_pages

    def _break_admission_stall(self) -> List[RequestResult]:
        """Admission-livelock breaker (the preempt-newest loop's blind
        spot): the queue has work, every slot is free, yet the head can't
        complete even with all reclaimable pages — pages are pinned
        outside the slot table (retained prefixes, external retains).
        Idle prefixes are dropped first; a head that STILL can't fit
        alone can never run and fails typed instead of spinning through
        admit → self-preempt forever."""
        out: List[RequestResult] = []
        if not self.paged:
            return out
        while self._sched.queued and not self._sched.occupied_slots():
            head = self._sched.queued_requests()[0]
            if self._fits_alone(head):
                break
            if self._drop_one_idle_prefix():
                continue
            self._sched.drop_queued(head.uid)
            out.append(self._queue_terminal(head, STATUS_FAILED))
        return out

    # -- graceful degradation ------------------------------------------------

    def _degrade_tick(self) -> None:
        """One hysteresis-controller observation per engine step.
        Pressure is the worst of queue depth (vs. the configured limit or
        4× the slot table), page-pool occupancy, and a fresh watchdog
        stall (saturates the signal).  Level changes re-apply the ladder
        immediately; level 3+ additionally keeps idle prefixes drained."""
        qcap = self._resil.queue_limit or 4 * self.cfg.max_slots
        pressure = min(self._sched.queued / qcap, 1.0)
        if self.paged:
            usable = max(self.pages.n_pages - 1, 1)
            pressure = max(pressure, self.pages.pages_in_use / usable)
        stalls = self.n_stalls
        if stalls > self._stalls_seen:
            self._stalls_seen = stalls
            pressure = 1.0
        lvl = self._degrade_ctl.observe(pressure)
        if lvl != self._degrade_level:
            self._apply_degradation(lvl)
        if self._degrade_level >= DEGRADE_DROP_PREFIXES:
            while self._drop_one_idle_prefix():
                pass

    def _apply_degradation(self, level: int) -> None:
        """Make one ladder level effective (both directions — recovery
        restores full service).  The base engine owns the chunk-shrink
        rung; the speculative subclass adds the γ rungs."""
        prev, self._degrade_level = self._degrade_level, level
        if self._chunking:
            self._chunk_eff = (
                self.cfg.prefill_chunk if level < DEGRADE_SHRINK_CHUNK
                else max(self._page,
                         (self.cfg.prefill_chunk // 2 // self._page)
                         * self._page))
        self.events.emit("degrade", -1, level=level, prev=prev)

    def _drop_one_idle_prefix(self) -> bool:
        """Free one cached prefix with no live sharers; False if none."""
        if not self.paged:
            return False
        for pid in list(self._prefix):
            entry = self._prefix[pid]
            if entry.active == 0:
                self.pages.release_ids(entry.pages)
                del self._prefix[pid]
                return True
        return False

    # -- snapshot / restore / fault installation -----------------------------

    def install_faults(self, plan) -> None:
        """Attach a :class:`repro.testing.faults.FaultPlan`; the engine
        consults it pre-dispatch (``tick``/``stall``), at page growth
        (``alloc``) and at admission (``adapter``)."""
        self._faults = plan

    def snapshot(self) -> dict:
        """JSON-compatible engine state: in-flight + queued requests (in
        restart order), wall-clock stamps and absolute deadlines, the uid
        watermark, the host tick mirror, and the allocator dump."""
        return engine_snapshot(self)

    def restore(self, snap: dict) -> None:
        """Load a snapshot into this (idle) engine: every captured
        request re-queues under its original uid and stamps and re-runs
        from its prompt — deterministic per-(seed, index) sampling makes
        the re-run token-identical to the uninterrupted one."""
        engine_restore(self, snap)

    def _self_restart(self) -> None:
        """Escalation endpoint (tick-retry exhaustion, stall ladder):
        snapshot, wipe runtime state, restore into ourselves."""
        self._want_restart = False
        snap = engine_snapshot(self)
        self._reset_runtime_state()
        engine_restore(self, snap)

    def _reset_runtime_state(self) -> None:
        """Drop every in-flight structure back to the post-construction
        state.  Counters, the event log, prefix token declarations and
        the uid watermark survive; device caches are NOT cleared — the
        zeroed tick state makes their stale contents unreachable, and
        restored requests re-prefill exactly like preemption re-runs."""
        self._sched.reset()
        S = self.cfg.max_slots
        if self.paged:
            peak = self.pages.peak_in_use
            self.pages = PageAllocator(self.pages.n_pages, self._page,
                                       self._n_tbl, S)
            self.pages.peak_in_use = peak
            self._slot_pos = [0] * S
            self._admit_seq = [-1] * S
            self._prefill_ctx.clear()
            self._prefix.clear()
            self._prefix_pending.clear()
            self._slot_prefix.clear()
        self._n_hot = 0
        self._terminal_info.clear()
        if self.registry is not None:
            # Scheduler.reset() wipes the slot table WITHOUT per-slot evict
            # hooks, so the slot-held bank-row references drop here; the
            # rows themselves (and the host tier) survive the restart
            self.registry.residency.clear_refcounts()
        st = self._init_tick_state(S, self.cfg)
        if self.mesh is not None:
            st = jax.device_put(st, st.shardings(self.mesh))
        self._st = st

    def _resubmit(self, rd: dict, stamps: dict) -> None:
        """Re-queue one serialized request under its ORIGINAL uid and
        wall-clock stamps (deadlines stay absolute, so a request that
        slept through a restart still times out honestly).  An adapter
        that no longer resolves fails the request typed instead of
        poisoning the whole restore."""
        prompt = np.asarray(rd["prompt"], np.int32)
        req = Request(uid=int(rd["uid"]), prompt=prompt,
                      max_new_tokens=int(rd["max_new_tokens"]),
                      adapter=rd.get("adapter"),
                      temperature=float(rd.get("temperature", 0.0)),
                      seed=int(rd.get("seed", 0)),
                      speculative=bool(rd.get("speculative", True)),
                      prefix_id=rd.get("prefix_id"),
                      prefix_len=int(rd.get("prefix_len", 0)))
        for key, store in (("t_submit", self._t_submit),
                           ("t_first", self._t_first),
                           ("deadline", self._deadline_abs),
                           ("ttft_deadline", self._ttft_deadline_abs)):
            if stamps.get(key) is not None:
                store[req.uid] = float(stamps[key])
        if req.temperature > 0.0:
            self._n_hot += 1       # _result_for decrements on any terminal
        if req.adapter is not None:
            try:
                if self.registry is None:
                    raise ValueError("engine has no adapter registry")
                req.adapter_id = self.registry.resolve(req.adapter)
            except Exception:
                self._pending_results.append(
                    self._queue_terminal(req, STATUS_FAILED))
                return
        if (req.prefix_id is not None and self.paged and self._sharing
                and req.prefix_len):
            self._prefix_tokens.setdefault(req.prefix_id,
                                           prompt[:req.prefix_len].copy())
        self._sched.submit(req)

    def _note_restore(self, n: int) -> None:
        self._c_restores.inc()
        self.events.emit("restore", -1, n_requests=n)

    def run(self) -> Dict[int, RequestResult]:
        """Drain the queue completely; returns {uid: result}."""
        out: Dict[int, RequestResult] = {}
        for res in self.stream():
            out[res.uid] = res
        return out

    def stream(self) -> Iterator[RequestResult]:
        """Yield results as requests complete (streaming consumption).
        Out-of-band terminals (shed/failed at submit time) drain through
        the same stream."""
        while self._pending_results or self._sched.has_work:
            yield from self.step()

    @property
    def pending(self) -> int:
        return (self._sched.queued + len(self._sched.occupied_slots())
                + len(self._pending_results))

    # -- internals ----------------------------------------------------------

    def _init_tick_state(self, S: int, cfg: ServeConfig) -> TickState:
        """The engine's initial :class:`TickState` (all slots free).  The
        speculative engine overrides this to request the draft-round leaves
        — the base constructor then places ONE state for both."""
        return TickState.zeros(S, cfg.max_new_tokens,
                               n_tbl=self._n_tbl if self.paged else 0)

    def _bucketed_prompt(self, req: Request):
        """(tokens (1, Sb), valid_len) — the prompt right-padded to its
        power-of-two bucket.  Paged mode always buckets (scratch prefill rows
        scatter into whole pages); dense mode buckets when configured."""
        n = len(req.prompt)
        sb = bucket_len(n, self._page if self.paged else 1,
                        self.cfg.max_seq_len)
        padded = np.zeros(sb, np.int32)
        padded[:n] = req.prompt
        return jnp.asarray(padded[None]), n

    def _paged_prefill_step(self, bucket: int):
        step = self._prefill_steps.get(bucket)
        if step is None:
            step = jax.jit(
                make_paged_prefill_into_slot(self.plan, bucket, self._page,
                                             self._n_tbl,
                                             lora_scale=self._lora_scale),
                donate_argnums=(3,))
            self._prefill_steps[bucket] = step
        return step

    # -- chunked prefill ----------------------------------------------------

    def _chunked_path(self, req: Request) -> bool:
        """Does this request stream in via prefill chunks?  Shared-prefix
        requests always do (the suffix is a continuation at pos > 0);
        otherwise only prompts longer than one chunk — short prompts keep
        the monolithic single-dispatch path."""
        if not self.paged:
            return False
        if self._sharing and req.prefix_id is not None:
            return True
        return self._chunking and bucket_len(
            len(req.prompt), self._page,
            self.cfg.max_seq_len) > self.cfg.prefill_chunk

    def _admit_chunked(self, slot: int, req: Request) -> None:
        """Claim the slot but run NO model work yet: the prompt streams in
        one chunk per engine step (:meth:`_prefill_tick`).  The slot's
        device-side block-table row stays ZERO until activation — the row
        rides into each chunk dispatch as an explicit operand instead, so
        the masked decode tick's garbage writes for this (still inactive)
        slot keep landing on the trash page and can never corrupt the
        half-prefilled pages."""
        self._admit_seq[slot] = self._next_seq()
        self._slot_pos[slot] = 0
        # the prefix cache unit is (prompt prefix, adapter): K/V runs
        # through the slot's LoRA wk/wv deltas, so each adapter stream
        # shares its own entry
        pid = ((req.prefix_id, req.adapter_id)
               if self._sharing and req.prefix_id is not None else None)
        ctx = {"req": req, "prefix": pid, "mapped": False,
               "capture_at": None, "building": None,
               # recurrent state rides host-side between chunks — the
               # decode tick garbage-advances every slot's dense rows, so
               # the shared cache can't hold a half-prefilled recurrence
               "state": self._init_chunk_state()}
        if pid is not None and pid not in self._prefix:
            # first request under this id builds the prefix; later submits
            # are gated out until the entry exists
            ctx["capture_at"] = req.prefix_len
            ctx["building"] = pid
            self._prefix_pending.add(pid)
        self._prefill_ctx[slot] = ctx

    def _prefill_tick(self) -> None:
        """Run one bounded prefill chunk for every still-prefilling slot,
        oldest first (FCFS progress under preemption pressure)."""
        for slot in sorted(self._sched.prefilling_slots(),
                           key=lambda s: self._admit_seq[s]):
            if self._sched.slot_request(slot) is None:
                continue          # preempted by an earlier slot's growth
            self._run_chunk(slot)

    def _chunk_step(self, chunk_len: int):
        step = self._chunk_steps.get(chunk_len)
        if step is None:
            step = jax.jit(
                make_paged_prefill_chunk(self.plan, chunk_len, self._page,
                                         self._n_tbl,
                                         lora_scale=self._lora_scale),
                donate_argnums=(3,))
            self._chunk_steps[chunk_len] = step
        return step

    def _init_chunk_state(self):
        """Zero recurrent rows for a fresh chunked admission (None for
        attention-only plans).  Overridden by the speculative engine to
        carry the draft's rows too."""
        if self._cap_fn is None:
            return None
        if self._zero_state is None:
            self._zero_state = jax.tree.map(jnp.zeros_like,
                                            self._cap_fn(self.cache, 0))
        return self._zero_state

    def _chunk_dispatch(self, req: Request, slot: int, tokens, row, pos0,
                        valid, state):
        """One jitted chunk dispatch; returns (logits, new recurrent
        state).  Overridden by the speculative engine to prefill the draft
        cache in the same fused call."""
        tree = (None if self.registry is None
                else self.registry.adapter_tree(req.adapter_id))
        step = self._chunk_step(tokens.shape[1])
        logits, self.cache, new_state = step(
            self.params, tree, tokens, self.cache,
            {} if state is None else state, row, pos0, valid)
        return logits, new_state or None

    def _activate(self, slot: int, req: Request, first) -> None:
        """Flip a fully-prefilled slot live in the jitted tick state.  The
        speculative operands trace unused when the state has no spec leaves.

        ``TickState.adapter_ids`` carries the BANK ROW, not the host
        adapter id: the admission gate proved residency, so the row is
        resolved here once and pinned (refcounted) until the slot
        evicts — the decode gather never needs the host-side mapping."""
        row = (self.registry.bank_row(req.adapter_id)
               if self.registry is not None else 0)
        self._st = self._admit_update(
            self._st, slot, first, len(req.prompt), row,
            req.temperature, req.seed, req.max_new_tokens, req.speculative)

    def _run_chunk(self, slot: int) -> None:
        ctx = self._prefill_ctx[slot]
        req = ctx["req"]
        total = len(req.prompt)
        # map an existing shared prefix before the first chunk: share its
        # pages, clone its recurrent state, skip its prompt tokens entirely
        if (ctx["prefix"] is not None and not ctx["mapped"]
                and self._sched.slot_prefill_pos(slot) == 0
                and ctx["prefix"] in self._prefix):
            entry_state = self._map_prefix(slot, ctx["prefix"])
            if entry_state is not None:
                ctx["state"] = entry_state
            ctx["mapped"] = True
        pos0 = self._sched.slot_prefill_pos(slot)
        cap_at = ctx["capture_at"]
        if self._chunking:
            chunk_len = self._chunk_eff    # == cfg.prefill_chunk unless
        else:                              # the degradation ladder shrank it
            # prefix sharing without chunking: one bucket-sized span per
            # call (compiled O(log) times, like monolithic prefill)
            span_end = cap_at if (cap_at is not None and pos0 < cap_at) \
                else total
            chunk_len = bucket_len(span_end - pos0, self._page,
                                   self.cfg.max_seq_len)
        end = min(pos0 + chunk_len, total)
        if cap_at is not None and pos0 < cap_at:
            # stop EXACTLY at the prefix boundary so the captured pages and
            # state hold the prefix alone — the boundary page is still
            # untouched by this request's suffix
            end = min(end, cap_at)
        valid = end - pos0
        if not self._grow_for_prefill(slot, end):
            return                # slot preempted under pool pressure
        self._cow_range(slot, pos0, end)
        if self._sched.slot_request(slot) is None:
            return                # a COW fork's allocation preempted us
        tokens = np.zeros(chunk_len, np.int32)
        tokens[:valid] = req.prompt[pos0:end]
        row = np.zeros(self._n_tbl, np.int32)
        owned = self.pages.slot_pages(slot)
        row[:len(owned)] = owned
        logits, ctx["state"] = self._chunk_dispatch(
            req, slot, jnp.asarray(tokens[None]), jnp.asarray(row[None]),
            pos0, valid, ctx["state"])
        self._slot_pos[slot] = end
        self._c_prefill_tokens.inc(valid)
        self._c_prefill_chunks.inc()
        self.events.emit("prefill_chunk", req.uid, slot=slot, start=pos0,
                         n_tokens=valid)
        self._sched.advance_prefill(slot, valid)
        if cap_at is not None and end == cap_at:
            self._capture_prefix(slot, ctx)
        if end == total:
            first = self._first_token(logits[0], req)
            # the streamed recurrent state finally lands in the big cache —
            # from here the decode tick owns it
            self._state_restore(slot, ctx["state"])
            self._activate(slot, req, first)
            self._set_table_row(slot, self.pages.slot_pages(slot))
            self._sched.start_decode(slot)
            self._stamp_first_token(req)
            del self._prefill_ctx[slot]

    def _grow_for_prefill(self, slot: int, end: int) -> bool:
        """Back positions [0, end) with pages before a chunk dispatch;
        reclaims (idle prefixes first, then newest slots) on exhaustion.
        Returns False if this slot itself was preempted."""
        need = pages_for(end, self._page)
        while True:
            try:
                if self._faults is not None:
                    self._faults.check_alloc()
                self.pages.ensure(slot, need)
                return True
            except PoolExhausted:
                self._reclaim()
                if self._sched.slot_request(slot) is None:
                    return False

    # -- prefix sharing -----------------------------------------------------

    def _map_prefix(self, slot: int, pid):
        """Share the entry's pages into the slot and hand back its
        recurrent-state snapshot (which becomes the slot's streaming
        state — NOT written to the cache until activation)."""
        entry = self._prefix[pid]
        self.pages.share(slot, entry.pages)
        entry.active += 1
        self._slot_prefix[slot] = pid
        self._slot_pos[slot] = entry.n_tokens
        self._sched.advance_prefill(slot, entry.n_tokens)
        self._c_prefix_hits.inc()
        self._c_prefix_tokens_saved.inc(entry.n_tokens)
        self._c_prefix_pages_shared.inc(len(entry.pages))
        req = self._sched.slot_request(slot)
        if req is not None:
            self.events.emit("prefix_hit", req.uid, slot=slot,
                             tokens_saved=entry.n_tokens,
                             pages_shared=len(entry.pages))
        return entry.state

    def _capture_prefix(self, slot: int, ctx: Dict[str, Any]) -> None:
        """The builder slot just committed exactly the prefix: retain its
        pages under the registry entry and snapshot the recurrent state at
        the boundary."""
        req = ctx["req"]
        pid = ctx["building"]
        n_p = req.prefix_len
        pages = self.pages.slot_pages(slot)[:pages_for(n_p, self._page)]
        self.pages.retain(pages)
        entry = PrefixEntry(tokens=np.asarray(req.prompt[:n_p]),
                            n_tokens=n_p, pages=list(pages),
                            state=ctx["state"], active=1)
        self._prefix[pid] = entry
        self._prefix_pending.discard(pid)
        self._slot_prefix[slot] = pid
        ctx["capture_at"] = None
        ctx["building"] = None
        ctx["mapped"] = True      # the builder holds its own prefix already

    def _state_restore(self, slot: int, state) -> None:
        if state is not None:
            self.cache = self._res_fn(self.cache, state, slot)

    def _copy_page(self, src: int, dst: int) -> None:
        self.cache = self._copy_page_fn(self.cache,
                                        jnp.int32(src), jnp.int32(dst))

    def _write_entries(self, lo: int, hi: int):
        """Logical block-table entries ANY attention layer writes for
        positions [lo, hi) — full-attention layers write position-linear,
        each windowed layer writes its bounded ring's low entries."""
        ents = set()
        for w in self._write_windows:
            ring = ring_pages(w, self._n_tbl, self._page) * self._page
            for p in range(max(lo, hi - ring), hi):
                ents.add((p % ring) // self._page)
        return ents

    def _cow_range(self, slot: int, lo: int, hi: int) -> None:
        """Copy-on-write sweep: before any dispatch that writes positions
        [lo, hi) for ``slot``, fork every shared (refcount > 1) page one of
        those writes would land on — sharers keep the original, this slot
        gets a private device-copied clone."""
        if not self._sharing or lo >= hi:
            return
        changed = False
        owned = self.pages.slot_pages(slot)
        for e in sorted(self._write_entries(lo, hi)):
            if e >= len(owned):
                continue   # unbacked entry → trash-page write (garbage
                           # past the request's final length, never read)
            if self.pages.refcount(owned[e]) <= 1:
                continue
            while True:
                try:
                    old, new = self.pages.fork(slot, e)
                    break
                except PoolExhausted:
                    self._reclaim()
                    if self._sched.slot_request(slot) is None:
                        return
            self._copy_page(old, new)
            changed = True
            owned = self.pages.slot_pages(slot)
        if changed and slot not in self._prefill_ctx:
            # prefilling slots keep their device row zero (the chunk
            # dispatch carries the row explicitly); live slots re-upload
            self._set_table_row(slot, self.pages.slot_pages(slot))

    def release_prefix(self, prefix_id: str) -> bool:
        """Drop a cached prefix — every adapter variant under the id (pages
        return to the free list once no slot maps them).  Refuses while a
        live slot still shares any of them."""
        if not (self.paged and self._sharing):
            return False
        keys = [k for k in self._prefix if k[0] == prefix_id]
        if not keys:
            return False
        for k in keys:
            if self._prefix[k].active > 0:
                raise ValueError(
                    f"prefix {prefix_id!r} is mapped by "
                    f"{self._prefix[k].active} live slot(s) — drain them "
                    f"first")
        for k in keys:
            self.pages.release_ids(self._prefix[k].pages)
            del self._prefix[k]
        self._prefix_tokens.pop(prefix_id, None)
        return True

    def _reclaim(self) -> None:
        """Free pages under pool pressure: drop an idle prefix entry first
        (no live sharers — all its pages come straight back), else preempt
        the NEWEST occupied slot.  Strictly decreases entries + occupied
        slots, so exhaustion handling always terminates."""
        if self._drop_one_idle_prefix():
            return
        victims = self._sched.occupied_slots()
        assert victims, "pool exhausted with no occupied slots"
        self._preempt(max(victims, key=lambda s: self._admit_seq[s]))

    # -- admission ----------------------------------------------------------

    def _admission_gate(self, req: Request) -> bool:
        # adapter residency first: a miss stages the upload and blocks the
        # (FCFS) head until the row is committed — exactly the free-page
        # discipline, applied to bank rows.  Progress is guaranteed: rows
        # are pinned only by active slots, and active slots finish.
        if (self.registry is not None
                and not self.registry.acquire(req.adapter_id)):
            return False
        if not self.paged:
            return True
        if self.paged and self._chunked_path(req):
            pid = ((req.prefix_id, req.adapter_id)
                   if self._sharing and req.prefix_id is not None else None)
            if pid is not None and pid in self._prefix_pending:
                # the prefix is mid-construction in another slot: admitting
                # now would rebuild it — wait (FCFS holds; the builder
                # either captures within a few steps or frees the id)
                return False
            start = 0
            if pid is not None and pid in self._prefix:
                start = self._prefix[pid].n_tokens
            total = len(req.prompt)
            first_end = min(start + (self._chunk_eff
                                     if self._chunking else total), total)
            if req.prefix_len and start == 0:
                first_end = min(first_end, req.prefix_len)
            # fresh pages for the first chunk + one fork margin for a
            # shared boundary page
            need = pages_for(first_end, self._page) - pages_for(
                start, self._page) + (1 if start else 0)
            return self.pages.can_alloc(max(need, 0))
        sb = bucket_len(len(req.prompt), self._page, self.cfg.max_seq_len)
        return self.pages.can_alloc(pages_for(sb, self._page))

    def _next_seq(self) -> int:
        self._seq_counter += 1
        return self._seq_counter

    def _set_table_row(self, slot: int, ids):
        row = np.zeros(self._n_tbl, np.int32)
        row[:len(ids)] = ids
        self._st = self._st.replace(
            block_table=self._st.block_table.at[slot].set(jnp.asarray(row)))

    def _release_slot_pages(self, slot: int):
        self.pages.release(slot)
        pid = self._slot_prefix.pop(slot, None)
        if pid is not None and pid in self._prefix:
            self._prefix[pid].active -= 1
        ctx = self._prefill_ctx.pop(slot, None)
        if ctx is not None and ctx.get("building"):
            # the builder lost its slot before capturing — free the id so
            # the (requeued-at-head) request can rebuild on re-admission
            self._prefix_pending.discard(ctx["building"])
        self._st = self._st.replace(
            block_table=self._st.block_table.at[slot].set(0))
        self._slot_pos[slot] = 0
        self._admit_seq[slot] = -1

    def _preempt(self, slot: int):
        """Page-pool exhaustion: roll the slot's request back to the queue
        head and free its pages.  Generation is deterministic per (seed,
        generation index), so the re-run emits the same tokens."""
        self._sched.preempt(slot)
        self._release_slot_pages(slot)
        self._st = self._st.replace(
            active=self._st.active.at[slot].set(False))
        self._c_preemptions.inc()

    def _ensure_growth(self, lookahead: int):
        """Back positions ``slot_pos .. slot_pos+lookahead-1`` of every
        active slot with real pages, oldest slot first; reclaim (idle
        prefix entries first, then the NEWEST occupied slot) on exhaustion
        — never deadlocks: the pool holds at least one max-length request,
        so the oldest survivor always grows.

        The per-slot reservation is capped at the request's FINAL length
        ``prompt + max_new_tokens``: a speculative k-round batch's lookahead
        (k·γ) can overshoot a nearly-finished request's real footprint, and
        rows committed past its end land on the trash page through the
        block table's all-zero tail anyway (never read — the slot emits
        nothing after its budget).  Without the cap an autosized pool at
        full occupancy preempts live traffic to back garbage
        (regression-tested in tests/test_prefix.py)."""
        order = sorted(self._sched.active_slots(),
                       key=lambda s: self._admit_seq[s])
        for slot in order:
            req = self._sched.slot_request(slot)
            if req is None:
                continue                      # preempted below, earlier
            limit = min(len(req.prompt) + req.max_new_tokens,
                        self.cfg.max_seq_len)
            need = pages_for(min(self._slot_pos[slot] + lookahead, limit),
                             self._page)
            while True:
                try:
                    if self._faults is not None:
                        self._faults.check_alloc()
                    new = self.pages.ensure(slot, need)
                    break
                except PoolExhausted:
                    self._reclaim()
                    if self._sched.slot_request(slot) is None:
                        new = []
                        break
            if new:
                # one device dispatch per grown slot: re-upload the whole
                # row from the allocator's (host-side) page list
                self._set_table_row(slot, self.pages.slot_pages(slot))

    def kv_cache_bytes(self) -> int:
        """Device bytes reserved for attention K/V (the paged pool + block
        table, or the dense per-slot reservation; int8 pools count their
        per-row scale pools too) — what the serving bench compares across
        engines."""
        total = 0
        for stc in self.cache.values():
            for bc in stc.values():
                if "k" in bc:
                    total += sum(bc[n].nbytes for n in bc)
        if self.paged:
            total += self._st.block_table.nbytes
        return total

    def _admit(self, slot: int, req: Request):
        tree = (None if self.registry is None
                else self.registry.adapter_tree(req.adapter_id))
        if self.paged:
            tokens, valid = self._bucketed_prompt(req)
            sb = tokens.shape[1]
            ids = self.pages.alloc(slot, pages_for(sb, self._page))
            self._set_table_row(slot, ids)
            self._slot_pos[slot] = valid
            self._admit_seq[slot] = self._next_seq()
            step = self._paged_prefill_step(sb)
            logits, self.cache = step(self.params, tree, tokens, self.cache,
                                      jnp.asarray(ids, jnp.int32), slot,
                                      valid)
        elif self.cfg.prefill_buckets:
            tokens, valid = self._bucketed_prompt(req)
            logits, self.cache = self._prefill(self.params, tree, tokens,
                                               self.cache, slot, valid)
        else:
            tokens = jnp.asarray(req.prompt[None])
            logits, self.cache = self._prefill(self.params, tree, tokens,
                                               self.cache, slot)
        first = self._first_token(logits[0], req)
        self._activate(slot, req, first)
        self._c_prefill_tokens.inc(len(req.prompt))
        self._stamp_first_token(req)

    @staticmethod
    def _first_token(logits, req: Request):
        if req.temperature <= 0.0:
            return jnp.argmax(logits).astype(jnp.int32)
        # generation index 0 of the same (seed, gen_idx) stream the tick uses
        return jax.random.categorical(
            request_key(req.seed, 0),
            logits / req.temperature).astype(jnp.int32)

    def _finalize(self, slot: int, status: str = STATUS_OK) -> RequestResult:
        """Terminal transition for an occupied slot.  ``status`` defaults
        to a clean completion; deadline expiry and cancellation finalize
        the same way (partial tokens are returned) but carry their own
        status + terminal event.  A still-prefilling slot has generated
        nothing (``slot_generated == 0``) and returns an empty row."""
        req = self._sched.slot_request(slot)
        n = self._sched.slot_generated(slot)
        # the single device→host transfer for this request
        row = (np.asarray(self._st.out_buf[slot, :n]) if n
               else np.zeros(0, np.int32))
        self._st = self._st.replace(
            active=self._st.active.at[slot].set(False))
        if self.paged:
            self._release_slot_pages(slot)
        t_end = time.perf_counter()
        # stage the taxonomy for the scheduler's evict hook — the one
        # choke point every terminal transition reports through
        self._terminal_info[req.uid] = (status, n, t_end)
        self._sched.evict(slot)
        self._c_decode_tokens.inc(max(n - 1, 0))
        return self._result_for(req, n, row, status, t_end)


def _sample(logits, temperature, top_p, rng):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    probs = jax.nn.softmax(logits, axis=-1)
    sorted_idx = jnp.argsort(-probs, axis=-1)
    sorted_p = jnp.take_along_axis(probs, sorted_idx, axis=-1)
    cum = jnp.cumsum(sorted_p, axis=-1)
    keep = cum - sorted_p < top_p
    filt = jnp.where(keep, sorted_p, 0.0)
    filt = filt / jnp.sum(filt, axis=-1, keepdims=True)
    choice = jax.random.categorical(rng, jnp.log(filt + 1e-20), axis=-1)
    return jnp.take_along_axis(sorted_idx, choice[..., None], axis=-1)[..., 0].astype(jnp.int32)


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False

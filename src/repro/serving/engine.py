"""Batched serving engine — the "infer large" half of LoRAM.

Serves the ORIGINAL (large) model with recovered adapters, either merged
(paper default, Eq. 7: W₀ + Bᴿ*Aᴿ*) or unmerged (multi-adapter serving: one
base, several LoRAM-trained adapters hot-swapped per request batch).

Pipeline per request batch: tokenize-stub → prefill (fills KV/SSM caches)
→ greedy/temperature decode loop (jitted one-token step) → detokenize-stub.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.core.recovery import merge_lora
from repro.distributed import sharding
from repro.models.model import Plan, init_cache
from repro.runtime.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_generated)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class ServeEngine:
    def __init__(self, plan: Plan, params: Any, cfg: ServeConfig,
                 lora: Optional[Any] = None, *, lora_scale: float = 2.0,
                 mesh=None):
        self.plan = plan
        self.cfg = cfg
        self.mesh = mesh
        if lora is not None and cfg.merge_adapters:
            params = merge_lora(params, lora, lora_scale)
            lora = None
        self.params = params
        self.lora = lora
        self._prefill = jax.jit(make_prefill_step(
            plan, lora_scale=lora_scale, with_lora=lora is not None))
        self._decode = jax.jit(make_decode_step(
            plan, lora_scale=lora_scale, with_lora=lora is not None),
            donate_argnums=(2 if lora is None else 3,))

    def _call_prefill(self, tokens, cache, frontend=None):
        if self.lora is not None:
            return self._prefill(self.params, self.lora, tokens, cache,
                                 frontend)
        return self._prefill(self.params, tokens, cache, frontend)

    def _call_decode(self, token, cache, pos):
        if self.lora is not None:
            return self._decode(self.params, self.lora, token, cache, pos)
        return self._decode(self.params, token, cache, pos)

    def generate(
        self,
        prompts: np.ndarray,               # (B, S_prompt) int32
        *,
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        top_p: float = 0.95,
        seed: int = 0,
        frontend: Optional[np.ndarray] = None,
    ) -> GenerationResult:
        B = prompts.shape[0]
        ctx = (sharding.use_mesh(self.mesh, False) if self.mesh is not None
               else _null())
        with ctx:
            cache = init_cache(self.plan, B, self.cfg.max_seq_len,
                               jnp.dtype(self.cfg.kv_cache_dtype))
            t0 = time.perf_counter()
            logits, cache, pos = self._call_prefill(
                jnp.asarray(prompts), cache,
                None if frontend is None else jnp.asarray(frontend))
            jax.block_until_ready(logits)
            t1 = time.perf_counter()

            rng = jax.random.PRNGKey(seed)
            out = []
            tok = _sample(logits, temperature, top_p, rng)
            out.append(np.asarray(tok))
            for i in range(1, max_new_tokens):
                rng = jax.random.fold_in(rng, i)
                logits, cache = self._call_decode(
                    tok, cache, jnp.asarray(pos + i - 1, jnp.int32))
                tok = _sample(logits, temperature, top_p, rng)
                out.append(np.asarray(tok))
            jax.block_until_ready(tok)
            t2 = time.perf_counter()
        gen = np.stack(out, axis=1)
        return GenerationResult(
            tokens=gen, prefill_s=t1 - t0, decode_s=t2 - t1,
            tokens_per_s=B * max_new_tokens / max(t2 - t1, 1e-9))


def _sample(logits, temperature, top_p, rng):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    probs = jax.nn.softmax(logits, axis=-1)
    sorted_idx = jnp.argsort(-probs, axis=-1)
    sorted_p = jnp.take_along_axis(probs, sorted_idx, axis=-1)
    cum = jnp.cumsum(sorted_p, axis=-1)
    keep = cum - sorted_p < top_p
    filt = jnp.where(keep, sorted_p, 0.0)
    filt = filt / jnp.sum(filt, axis=-1, keepdims=True)
    choice = jax.random.categorical(rng, jnp.log(filt + 1e-20), axis=-1)
    return jnp.take_along_axis(sorted_idx, choice[..., None], axis=-1)[..., 0].astype(jnp.int32)


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False

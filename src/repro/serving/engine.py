"""Serving engines — the "infer large" half of LoRAM.

Serves the ORIGINAL (large) model with recovered adapters, either merged
(paper default, Eq. 7: W₀ + Bᴿ*Aᴿ*) or unmerged (multi-adapter serving: one
base, several LoRAM-trained adapters).

Two engines:

* :class:`ServeEngine` — the synchronous single-batch reference path: one
  prefill for the whole batch, then a lock-step decode loop.  Every request
  in the batch shares one adapter and one prompt length.

* :class:`ContinuousServeEngine` — continuous batching over a fixed slot
  table (``ServeConfig.max_slots``): requests are admitted into free slots
  the moment one opens (per-slot prefill insertion), every decode tick
  advances all active slots at their own positions, and each slot routes
  through its own adapter via the stacked bank
  (:class:`repro.serving.adapters.AdapterRegistry`).  The jitted one-token
  decode step has a fixed shape — slot count, cache, id/pos vectors — so XLA
  compiles it exactly once and never recompiles mid-flight; free slots decode
  masked garbage that nothing reads.  Generated tokens accumulate on device
  and transfer to the host once per request, at eviction.

Cache layouts (``ServeConfig.kv_paging``):

* dense (default): every slot reserves a ``max_seq_len`` K/V buffer per
  attention layer — HBM scales with ``max_slots × max_seq_len`` no matter
  how short the traffic actually is.
* paged: attention K/V lives in a global pool of fixed-size pages indexed
  through a per-slot block table (part of the jitted tick state — shapes
  still never change).  Admission is gated on free PAGES, decode growth
  allocates a page per crossed boundary, pool exhaustion preempts the
  newest slot (requeued at the queue head — deterministic generation makes
  the re-run emit identical tokens), and eviction returns pages to the free
  list.  See ``repro.serving.pages``.  SSM/conv state stays dense (O(1) per
  slot).

Prompts are padded to power-of-two buckets (``ServeConfig.prefill_buckets``)
so prefill compiles O(log max_seq_len) variants instead of one per distinct
prompt length; masked cache writes, frozen recurrent state and lossless MoE
routing past the real length keep bucketed output exactly equal to unpadded
(see :func:`repro.models.model.prefill`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterator, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.core.recovery import merge_lora
from repro.distributed import sharding
from repro.models.model import Plan, init_cache, init_paged_cache
from repro.runtime.steps import (make_decode_step, make_multi_adapter_decode_step,
                                 make_paged_prefill_into_slot,
                                 make_prefill_into_slot, make_prefill_step,
                                 request_key)
from repro.serving.adapters import AdapterRegistry
from repro.serving.pages import (PageAllocator, PoolExhausted, bucket_len,
                                 pages_for)
from repro.serving.scheduler import Request, RequestResult, Scheduler


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_generated)
    prefill_s: float
    decode_s: float
    tokens_per_s: float           # end-to-end: all generated tokens / total time
    prefill_tokens_per_s: float   # prompt tokens through prefill
    decode_tokens_per_s: float    # decode-loop tokens over the decode window only


class ServeEngine:
    """Synchronous single-batch engine (the pre-scheduler reference path)."""

    def __init__(self, plan: Plan, params: Any, cfg: ServeConfig,
                 lora: Optional[Any] = None, *, lora_scale: float = 2.0,
                 mesh=None):
        self.plan = plan
        self.cfg = cfg
        self.mesh = mesh
        if lora is not None and cfg.merge_adapters:
            params = merge_lora(params, lora, lora_scale)
            lora = None
        self.params = params
        self.lora = lora
        self._prefill = jax.jit(make_prefill_step(
            plan, lora_scale=lora_scale, with_lora=lora is not None))
        self._decode = jax.jit(make_decode_step(
            plan, lora_scale=lora_scale, with_lora=lora is not None),
            donate_argnums=(2 if lora is None else 3,))

    def _call_prefill(self, tokens, cache, frontend=None):
        if self.lora is not None:
            return self._prefill(self.params, self.lora, tokens, cache,
                                 frontend)
        return self._prefill(self.params, tokens, cache, frontend)

    def _call_decode(self, token, cache, pos):
        if self.lora is not None:
            return self._decode(self.params, self.lora, token, cache, pos)
        return self._decode(self.params, token, cache, pos)

    def generate(
        self,
        prompts: np.ndarray,               # (B, S_prompt) int32
        *,
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        top_p: float = 0.95,
        seed: int = 0,
        frontend: Optional[np.ndarray] = None,
    ) -> GenerationResult:
        B, S_prompt = prompts.shape
        ctx = (sharding.use_mesh(self.mesh, False) if self.mesh is not None
               else _null())
        with ctx:
            cache = init_cache(self.plan, B, self.cfg.max_seq_len,
                               jnp.dtype(self.cfg.kv_cache_dtype))
            t0 = time.perf_counter()
            logits, cache, pos = self._call_prefill(
                jnp.asarray(prompts), cache,
                None if frontend is None else jnp.asarray(frontend))
            jax.block_until_ready(logits)
            t1 = time.perf_counter()

            rng = jax.random.PRNGKey(seed)
            # tokens accumulate on device; one transfer at the end (a
            # per-token np.asarray would force a host sync every step)
            out_buf = jnp.zeros((B, max_new_tokens), jnp.int32)
            tok = _sample(logits, temperature, top_p, rng)
            out_buf = out_buf.at[:, 0].set(tok)
            for i in range(1, max_new_tokens):
                rng = jax.random.fold_in(rng, i)
                logits, cache = self._call_decode(
                    tok, cache, jnp.asarray(pos + i - 1, jnp.int32))
                tok = _sample(logits, temperature, top_p, rng)
                out_buf = out_buf.at[:, i].set(tok)
            jax.block_until_ready(out_buf)
            t2 = time.perf_counter()
        gen = np.asarray(out_buf)
        # honest accounting: the first token comes out of prefill, so the
        # decode window covers only max_new_tokens - 1 steps
        decode_toks = B * max(max_new_tokens - 1, 0)
        return GenerationResult(
            tokens=gen, prefill_s=t1 - t0, decode_s=t2 - t1,
            tokens_per_s=B * max_new_tokens / max(t2 - t0, 1e-9),
            prefill_tokens_per_s=B * S_prompt / max(t1 - t0, 1e-9),
            decode_tokens_per_s=decode_toks / max(t2 - t1, 1e-9))


class ContinuousServeEngine:
    """Continuous-batching, multi-adapter engine (``submit`` / ``step`` /
    ``stream``)."""

    def __init__(self, plan: Plan, params: Any, cfg: ServeConfig,
                 registry: Optional[AdapterRegistry] = None, *,
                 lora_scale: float = 2.0, mesh=None):
        if plan.cfg.family == "encdec":
            raise NotImplementedError(
                "continuous batching does not cover encoder-decoder "
                "frontends yet — use ServeEngine")
        self.plan = plan
        self.params = params
        self.cfg = cfg
        self.registry = registry
        self.mesh = mesh
        if registry is not None and registry.max_adapters != cfg.max_adapters:
            raise ValueError(
                f"ServeConfig.max_adapters={cfg.max_adapters} does not match "
                f"the registry's capacity ({registry.max_adapters})")
        S = cfg.max_slots
        self._sched = Scheduler(S)
        self._n_ticks = 0
        self._lora_scale = lora_scale

        # ---- paged KV cache plumbing (ServeConfig.kv_paging) ----
        self.paged = cfg.kv_paging
        self._page = cfg.kv_page_size
        self._n_tbl = pages_for(cfg.max_seq_len, self._page) if self.paged else 0
        if self.paged:
            n_pages = cfg.kv_pages or (S * self._n_tbl + 1)
            if n_pages - 1 < self._n_tbl:
                raise ValueError(
                    f"kv_pages={n_pages} cannot back one max-length request "
                    f"({self._n_tbl} pages + the trash page) — the paged "
                    f"engine would preempt forever")
            self.pages = PageAllocator(n_pages, self._page, self._n_tbl, S)
            self._prefill_steps: Dict[int, Any] = {}    # bucket → jitted step
            self._slot_pos = [0] * S        # next write position per slot
            self._admit_seq = [-1] * S      # admission order (newest preempts)
            self._seq_counter = 0
            self.n_preemptions = 0
        else:
            self._prefill = jax.jit(
                make_prefill_into_slot(plan, lora_scale=lora_scale,
                                       bucketed=cfg.prefill_buckets),
                donate_argnums=(3,))

        decode = make_multi_adapter_decode_step(plan, lora_scale=lora_scale,
                                                paged=self.paged)
        paged = self.paged

        def make_tick(sampling: bool):
            def tick(params_, bank, cache, st):
                if paged:
                    logits, cache = decode(params_, bank, st["last_tok"],
                                           cache, st["pos"],
                                           st["adapter_ids"],
                                           st["block_table"])
                else:
                    logits, cache = decode(params_, bank, st["last_tok"],
                                           cache, st["pos"],
                                           st["adapter_ids"])
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                if sampling:
                    # key = (request seed, generation index): sampling is
                    # reproducible per request no matter how the scheduler
                    # interleaved it with other traffic
                    keys = jax.vmap(request_key)(st["seeds"], st["gen_idx"])
                    temp = jnp.maximum(st["temps"], 1e-6)[:, None]
                    sampled = jax.vmap(jax.random.categorical)(
                        keys, logits / temp).astype(jnp.int32)
                    tok = jnp.where(st["temps"] > 0.0, sampled, tok)
                act = st["active"]
                tok = jnp.where(act, tok, st["last_tok"])
                step1 = act.astype(jnp.int32)
                bidx = jnp.arange(S)
                gi = jnp.minimum(st["gen_idx"], st["out_buf"].shape[1] - 1)
                cur = st["out_buf"][bidx, gi]
                out_buf = st["out_buf"].at[bidx, gi].set(
                    jnp.where(act, tok, cur))
                new_st = dict(st)       # carries block_table when paged
                new_st.update(
                    last_tok=tok,
                    pos=st["pos"] + step1,
                    gen_idx=st["gen_idx"] + step1,
                    out_buf=out_buf,
                )
                return cache, new_st

            return jax.jit(tick, donate_argnums=(2, 3))

        # all-greedy traffic skips the per-slot rng/categorical work entirely
        self._tick_greedy = make_tick(False)
        self._tick_sample = make_tick(True)
        self._n_hot = 0    # in-flight/queued requests with temperature > 0

        def admit_update(st, slot, first, pos0, aid, temp, seed):
            out = dict(st)              # carries block_table when paged
            out.update(
                last_tok=st["last_tok"].at[slot].set(first),
                pos=st["pos"].at[slot].set(pos0),
                active=st["active"].at[slot].set(True),
                adapter_ids=st["adapter_ids"].at[slot].set(aid),
                temps=st["temps"].at[slot].set(temp),
                seeds=st["seeds"].at[slot].set(seed),
                gen_idx=st["gen_idx"].at[slot].set(1),
                out_buf=st["out_buf"].at[slot, 0].set(first),
            )
            return out

        # one fused dispatch per admission instead of seven .at[].set calls
        self._admit_update = jax.jit(admit_update, donate_argnums=(0,))

        if self.paged:
            self.cache = init_paged_cache(plan, S, self.pages.n_pages,
                                          self._page,
                                          jnp.dtype(cfg.kv_cache_dtype))
        else:
            self.cache = init_cache(plan, S, cfg.max_seq_len,
                                    jnp.dtype(cfg.kv_cache_dtype))
        self._st: Dict[str, jax.Array] = {
            "last_tok": jnp.zeros((S,), jnp.int32),
            "pos": jnp.zeros((S,), jnp.int32),
            "active": jnp.zeros((S,), bool),
            "adapter_ids": jnp.zeros((S,), jnp.int32),
            "temps": jnp.zeros((S,), jnp.float32),
            "seeds": jnp.zeros((S,), jnp.int32),
            "gen_idx": jnp.zeros((S,), jnp.int32),
            "out_buf": jnp.zeros((S, cfg.max_new_tokens), jnp.int32),
        }
        if self.paged:
            # all-zero rows route free slots' garbage writes to the trash page
            self._st["block_table"] = jnp.zeros((S, self._n_tbl), jnp.int32)
        # aggregate counters for benchmarks / monitoring
        self.n_prefill_tokens = 0
        self.n_decode_tokens = 0
        self.n_completed = 0

    # -- intake -------------------------------------------------------------

    def submit(self, prompt: np.ndarray, *, max_new_tokens: int = 32,
               adapter: Union[str, int, None] = None,
               temperature: float = 0.0, seed: int = 0,
               speculative: bool = True) -> int:
        """Enqueue one request; returns its uid.  Non-blocking — call
        :meth:`step` (or :meth:`run` / :meth:`stream`) to make progress.
        ``speculative`` is honored by :class:`SpeculativeServeEngine` only
        (per-request opt-out of draft-then-verify); this engine ignores it."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1 or max_new_tokens > self.cfg.max_new_tokens:
            raise ValueError(
                f"max_new_tokens must be in [1, {self.cfg.max_new_tokens}]")
        if len(prompt) + max_new_tokens > self.cfg.max_seq_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_seq_len={self.cfg.max_seq_len}")
        aid = 0
        if self.registry is not None:
            aid = self.registry.resolve(adapter)
        elif adapter is not None:
            raise ValueError("adapter given but engine has no registry")
        req = Request(uid=self._sched.new_uid(), prompt=prompt,
                      max_new_tokens=max_new_tokens, adapter=adapter
                      if isinstance(adapter, str) else None,
                      adapter_id=aid, temperature=temperature, seed=seed,
                      speculative=speculative)
        if temperature > 0.0:
            self._n_hot += 1
        return self._sched.submit(req)

    # -- progress -----------------------------------------------------------

    def step(self) -> List[RequestResult]:
        """Admit whatever fits, run one decode tick, return newly completed
        requests (empty list if nothing finished this tick)."""
        ctx = (sharding.use_mesh(self.mesh, False) if self.mesh is not None
               else _null())
        done: List[RequestResult] = []
        with ctx:
            if self.paged:
                # grow EXISTING slots before admitting: otherwise a freshly
                # admitted request is always the newest slot and the first
                # preemption victim, wasting its just-run prefill
                self._ensure_growth(lookahead=1)
            while True:
                adm = self._sched.next_admission(
                    gate=self._admission_gate if self.paged else None)
                if adm is None:
                    break
                self._admit(*adm)
            # single-token requests finish at prefill, before any tick
            for slot in self._sched.completed_slots():
                done.append(self._finalize(slot))
            if self.paged:
                # back the next write position of every active slot —
                # including a just-admitted slot whose prompt filled its
                # bucket exactly — with a real page BEFORE the tick
                self._ensure_growth(lookahead=1)
            active = self._sched.active_slots()
            if active:
                tick = self._tick_sample if self._n_hot else self._tick_greedy
                # read the bank through the registry every tick so add() /
                # hot-swap after construction takes effect (same shapes →
                # no recompile)
                bank = None if self.registry is None else self.registry.bank
                self.cache, self._st = tick(
                    self.params, bank, self.cache, self._st)
                self._n_ticks += 1
                if self.paged:
                    for slot in active:
                        self._slot_pos[slot] += 1
                for slot in self._sched.tick():
                    done.append(self._finalize(slot))
        return done

    def run(self) -> Dict[int, RequestResult]:
        """Drain the queue completely; returns {uid: result}."""
        out: Dict[int, RequestResult] = {}
        for res in self.stream():
            out[res.uid] = res
        return out

    def stream(self) -> Iterator[RequestResult]:
        """Yield results as requests complete (streaming consumption)."""
        while self._sched.has_work:
            yield from self.step()

    @property
    def pending(self) -> int:
        return self._sched.queued + len(self._sched.active_slots())

    # -- internals ----------------------------------------------------------

    def _bucketed_prompt(self, req: Request):
        """(tokens (1, Sb), valid_len) — the prompt right-padded to its
        power-of-two bucket.  Paged mode always buckets (scratch prefill rows
        scatter into whole pages); dense mode buckets when configured."""
        n = len(req.prompt)
        sb = bucket_len(n, self._page if self.paged else 1,
                        self.cfg.max_seq_len)
        padded = np.zeros(sb, np.int32)
        padded[:n] = req.prompt
        return jnp.asarray(padded[None]), n

    def _paged_prefill_step(self, bucket: int):
        step = self._prefill_steps.get(bucket)
        if step is None:
            step = jax.jit(
                make_paged_prefill_into_slot(self.plan, bucket, self._page,
                                             self._n_tbl,
                                             lora_scale=self._lora_scale),
                donate_argnums=(3,))
            self._prefill_steps[bucket] = step
        return step

    def _admission_gate(self, req: Request) -> bool:
        sb = bucket_len(len(req.prompt), self._page, self.cfg.max_seq_len)
        return self.pages.can_alloc(pages_for(sb, self._page))

    def _next_seq(self) -> int:
        self._seq_counter += 1
        return self._seq_counter

    def _set_table_row(self, slot: int, ids):
        row = np.zeros(self._n_tbl, np.int32)
        row[:len(ids)] = ids
        self._st["block_table"] = self._st["block_table"].at[slot].set(
            jnp.asarray(row))

    def _release_slot_pages(self, slot: int):
        self.pages.release(slot)
        self._st["block_table"] = self._st["block_table"].at[slot].set(0)
        self._slot_pos[slot] = 0
        self._admit_seq[slot] = -1

    def _preempt(self, slot: int):
        """Page-pool exhaustion: roll the slot's request back to the queue
        head and free its pages.  Generation is deterministic per (seed,
        generation index), so the re-run emits the same tokens."""
        self._sched.preempt(slot)
        self._release_slot_pages(slot)
        self._st["active"] = self._st["active"].at[slot].set(False)
        self.n_preemptions += 1

    def _ensure_growth(self, lookahead: int):
        """Back positions ``slot_pos .. slot_pos+lookahead-1`` of every
        active slot with real pages, oldest slot first; preempt the NEWEST
        active slot on exhaustion (never deadlocks: the pool holds at least
        one max-length request, so the oldest survivor always grows)."""
        order = sorted(self._sched.active_slots(),
                       key=lambda s: self._admit_seq[s])
        for slot in order:
            if self._sched.slot_request(slot) is None:
                continue                      # preempted below, earlier
            need = pages_for(min(self._slot_pos[slot] + lookahead,
                                 self.cfg.max_seq_len), self._page)
            while True:
                try:
                    new = self.pages.ensure(slot, need)
                    break
                except PoolExhausted:
                    victim = max(self._sched.active_slots(),
                                 key=lambda s: self._admit_seq[s])
                    self._preempt(victim)
                    if victim == slot:
                        new = []
                        break
            if new:
                # one device dispatch per grown slot: re-upload the whole
                # row from the allocator's (host-side) page list
                self._set_table_row(slot, self.pages.slot_pages(slot))

    def kv_cache_bytes(self) -> int:
        """Device bytes reserved for attention K/V (the paged pool + block
        table, or the dense per-slot reservation) — what the serving bench
        compares across engines."""
        total = 0
        for stc in self.cache.values():
            for bc in stc.values():
                if "k" in bc:
                    total += bc["k"].nbytes + bc["v"].nbytes
        if self.paged:
            total += self._st["block_table"].nbytes
        return total

    def _admit(self, slot: int, req: Request):
        tree = (None if self.registry is None
                else self.registry.adapter_tree(req.adapter_id))
        if self.paged:
            tokens, valid = self._bucketed_prompt(req)
            sb = tokens.shape[1]
            ids = self.pages.alloc(slot, pages_for(sb, self._page))
            self._set_table_row(slot, ids)
            self._slot_pos[slot] = valid
            self._admit_seq[slot] = self._next_seq()
            step = self._paged_prefill_step(sb)
            logits, self.cache = step(self.params, tree, tokens, self.cache,
                                      jnp.asarray(ids, jnp.int32), slot,
                                      valid)
        elif self.cfg.prefill_buckets:
            tokens, valid = self._bucketed_prompt(req)
            logits, self.cache = self._prefill(self.params, tree, tokens,
                                               self.cache, slot, valid)
        else:
            tokens = jnp.asarray(req.prompt[None])
            logits, self.cache = self._prefill(self.params, tree, tokens,
                                               self.cache, slot)
        first = self._first_token(logits[0], req)
        self._st = self._admit_update(
            self._st, slot, first, len(req.prompt), req.adapter_id,
            req.temperature, req.seed)
        self.n_prefill_tokens += len(req.prompt)

    @staticmethod
    def _first_token(logits, req: Request):
        if req.temperature <= 0.0:
            return jnp.argmax(logits).astype(jnp.int32)
        # generation index 0 of the same (seed, gen_idx) stream the tick uses
        return jax.random.categorical(
            request_key(req.seed, 0),
            logits / req.temperature).astype(jnp.int32)

    def _finalize(self, slot: int) -> RequestResult:
        req = self._sched.slot_request(slot)
        n = self._sched.slot_generated(slot)
        # the single device→host transfer for this request
        row = np.asarray(self._st["out_buf"][slot, :n])
        self._st["active"] = self._st["active"].at[slot].set(False)
        if self.paged:
            self._release_slot_pages(slot)
        req_evicted = self._sched.evict(slot)
        if req_evicted.temperature > 0.0:
            self._n_hot -= 1
        self.n_decode_tokens += n - 1
        self.n_completed += 1
        name = (self.registry.name_of(req.adapter_id)
                if self.registry is not None else None)
        return RequestResult(uid=req.uid, tokens=row, adapter=name,
                             prompt_len=len(req.prompt), n_generated=n)


def _sample(logits, temperature, top_p, rng):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    probs = jax.nn.softmax(logits, axis=-1)
    sorted_idx = jnp.argsort(-probs, axis=-1)
    sorted_p = jnp.take_along_axis(probs, sorted_idx, axis=-1)
    cum = jnp.cumsum(sorted_p, axis=-1)
    keep = cum - sorted_p < top_p
    filt = jnp.where(keep, sorted_p, 0.0)
    filt = filt / jnp.sum(filt, axis=-1, keepdims=True)
    choice = jax.random.categorical(rng, jnp.log(filt + 1e-20), axis=-1)
    return jnp.take_along_axis(sorted_idx, choice[..., None], axis=-1)[..., 0].astype(jnp.int32)


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False

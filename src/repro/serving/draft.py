"""Draft models for speculative serving — LoRAM's pruned model as proposer.

The paper's central artifact is a structurally pruned "train small" model
whose :class:`~repro.core.pruning.PruneSpec` maps every kept channel back
into the full model.  That same artifact is a ready-made DRAFT model for
speculative decoding: it is a real (smaller) transformer over the same
vocabulary, and the adapters trained on it run natively at pruned widths —
no recovery needed on the draft side.  A :class:`DraftModel` bundles:

  * the pruned plan + pruned frozen base (``LoRAMSetup.small_plan`` /
    ``small_params`` — possibly aligned and/or NF4-quantized), and
  * optionally an :class:`~repro.serving.adapters.AdapterRegistry` whose bank
    stacks the PRE-RECOVERY (pruned-width) adapter trees, routed per slot by
    the same ``adapter_id`` the target registry uses.

Correctness never depends on the draft: the target's acceptance-rejection
verify makes the output distribution exactly the target model's (and
token-identical under greedy) for ANY proposer.  The draft only sets the
acceptance rate — i.e. the speedup.  A draft without adapters (``registry
= None``) therefore still serves adapter traffic correctly, just with more
rejections on adapter-heavy streams.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

from repro.models.model import Plan
from repro.serving.adapters import AdapterRegistry

PyTree = Any


@dataclasses.dataclass
class DraftModel:
    """The pruned proposer: small plan, frozen pruned base, optional bank of
    pruned-width adapters (ids MUST mirror the target registry's)."""

    plan: Plan
    params: Any
    registry: Optional[AdapterRegistry] = None

    @property
    def bank(self) -> Optional[PyTree]:
        return None if self.registry is None else self.registry.bank

    def with_params(self, params: PyTree) -> "DraftModel":
        """The same draft with re-placed params — how a meshed
        :class:`~repro.serving.speculative.SpeculativeServeEngine` swaps in
        the TP-sharded copy (``sharding.shard_serving``; pruned widths that
        don't divide the ``model`` axis replicate).  The registry — and so
        the stacked adapter bank — stays shared with the original."""
        return dataclasses.replace(self, params=params)

    def add(self, name: str, small_lora: PyTree) -> int:
        """Register a pruned-width adapter under ``name``.  Register adapters
        in the SAME ORDER as on the target registry so ids line up."""
        if self.registry is None:
            raise ValueError("draft model was built without an adapter bank")
        return self.registry.add(name, small_lora)

    def adapter_tree(self, adapter: Union[str, int, None]) -> Optional[PyTree]:
        if self.registry is None:
            return None
        aid = adapter if isinstance(adapter, int) else None
        if aid is not None and not self.registry.has_id(aid):
            # target knows more adapters than the draft — fall back to the
            # pruned base (correct, just a worse proposer for that stream).
            # The decode loop needs no such guard: a row the draft never
            # registered is zeros, and a zero LoRA delta IS the base route.
            return None
        return self.registry.adapter_tree(adapter)


def build_draft(small_plan: Plan, small_params, *,
                adapter_template: Optional[PyTree] = None,
                max_adapters: int = 0, bank_slots: Optional[int] = None,
                rank_buckets: int = 1) -> DraftModel:
    """Assemble a :class:`DraftModel` from the pruned ("train small") plan and
    params.  ``adapter_template`` is any pruned-width adapter tree (e.g.
    ``LoRAMSetup.lora0``) — required when ``max_adapters > 0``.
    ``bank_slots``/``rank_buckets`` must mirror the TARGET registry's (the
    speculative engine puts the two banks in residency lockstep)."""
    registry = None
    if max_adapters:
        if adapter_template is None:
            raise ValueError("max_adapters > 0 requires an adapter_template")
        registry = AdapterRegistry(adapter_template, max_adapters,
                                   bank_slots=bank_slots,
                                   rank_buckets=rank_buckets)
    return DraftModel(small_plan, small_params, registry)


def draft_from_setup(setup, *, max_adapters: int = 0,
                     bank_slots: Optional[int] = None,
                     rank_buckets: int = 1) -> DraftModel:
    """Build the draft straight from a :class:`~repro.core.loram.LoRAMSetup` —
    the exact artifacts the online training stage already has in memory."""
    return build_draft(setup.small_plan, setup.small_params,
                       adapter_template=setup.lora0,
                       max_adapters=max_adapters, bank_slots=bank_slots,
                       rank_buckets=rank_buckets)

"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    t = jnp.asarray(step, jnp.float32)
    warm = t / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((t - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(t < warmup_steps, warm, cos)


def constant(step, *, peak_lr: float, **_):
    return jnp.full((), peak_lr, jnp.float32)

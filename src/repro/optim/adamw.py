"""Pure-JAX AdamW with global-norm clipping.

Moments are kept in fp32 regardless of param dtype (bf16-safe).  For LoRA
training the optimizer state covers only the adapter tree — a few MB even for
a 70B base — which is the property LoRAM exploits to make multi-pod DP
nearly free (see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    params, grads, state: AdamWState, *, lr, b1: float = 0.9, b2: float = 0.999,
    eps: float = 1e-8, wd: float = 0.0, clip: float = 0.0,
):
    if clip:
        grads, _ = clip_by_global_norm(grads, clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        update = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        if wd:
            update = update + wd * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - jnp.asarray(lr, jnp.float32) * update
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([n[0] for n in new])
    new_m = tdef.unflatten([n[1] for n in new])
    new_v = tdef.unflatten([n[2] for n in new])
    return new_p, AdamWState(step, new_m, new_v)

"""Low-overhead per-tick tracer: host wall-clock spans in a ring buffer.

The serving engines wrap each scheduling phase — ``admit``, ``chunk``
(chunked-prefill dispatch), ``tick`` (decode), ``round`` (speculative
draft→verify), ``cow`` (copy-on-write sweep) — in :meth:`TickTracer.span`.
Each span records host wall-clock start and duration into a bounded
``deque`` (old spans fall off; telemetry must never grow with uptime), and
also opens a :class:`jax.profiler.TraceAnnotation` with the same
``serve/<name>`` label so the host spans line up with XLA device traces when
a profile is being captured.

Host wall-clock measures DISPATCH time — the engines never block their hot
loop, so a span closes when the jitted call returns, not when the device
finishes.  For latency work that needs device-complete timing, construct the
tracer with ``sync_fn`` (and ``ServeConfig.obs_device_sync=True``): every
span then ends with a ``block_until_ready`` on the engine's tick state,
trading pipelining for honest per-phase numbers — the same trade
``benchmarks/serve_bench.run_latency`` makes explicitly.

A disabled tracer (``enabled=False``) costs one attribute check per span —
the on/off token-identity tests in ``tests/test_obs.py`` pin that neither
mode can perturb engine output.
"""
from __future__ import annotations

import contextlib
import time
from collections import deque
from typing import Any, Callable, Dict, List, NamedTuple, Optional

try:                                  # the annotation is cosmetic; the
    from jax.profiler import TraceAnnotation   # tracer works without jax
except Exception:                     # pragma: no cover - jax is baked in
    TraceAnnotation = None


class Span(NamedTuple):
    name: str
    t0: float          # host clock at span open (time.perf_counter domain)
    dur_s: float


class TickTracer:
    def __init__(self, capacity: int = 512, *, enabled: bool = True,
                 sync_fn: Optional[Callable[[], Any]] = None,
                 clock: Callable[[], float] = time.perf_counter):
        assert capacity >= 1
        self.capacity = capacity
        self.enabled = enabled
        self.sync_fn = sync_fn
        self.clock = clock
        self._spans: deque = deque(maxlen=capacity)
        self.n_recorded = 0            # total ever, incl. those evicted

    @contextlib.contextmanager
    def span(self, name: str):
        if not self.enabled:
            yield
            return
        ann = (TraceAnnotation(f"serve/{name}")
               if TraceAnnotation is not None else contextlib.nullcontext())
        t0 = self.clock()
        try:
            with ann:
                yield
        finally:
            if self.sync_fn is not None:
                self.sync_fn()
            self._spans.append(Span(name, t0, self.clock() - t0))
            self.n_recorded += 1

    # -- introspection -------------------------------------------------------

    def spans(self, name: Optional[str] = None) -> List[Span]:
        if name is None:
            return list(self._spans)
        return [s for s in self._spans if s.name == name]

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-name aggregate over the spans still in the ring:
        ``{name: {count, total_s, mean_s, max_s, last_s}}``."""
        agg: Dict[str, Dict[str, float]] = {}
        for s in self._spans:
            a = agg.setdefault(s.name, {"count": 0, "total_s": 0.0,
                                        "max_s": 0.0, "last_s": 0.0})
            a["count"] += 1
            a["total_s"] += s.dur_s
            a["max_s"] = max(a["max_s"], s.dur_s)
            a["last_s"] = s.dur_s
        for a in agg.values():
            a["mean_s"] = a["total_s"] / a["count"]
        return agg

    def clear(self) -> None:
        self._spans.clear()
        self.n_recorded = 0

"""Request-lifecycle event log: one record per scheduling transition.

The serving engines emit a bounded stream of host-side records tracing each
request from ``submit`` through ``admit``, ``prefix_hit``,
``prefill_chunk`` × N, ``first_token``, ``preempt`` (with implicit
requeue-at-head), ``stall`` (watchdog), to ``complete`` — each carrying the
uid plus whatever attribution the engine knows at that instant (slot,
adapter, prefix hit, pages held, tokens).  Under the resilience layer a
request may instead terminate as ``timeout`` / ``shed`` / ``cancel`` /
``failed`` (one terminal record per uid, mirroring
``RequestResult.status``), and the engine itself logs ``degrade`` /
``restore`` transitions with uid=-1.  This is what lets a TTFT or p99
regression be blamed on SCHEDULING (admission waited on pages; prefill
yielded to decode ticks; a preemption restarted the prompt) instead of being
re-derived from benchmark harness stamps after the fact.

Records live in a ring (``capacity``; old records drop and are counted in
``n_dropped``) and can simultaneously stream to a JSONL file (``path``) —
one ``json.dumps`` per line, flushed on :meth:`close`, so a crashed run
still leaves its tail on disk.

Timestamps are ``time.perf_counter()`` floats in the SAME clock domain as
the engines' TTFT stamps — :meth:`derive_ttft` (``first_token.t`` minus
``submit.t``) therefore reproduces ``RequestResult.ttft_s`` exactly, which
``tests/test_obs.py`` pins per request.
"""
from __future__ import annotations

import json
import threading
import time
from collections import Counter as _TallyCounter
from collections import deque
from typing import Any, Dict, List, Optional

# the full lifecycle vocabulary — exported so tests and the snapshot schema
# agree on what may appear in a record's "kind".  The second line is the
# resilience layer (repro.serving.resilience): "complete"/"timeout"/"shed"/
# "cancel"/"failed" are the TERMINAL kinds — every submitted uid gets
# exactly one of them; "degrade" (ladder level change) and "restore"
# (snapshot-and-restart) are engine-scoped records carrying uid=-1, as are
# "adapter_upload" (a host tree committed into a device bank row) and
# "adapter_evict" (a refcount-0 row zeroed) from the adapter residency
# manager (repro.serving.adapters.AdapterResidency)
EVENT_KINDS = ("submit", "admit", "prefix_hit", "prefill_chunk",
               "first_token", "preempt", "stall", "complete",
               "timeout", "shed", "cancel", "failed", "degrade", "restore",
               "adapter_upload", "adapter_evict")


class EventLog:
    def __init__(self, capacity: int = 4096, *, enabled: bool = True,
                 path: Optional[str] = None,
                 clock=time.perf_counter):
        assert capacity >= 1
        self.capacity = capacity
        self.enabled = enabled
        self.clock = clock
        self._records: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self.n_dropped = 0
        self._counts: _TallyCounter = _TallyCounter()
        self._file = open(path, "w") if (enabled and path) else None

    def emit(self, kind: str, uid: int, *, t: Optional[float] = None,
             **fields: Any) -> None:
        """Append one record.  ``t`` lets the caller reuse a stamp it
        already took (the engines pass their TTFT stamps through, so the
        event log and ``RequestResult`` can never disagree)."""
        if not self.enabled:
            return
        assert kind in EVENT_KINDS, kind
        rec = {"t": self.clock() if t is None else t, "kind": kind,
               "uid": uid, **fields}
        with self._lock:
            if len(self._records) == self.capacity:
                self.n_dropped += 1
            self._records.append(rec)
            self._counts[kind] += 1
            if self._file is not None:
                self._file.write(json.dumps(rec) + "\n")

    # -- queries -------------------------------------------------------------

    def records(self, uid: Optional[int] = None,
                kind: Optional[str] = None) -> List[Dict[str, Any]]:
        with self._lock:
            recs = list(self._records)
        if uid is not None:
            recs = [r for r in recs if r["uid"] == uid]
        if kind is not None:
            recs = [r for r in recs if r["kind"] == kind]
        return recs

    def counts(self) -> Dict[str, int]:
        """Total records emitted per kind (including any that have since
        fallen off the ring) — the counter-vs-event-log consistency hook."""
        with self._lock:
            return dict(self._counts)

    def _first_t(self, uid: int, kind: str) -> Optional[float]:
        for r in self.records(uid=uid, kind=kind):
            return r["t"]
        return None

    def derive_ttft(self, uid: int) -> Optional[float]:
        """``first_token.t - submit.t`` from the ring (None if either record
        dropped or never happened)."""
        t0 = self._first_t(uid, "submit")
        t1 = self._first_t(uid, "first_token")
        if t0 is None or t1 is None:
            return None
        return t1 - t0

    def derive_latency(self, uid: int) -> Optional[float]:
        t0 = self._first_t(uid, "submit")
        t1 = self._first_t(uid, "complete")
        if t0 is None or t1 is None:
            return None
        return t1 - t0

    # -- lifecycle -----------------------------------------------------------

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
            self._counts.clear()
            self.n_dropped = 0

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None

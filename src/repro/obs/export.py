"""Exposition: Prometheus text page + schema-stable JSON snapshot.

Two renderings of the same registry:

* :func:`render_prometheus` — the standard ``text/plain; version=0.0.4``
  exposition format (``# HELP`` / ``# TYPE`` headers, ``_bucket{le=...}`` /
  ``_sum`` / ``_count`` for histograms), scrape-able by any Prometheus.
* :func:`snapshot` — one JSON document bundling the metrics registry, the
  tick tracer's span summary, and the request event log.  Its shape is
  pinned by the checked-in schema ``obs/snapshot.schema.json`` and
  :func:`validate_snapshot` (a deliberately small JSON-Schema subset
  interpreter — the container has no ``jsonschema`` package, and the subset
  keeps the contract readable); CI validates every smoke snapshot against
  it so the shape cannot drift silently.

:func:`serve_http` puts both behind a background ``http.server`` thread
(``/metrics`` → Prometheus text, ``/metrics.json`` → snapshot) for
``launch/serve.py --metrics-port``.

:func:`metric_value` is the read-side helper the serving benchmark uses
instead of reaching into engine-private attributes.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional

from repro.obs.events import EventLog
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TickTracer

SNAPSHOT_VERSION = 1


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    body = ",".join(f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
                    for k, v in sorted(labels.items()))
    return "{" + body + "}"


def _fmt_value(v: Any) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) else repr(f)


def render_prometheus(registry: MetricsRegistry) -> str:
    lines = []
    snap = registry.snapshot()
    for name, m in snap.items():
        if m["help"]:
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} {m['type']}")
        for s in m["samples"]:
            labels = s["labels"]
            if m["type"] == "histogram":
                for le, cum in s["buckets"]:
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels({**labels, 'le': le})} {cum}")
                lines.append(f"{name}_sum{_fmt_labels(labels)} "
                             f"{_fmt_value(s['sum'])}")
                lines.append(f"{name}_count{_fmt_labels(labels)} "
                             f"{s['count']}")
            else:
                lines.append(f"{name}{_fmt_labels(labels)} "
                             f"{_fmt_value(s['value'])}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# JSON snapshot
# ---------------------------------------------------------------------------

def snapshot(registry: MetricsRegistry, tracer: Optional[TickTracer] = None,
             events: Optional[EventLog] = None,
             extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """The one schema-stable JSON document: metrics + trace summary +
    lifecycle events (+ caller extras like per-request results, merged at
    the top level; extras may not shadow the core sections)."""
    doc: Dict[str, Any] = {
        "schema_version": SNAPSHOT_VERSION,
        "metrics": registry.snapshot(),
    }
    if tracer is not None:
        doc["trace"] = {
            "capacity": tracer.capacity,
            "n_recorded": tracer.n_recorded,
            "summary": tracer.summary(),
        }
    if events is not None:
        doc["events"] = {
            "capacity": events.capacity,
            "n_dropped": events.n_dropped,
            "counts": events.counts(),
            "records": events.records(),
        }
    if extra:
        clash = set(extra) & set(doc)
        assert not clash, f"snapshot extras shadow core sections: {clash}"
        doc.update(extra)
    return doc


def write_snapshot(path: str, registry: MetricsRegistry,
                   tracer: Optional[TickTracer] = None,
                   events: Optional[EventLog] = None,
                   extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Validate-then-write (never persist a malformed snapshot), then
    re-read and re-validate what actually landed on disk — the same
    discipline BENCH_serving.json gets."""
    doc = snapshot(registry, tracer, events, extra)
    validate_snapshot(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    with open(path) as f:
        validate_snapshot(json.load(f))
    return doc


def metric_value(snap: Dict[str, Any], name: str,
                 labels: Optional[Dict[str, str]] = None) -> Any:
    """Pull one sample out of a registry/snapshot dict.  ``snap`` may be a
    full snapshot document or a bare ``registry.snapshot()``; ``labels``
    must match a sample's labels EXCLUDING any registry constant labels
    (those are matched as a subset).  Histogram samples return their
    ``{count, sum, buckets}`` view."""
    metrics = snap.get("metrics", snap)
    if name not in metrics:
        raise KeyError(f"metric {name!r} not in snapshot "
                       f"(have {sorted(metrics)})")
    want = labels or {}
    for s in metrics[name]["samples"]:
        if all(s["labels"].get(k) == str(v) for k, v in want.items()):
            if metrics[name]["type"] == "histogram":
                return {k: s[k] for k in ("count", "sum", "buckets")}
            return s["value"]
    raise KeyError(f"{name}: no sample matching {want} "
                   f"(have {[s['labels'] for s in metrics[name]['samples']]})")


# ---------------------------------------------------------------------------
# minimal JSON-Schema subset validator
# ---------------------------------------------------------------------------

_TYPES = {"object": dict, "array": list, "string": str, "boolean": bool,
          "null": type(None)}


def _check(doc, schema, path):
    t = schema.get("type")
    if t is not None:
        ts = t if isinstance(t, list) else [t]
        ok = False
        for tn in ts:
            if tn == "number":
                ok |= isinstance(doc, (int, float)) and not isinstance(doc, bool)
            elif tn == "integer":
                ok |= isinstance(doc, int) and not isinstance(doc, bool)
            else:
                ok |= isinstance(doc, _TYPES[tn])
        assert ok, f"{path}: expected {t}, got {type(doc).__name__}"
    if "enum" in schema:
        assert doc in schema["enum"], f"{path}: {doc!r} not in {schema['enum']}"
    if "const" in schema:
        assert doc == schema["const"], f"{path}: {doc!r} != {schema['const']!r}"
    if isinstance(doc, (int, float)) and not isinstance(doc, bool):
        if "minimum" in schema:
            assert doc >= schema["minimum"], f"{path}: {doc} < minimum"
    if isinstance(doc, dict):
        for req in schema.get("required", ()):
            assert req in doc, f"{path}: missing required key {req!r}"
        props = schema.get("properties", {})
        for k, v in doc.items():
            if k in props:
                _check(v, props[k], f"{path}.{k}")
            else:
                ap = schema.get("additionalProperties", True)
                assert ap is not False, f"{path}: unexpected key {k!r}"
                if isinstance(ap, dict):
                    _check(v, ap, f"{path}.{k}")
    if isinstance(doc, list) and "items" in schema:
        for i, v in enumerate(doc):
            _check(v, schema["items"], f"{path}[{i}]")


_SCHEMA_CACHE: Dict[str, Any] = {}


def load_schema(path: Optional[str] = None) -> Dict[str, Any]:
    if path is None:
        import os
        path = os.path.join(os.path.dirname(__file__),
                            "snapshot.schema.json")
    if path not in _SCHEMA_CACHE:
        with open(path) as f:
            _SCHEMA_CACHE[path] = json.load(f)
    return _SCHEMA_CACHE[path]


def validate_snapshot(doc: Dict[str, Any],
                      schema: Optional[Dict[str, Any]] = None) -> None:
    """Assert ``doc`` matches the checked-in snapshot schema (supports the
    type / required / properties / additionalProperties / items / enum /
    const / minimum subset — everything the schema file actually uses)."""
    _check(doc, schema or load_schema(), "$")


# ---------------------------------------------------------------------------
# HTTP exposition (launch/serve.py --metrics-port)
# ---------------------------------------------------------------------------

def serve_http(registry: MetricsRegistry, port: int,
               tracer: Optional[TickTracer] = None,
               events: Optional[EventLog] = None) -> ThreadingHTTPServer:
    """Background scrape endpoint: ``/metrics`` (Prometheus text) and
    ``/metrics.json`` (snapshot).  Returns the server; callers own
    ``shutdown()``."""

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.rstrip("/") == "/metrics":
                body = render_prometheus(registry).encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif self.path.rstrip("/") == "/metrics.json":
                body = json.dumps(snapshot(registry, tracer, events)).encode()
                ctype = "application/json"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):      # silence per-request stderr spam
            pass

    server = ThreadingHTTPServer(("", port), Handler)
    threading.Thread(target=server.serve_forever, daemon=True,
                     name="obs-metrics-http").start()
    return server

"""Typed, thread-safe metrics registry for the serving stack.

Three instrument kinds, deliberately mirroring the Prometheus data model so
:mod:`repro.obs.export` can render a standard text exposition page:

* :class:`Counter` — monotonically increasing totals (tokens prefilled,
  requests completed, preemptions).  ``set()`` exists ONLY as the
  backward-compat reset hook for the engines' legacy ``eng.n_* = 0`` idiom
  (benchmark warm-up zeroing); new code should use
  :meth:`MetricsRegistry.reset`.
* :class:`Gauge` — point-in-time values.  Besides ``set()``, a gauge can be
  bound to a zero-arg callable (:meth:`Gauge.set_fn`) or — for labelled
  families whose label set is dynamic, e.g. per-adapter active slots — to a
  collector returning ``{label_values_tuple: value}``
  (:meth:`Gauge.set_collector`).  Callables are resolved at READ time
  (snapshot / exposition), so the hot serving loop never pays for them.
* :class:`Histogram` — fixed bucket edges declared at creation (cumulative
  ``le`` semantics).  Fixed edges keep the snapshot schema stable across
  runs, which is what lets CI diff two snapshots structurally.

Labels: each metric declares its ``labelnames`` up front; ``labels(**kv)``
binds one child per distinct value tuple (Prometheus-style).  The registry
itself can carry ``constant_labels`` (e.g. ``{"engine": "paged"}``) that are
merged into every exported sample — the engines use this so dense / paged /
speculative snapshots are distinguishable without threading an engine label
through every call site.

Thread safety: one registry-wide :class:`threading.RLock` guards child
creation and every mutation.  The instruments are host-side Python — they
must NEVER appear inside a jitted function (the hard obs constraint:
instrumentation cannot change emitted tokens or jitted tick signatures).

The module also hosts the pure latency-summary helpers
(:func:`percentile`, :func:`latency_summary`) that ``benchmarks/
serve_bench.py`` previously duplicated privately.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

# default histogram edges for request-scale latencies (seconds); the +inf
# bucket is implicit.  Spans sub-ms ticks through multi-second long-context
# prefills.
LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)


class Metric:
    """Shared plumbing: name / help / unit / label validation / children."""

    kind = "untyped"

    def __init__(self, name: str, help: str, unit: str,
                 labelnames: Tuple[str, ...], lock: threading.RLock):
        assert name, "metric name required"
        self.name = name
        self.help = help
        self.unit = unit
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._children: Dict[Tuple[str, ...], Any] = {}

    def _key(self, labels: Dict[str, str]) -> Tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(labels[n]) for n in self.labelnames)

    def labels(self, **labels):
        """The child bound to this label-value combination (created on first
        use).  A metric with no labelnames IS its own sole child."""
        key = self._key(labels)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._new_child()
                self._children[key] = child
        return child

    def _default(self):
        """The label-less child — valid only when labelnames is empty."""
        return self.labels()

    def _new_child(self):
        raise NotImplementedError

    def samples(self) -> List[Tuple[Tuple[str, ...], Any]]:
        """[(label values, child view)] snapshot under the lock."""
        with self._lock:
            return [(k, c.view()) for k, c in sorted(self._children.items())]

    def reset(self) -> None:
        with self._lock:
            for c in self._children.values():
                c.reset()


class _CounterChild:
    __slots__ = ("_v", "_lock")

    def __init__(self, lock):
        self._v = 0.0
        self._lock = lock

    def inc(self, amount: float = 1) -> None:
        assert amount >= 0, f"counter decrement ({amount})"
        with self._lock:
            self._v += amount

    def set(self, value: float) -> None:
        with self._lock:
            self._v = value

    def value(self) -> float:
        return self._v

    def view(self) -> float:
        return self._v

    def reset(self) -> None:
        self._v = 0.0


class Counter(Metric):
    kind = "counter"

    def _new_child(self):
        return _CounterChild(self._lock)

    # label-less convenience surface (the common case in the engines)
    def inc(self, amount: float = 1, **labels) -> None:
        self.labels(**labels).inc(amount)

    def set(self, value: float, **labels) -> None:
        self.labels(**labels).set(value)

    def value(self, **labels) -> float:
        return self.labels(**labels).value()


class _GaugeChild:
    __slots__ = ("_v", "_fn", "_lock")

    def __init__(self, lock):
        self._v = 0.0
        self._fn: Optional[Callable[[], float]] = None
        self._lock = lock

    def set(self, value: float) -> None:
        with self._lock:
            self._v = float(value)
            self._fn = None

    def set_fn(self, fn: Callable[[], float]) -> None:
        with self._lock:
            self._fn = fn

    def value(self) -> float:
        fn = self._fn
        return float(fn()) if fn is not None else self._v

    def view(self) -> float:
        return self.value()

    def reset(self) -> None:
        if self._fn is None:
            self._v = 0.0


class Gauge(Metric):
    kind = "gauge"

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._collector: Optional[Callable[[], Dict[Tuple[str, ...], float]]] = None

    def _new_child(self):
        return _GaugeChild(self._lock)

    def set(self, value: float, **labels) -> None:
        self.labels(**labels).set(value)

    def set_fn(self, fn: Callable[[], float], **labels) -> None:
        """Bind a zero-arg callable; resolved at snapshot/exposition time."""
        self.labels(**labels).set_fn(fn)

    def set_collector(
            self, fn: Callable[[], Dict[Tuple[str, ...], float]]) -> None:
        """For dynamic label sets: ``fn`` returns the ENTIRE current family
        as ``{label_values_tuple: value}`` — e.g. active slots keyed by
        adapter name, where adapters register after engine construction."""
        with self._lock:
            self._collector = fn

    def value(self, **labels) -> float:
        return self.labels(**labels).value()

    def samples(self):
        coll = self._collector
        if coll is None:
            return super().samples()
        out = dict(super().samples())
        for key, v in coll().items():
            key = tuple(str(k) for k in key)
            assert len(key) == len(self.labelnames), (key, self.labelnames)
            out[key] = float(v)
        return sorted(out.items())


class _HistogramChild:
    __slots__ = ("_edges", "_counts", "_sum", "_n", "_lock")

    def __init__(self, edges, lock):
        self._edges = edges
        self._counts = [0] * (len(edges) + 1)     # last bucket = +inf
        self._sum = 0.0
        self._n = 0
        self._lock = lock

    def observe(self, x: float) -> None:
        with self._lock:
            i = 0
            for i, edge in enumerate(self._edges):
                if x <= edge:
                    break
            else:
                i = len(self._edges)
            self._counts[i] += 1
            self._sum += x
            self._n += 1

    def view(self) -> Dict[str, Any]:
        """{count, sum, buckets: [[le, cumulative count], ...]} — cumulative
        ``le`` semantics, +inf as the final bucket, like Prometheus."""
        with self._lock:
            cum, out = 0, []
            for edge, c in zip(self._edges, self._counts):
                cum += c
                out.append([edge, cum])
            out.append(["+Inf", cum + self._counts[-1]])
            return {"count": self._n, "sum": self._sum, "buckets": out}

    def reset(self) -> None:
        self._counts = [0] * (len(self._edges) + 1)
        self._sum = 0.0
        self._n = 0


class Histogram(Metric):
    kind = "histogram"

    def __init__(self, name, help, unit, labelnames, lock,
                 buckets: Tuple[float, ...] = LATENCY_BUCKETS):
        super().__init__(name, help, unit, labelnames, lock)
        edges = tuple(float(b) for b in buckets)
        assert edges == tuple(sorted(edges)) and len(set(edges)) == len(edges), \
            f"{name}: bucket edges must be strictly increasing ({edges})"
        assert edges and math.isfinite(edges[-1]), \
            f"{name}: +inf bucket is implicit, declare finite edges only"
        self.buckets = edges

    def _new_child(self):
        return _HistogramChild(self.buckets, self._lock)

    def observe(self, x: float, **labels) -> None:
        self.labels(**labels).observe(x)

    def count(self, **labels) -> int:
        return self.labels(**labels).view()["count"]


class MetricsRegistry:
    """Named metrics with get-or-create semantics (re-declaring a name with
    the same kind returns the existing instrument; a kind clash raises —
    silent shadowing is how telemetry lies)."""

    def __init__(self, constant_labels: Optional[Dict[str, str]] = None):
        self._lock = threading.RLock()
        self._metrics: Dict[str, Metric] = {}
        self.constant_labels = dict(constant_labels or {})

    def _get_or_create(self, cls, name, help, unit, labelnames, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}")
                return m
            m = cls(name, help, unit, tuple(labelnames), self._lock, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "", unit: str = "",
                labelnames: Iterable[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, unit, labelnames)

    def gauge(self, name: str, help: str = "", unit: str = "",
              labelnames: Iterable[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, unit, labelnames)

    def histogram(self, name: str, help: str = "", unit: str = "",
                  labelnames: Iterable[str] = (),
                  buckets: Tuple[float, ...] = LATENCY_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, unit, labelnames,
                                   buckets=buckets)

    def get(self, name: str) -> Metric:
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def metrics(self) -> List[Metric]:
        with self._lock:
            return list(self._metrics.values())

    def reset(self) -> None:
        """Zero every counter and histogram (bench warm-up hygiene); plain
        gauges zero too, callable-backed gauges keep their bindings."""
        for m in self.metrics():
            m.reset()

    def snapshot(self) -> Dict[str, Any]:
        """Schema-stable dict of everything (see obs/snapshot.schema.json):
        ``{name: {type, help, unit, labelnames, samples: [...]}}`` where each
        sample is ``{labels: {...}, value}`` for counters/gauges and
        ``{labels, count, sum, buckets}`` for histograms.  Constant labels
        are merged into every sample."""
        out: Dict[str, Any] = {}
        for m in self.metrics():
            samples = []
            for key, view in m.samples():
                labels = dict(self.constant_labels)
                labels.update(zip(m.labelnames, key))
                if m.kind == "histogram":
                    samples.append({"labels": labels, **view})
                else:
                    samples.append({"labels": labels, "value": view})
            out[m.name] = {"type": m.kind, "help": m.help, "unit": m.unit,
                           "labelnames": list(m.labelnames),
                           "samples": samples}
        return out


# ---------------------------------------------------------------------------
# latency summaries (pure math — previously duplicated in serve_bench)
# ---------------------------------------------------------------------------

def percentile(xs: Iterable[float], q: float) -> float:
    """Exact linear-interpolated percentile over raw samples (numpy
    semantics, without requiring numpy on this host-only path)."""
    xs = sorted(float(x) for x in xs)
    assert xs, "percentile of empty sample set"
    if len(xs) == 1:
        return xs[0]
    rank = (q / 100.0) * (len(xs) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def latency_summary(ttfts_s: Iterable[float], e2es_s: Iterable[float],
                    suffix: str = "") -> Dict[str, float]:
    """``{ttft,e2e}_{p50,p99}[suffix]_ms`` over per-request seconds — the
    exact field names BENCH_serving.json has carried since PR 4."""
    ttfts_s, e2es_s = list(ttfts_s), list(e2es_s)
    return {
        f"ttft_p50{suffix}_ms": round(percentile(ttfts_s, 50) * 1e3, 3),
        f"ttft_p99{suffix}_ms": round(percentile(ttfts_s, 99) * 1e3, 3),
        f"e2e_p50{suffix}_ms": round(percentile(e2es_s, 50) * 1e3, 3),
        f"e2e_p99{suffix}_ms": round(percentile(e2es_s, 99) * 1e3, 3),
    }

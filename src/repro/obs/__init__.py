"""Serving observability: metrics registry, tick tracer, lifecycle events.

Three host-side instruments (never inside jit — instrumentation must not
change emitted tokens or jitted tick signatures, and ``TickState`` gains no
leaves for it):

* :mod:`repro.obs.metrics` — typed, thread-safe registry of counters /
  gauges / histograms with Prometheus-style labels.
* :mod:`repro.obs.trace` — ring-buffer spans around each scheduling phase,
  aligned with XLA profiles via ``jax.profiler.TraceAnnotation``.
* :mod:`repro.obs.events` — per-request lifecycle event log
  (submit → admit → prefill_chunk × N → first_token → preempt → complete),
  optionally streamed to JSONL.
* :mod:`repro.obs.export` — Prometheus text page, schema-stable JSON
  snapshot (``snapshot.schema.json``), and the ``--metrics-port`` HTTP
  endpoint.

Engines attach all three when ``ServeConfig.obs`` is true (the default);
``eng.metrics`` / ``eng.tracer`` / ``eng.events`` are the public handles
(``eng.registry`` stays the ADAPTER registry), and
``repro.obs.export.snapshot(eng.metrics, eng.tracer, eng.events)`` is the
one-call export.

Metrics reference
=================

Every serving registry carries the constant label ``engine`` (``sync`` |
``continuous`` | ``speculative``), so multi-engine snapshots stay
distinguishable.

Counters (monotonic totals; reset only via ``registry.reset()`` or the
legacy ``eng.n_* = 0`` property setters kept for the benchmark warm-up):

``serve_prefill_tokens_total`` (tokens)
    Prompt tokens pushed through prefill, including re-prefill after a
    preemption and tokens skipped by a prefix hit (counted when admitted,
    matching the legacy ``n_prefill_tokens``).
``serve_decode_tokens_total`` (tokens)
    Tokens emitted by decode ticks / accepted by speculative verify.
    Moves once per host sync, by the number of live slots that advanced.
``serve_requests_completed_total`` (requests)
    Finalized requests (EOS or max-token budget).  Equals the event log's
    ``complete`` count — pinned by ``tests/test_obs.py``.
``serve_prefill_chunks_total`` (chunks)
    Chunked-prefill dispatches.  One admission = ceil(prompt/chunk) chunks.
``serve_ticks_total`` (ticks)
    Jitted decode-tick (or speculative-round) dispatches.
``serve_ticks_during_prefill_total`` (ticks)
    Decode ticks interleaved while at least one slot was mid-prefill — the
    "chunked prefill is actually overlapping" signal.
``serve_prefix_hits_total`` (requests)
    Admissions that found a shared-prefix match (COW page sharing).
``serve_prefix_tokens_saved_total`` (tokens)
    Prompt tokens NOT re-prefilled thanks to prefix hits.
``serve_prefix_pages_shared_total`` (pages)
    KV pages mapped copy-on-write instead of allocated fresh.
``serve_preemptions_total`` (requests)
    Slots evicted under page pressure and requeued at the head.
``serve_stalls_total`` (ticks)
    Watchdog-flagged straggler ticks (``ServeConfig.tick_watchdog``); the
    alarm is counted, never raised, in serving.
``serve_shed_total`` (requests)
    Requests dropped by admission control (bounded queue) or the
    degradation ladder's shed level.  Terminal status ``shed``.
``serve_deadline_miss_total`` (requests)
    Requests terminated at a TTFT or end-to-end deadline
    (``ResilienceConfig.ttft_deadline_s`` / ``deadline_s``).  Terminal
    status ``timeout``; partial tokens still ship in the result.
``serve_cancelled_total`` (requests)
    Requests terminated via ``engine.cancel(uid)``.
``serve_failed_total`` (requests)
    Requests that could never run (impossible admission, admission-
    livelock breaker) or were failed by injected faults.
    ``completed + shed + deadline_miss + cancelled + failed`` partitions
    every submitted request exactly once.
``serve_restores_total`` (restores)
    Snapshot-and-restart cycles (tick-retry exhaustion, stall-streak
    escalation, or an explicit ``engine.restore``).
``spec_rounds_total`` / ``spec_tokens_proposed_total`` /
``spec_tokens_accepted_total``
    Speculative engine only: draft→verify rounds, γ-sized proposals, and
    verifier-accepted tokens.  ``accepted/proposed`` is the acceptance rate.

Gauges (point-in-time; most are bound to live engine state and resolved at
snapshot time, so the hot loop never pays for them):

``serve_pages_in_use`` / ``serve_pages_free`` / ``serve_pages_peak_in_use``
/ ``serve_pages_pool_size`` (pages)
    Page-pool occupancy from ``serving/pages.PageAllocator`` (paged
    engines only); ``peak_in_use`` is the high-water mark that sizes pools.
``serve_slots_occupied`` / ``serve_slots_active`` (slots)
    Scheduler slots holding any request vs. slots actively decoding.
``serve_queue_depth`` (requests)
    Submitted-but-not-admitted requests waiting in the scheduler.
``serve_adapter_active_slots{adapter=...}`` (slots)
    Active slots per LoRA adapter name (``__base__`` for adapter-less),
    from ``serving/adapters.AdapterRegistry`` — a dynamic label family.
``serve_adapter_bank_slots`` / ``serve_adapter_bank_in_use`` (rows)
    Device adapter-bank capacity (incl. the reserved base row 0) vs. rows
    currently assigned (resident + mid-upload), from
    ``serving/adapters.AdapterResidency`` — the paged adapter bank's
    occupancy, mirroring the page-pool gauges.
``serve_adapter_registered`` (adapters)
    Adapters in the UNBOUNDED host tier (the device bank may hold fewer).
``serve_adapter_hits`` / ``serve_adapter_misses`` (checks)
    Admission-gate residency checks answered by a resident row vs. checks
    that staged a host→HBM upload (the request waits in queue while the
    transfer overlaps decode ticks).
``serve_adapter_hit_rate`` (ratio)
    ``hits / (hits + misses)``; 1.0 when nothing ever missed — the
    dense-equivalent regime (``bank_slots >= registered adapters``).
``serve_adapter_uploads`` / ``serve_adapter_upload_bytes`` (uploads/bytes)
    Adapter trees committed into the device bank and the host→HBM bytes
    streamed for them (registration-time commits included).
``serve_adapter_evictions`` (rows)
    Refcount-0 bank rows zeroed (LRU) to make room for a missing adapter.
``spec_acceptance_ema`` (ratio) / ``spec_gamma`` (tokens)
    ``GammaController`` EMA acceptance and the γ it currently proposes.
``serve_tick_ewma_s`` (seconds)
    ``StepWatchdog`` EWMA of tick wall-clock (watchdog enabled only).
``serve_degradation_level`` (level)
    Current rung of the graceful-degradation ladder (0 = healthy …
    5 = shed load), live from ``repro.serving.resilience``.
``hbm_bytes{component,device}`` (bytes)
    Per-device HBM attribution for ``weights`` / ``kv_cache`` /
    ``adapter_bank`` under the mesh — the LoRAM resource story, live.
    Reports PACKED bytes: under ``ServeConfig.quant`` the weight shards are
    NF4 codes + scales and the KV shards int8 codes + scale pools, so the
    gauge shrinks with the storage, not the logical shapes.
``serve_weight_bytes_packed`` / ``serve_weight_bytes_logical`` (bytes)
    Physical base-weight bytes (QTensors counted packed) vs. the
    fp32-equivalent footprint — ``logical / packed`` is the QLoRAM weight
    storage-reduction ratio BENCH_serving.json reports.
``serve_kv_cache_bytes`` (bytes)
    Attention K/V reservation: paged pool + block table (int8 pools
    include their per-row scale pools) or the dense per-slot reservation.

Histograms (fixed ``LATENCY_BUCKETS`` edges, seconds):

``serve_ttft_seconds``
    Time to first token per completed request (same stamp as
    ``RequestResult.ttft_s``).
``serve_e2e_latency_seconds``
    Submit-to-complete latency per request.
``serve_tick_retries`` (retries; same bucket edges, unit ``retries``)
    Tick-dispatch attempts burned before a snapshot-and-restart was
    triggered (fault injection / ``ResilienceConfig.tick_retries``).

Event log reference
===================

Each record: ``{"t": perf_counter float, "kind": ..., "uid": ...}`` plus
kind-specific fields.  ``t`` shares the clock domain of the engines' TTFT
stamps, so ``EventLog.derive_ttft(uid) == RequestResult.ttft_s`` exactly.

``submit``      queued; ``n_prompt``, ``adapter``.
``admit``       placed in a slot; ``slot``, ``adapter``, ``n_prompt``.
``prefix_hit``  COW match at admission; ``slot``, ``tokens_saved``,
                ``pages_shared``.
``prefill_chunk`` one chunk dispatched; ``slot``, ``start``, ``n_tokens``.
``first_token`` first decode token surfaced; the TTFT stamp.  Emitted at
                most once per uid (setdefault-guarded — a preempted-then-
                readmitted request keeps its true TTFT).
``preempt``     evicted under page pressure; ``slot``, ``pages_freed``;
                the request is requeued at the head.
``stall``       watchdog straggler tick; uid is -1 (engine-scoped).
``complete``    finalized; ``slot``, ``n_generated``.
``timeout``     TTFT / end-to-end deadline expired; ``slot`` (-1 if still
                queued), ``n_generated`` (partial tokens still shipped).
``shed``        dropped by the bounded queue or the ladder's shed level;
                always ``slot`` -1, ``n_generated`` 0.
``cancel``      ``engine.cancel(uid)``; queued or in-flight.
``failed``      impossible admission, livelock breaker, or injected
                adapter fault.
                Exactly ONE of {complete, timeout, shed, cancel, failed}
                per submitted uid — the terminal kinds mirror
                ``RequestResult.status``.
``degrade``     ladder level change; uid -1, ``level``, ``prev``.
``restore``     snapshot-and-restart re-queued work; uid -1,
                ``n_requests``.
``adapter_upload``  a host adapter tree was committed into a device bank
                row (registration or residency-miss streaming); uid -1,
                ``adapter``, ``row``, ``n_bytes``.
``adapter_evict``   an LRU refcount-0 bank row was zeroed to make room;
                uid -1, ``adapter``, ``row``.
"""
from repro.obs.events import EVENT_KINDS, EventLog
from repro.obs.export import (metric_value, render_prometheus, serve_http,
                              snapshot, validate_snapshot, write_snapshot)
from repro.obs.metrics import (LATENCY_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry, latency_summary, percentile)
from repro.obs.trace import Span, TickTracer

__all__ = [
    "EVENT_KINDS", "EventLog",
    "metric_value", "render_prometheus", "serve_http", "snapshot",
    "validate_snapshot", "write_snapshot",
    "LATENCY_BUCKETS", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "latency_summary", "percentile",
    "Span", "TickTracer",
]

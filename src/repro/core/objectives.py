"""Training objectives: SFT cross-entropy (L_SFT) and alignment LM loss (L_A)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def cross_entropy(logits: Array, labels: Array, mask: Optional[Array] = None):
    """Token-mean cross-entropy.  logits (B,S,V) fp32; labels (B,S) int32.
    mask (B,S): 1 for positions contributing to the loss (paper: answer
    tokens for SFT; all tokens for alignment).

    The label pick is a masked reduction (not take_along_axis): with
    vocab-sharded logits (gemma3's 262k vocab) a gather would all-gather the
    full fp32 logits per device; the where+sum shards cleanly (GSPMD psums
    the partial picks)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def sft_loss(plan, base_params, lora, batch, *, lora_scale=2.0, remat=False,
             masks=None, aux_weight: float = 0.01, frontend=None):
    """L_SFT: next-token CE on (tokens, labels[, loss_mask]) + MoE aux loss."""
    from repro.models.model import forward

    logits, aux = forward(plan, base_params, batch["tokens"], lora,
                          lora_scale=lora_scale, remat=remat, masks=masks,
                          frontend=frontend if frontend is not None else batch.get("frontend"))
    ce = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce + aux_weight * aux, (ce, aux)


def alignment_loss(plan, params, batch, *, remat=False, aux_weight: float = 0.01):
    """L_A (Eq. 8): plain causal LM loss of the *pruned base* on a general
    corpus — full-parameter continual pre-training, run offline by the
    model publisher."""
    from repro.models.model import forward

    logits, aux = forward(plan, params, batch["tokens"], None, remat=remat,
                          frontend=batch.get("frontend"))
    ce = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce + aux_weight * aux, (ce, aux)


def perplexity(loss: Array) -> Array:
    return jnp.exp(loss)

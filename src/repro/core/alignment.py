"""Pruned full-rank weight alignment (paper §2.2, Eq. 8).

One-shot offline continual pre-training of the pruned model on a small
general corpus, closing the knowledge gap between W₀ᴾ (used for training)
and W₀ (used for inference).  In the paper this is ~105M tokens / ≤1600
steps executed by the model publisher; here it is a function over the same
Trainer substrate with *all base params trainable* (unlike SFT, which trains
only the adapters).
"""
from __future__ import annotations

from typing import Callable, Iterator

import jax
import jax.numpy as jnp

from repro.core.objectives import alignment_loss
from repro.optim.adamw import adamw_init, adamw_update


def align(
    plan, params, batches: Iterator, *, steps: int, learning_rate: float = 1e-5,
    weight_decay: float = 0.0, grad_clip: float = 1.0, log_every: int = 10,
    callback: Callable | None = None,
):
    """Returns (aligned_params, losses).  Pure-JAX AdamW over the full pruned
    base.  Deliberately simple: alignment is an offline publisher-side step,
    not part of the distributed training hot path."""
    opt_state = adamw_init(params)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            lambda p: alignment_loss(plan, p, batch), has_aux=True)(params)
        params, opt_state = adamw_update(
            params, grads, opt_state, lr=learning_rate, wd=weight_decay,
            clip=grad_clip)
        return params, opt_state, loss

    losses = []
    for i, batch in enumerate(batches):
        if i >= steps:
            break
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
        if callback and i % log_every == 0:
            callback(i, float(loss))
    return params, losses

from repro.core import alignment, loram, objectives, pruning, recovery  # noqa: F401

"""LoRAM end-to-end pipeline (paper Algorithm 1).

Offline (publisher):   W₀ →P(·)→ W₀ᴾ →L_A→ W₀ᴾ'ᴬ →Q(·)→ W₀ᴾ'ᴬ'Q
Online  (user, train): W_Δ →P(·)→ W_Δᴾ →L_SFT→ W_Δᴾ*
Online  (user, infer): W_Δᴾ* →R(·)→ W_Δᴿ*;  serve with W₀ + Bᴿ*Aᴿ*
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LoRAConfig, LoRAMConfig
from repro.core import alignment as alignment_mod
from repro.core import pruning, recovery
from repro.models.model import Plan, init_lora, make_plan
from repro.quant import nf4


@dataclass
class LoRAMSetup:
    """Everything the online training stage needs."""

    full_plan: Plan
    small_plan: Plan
    small_params: Any          # frozen base (pruned [, aligned] [, NF4])
    lora0: Any                 # adapter init (trained on the small plan)
    spec: pruning.PruneSpec
    lora_cfg: LoRAConfig
    loram_cfg: LoRAMConfig

    @property
    def masks(self):
        return self.spec.masks   # None for structured variants

    def train_masks(self):
        """Masks tree for the forward pass (non-structured only).  We bake
        masks into the frozen base at setup (apply_masks_to_params), so the
        per-step forward needn't re-mask — return None."""
        return None


def setup(
    full_plan: Plan,
    full_params,
    loram_cfg: LoRAMConfig,
    lora_cfg: LoRAConfig,
    rng,
    *,
    scores: Optional[Dict] = None,
    align_batches: Optional[Iterator] = None,
    align_steps: int = 0,
    align_lr: float = 1e-5,
) -> LoRAMSetup:
    """Offline stages: prune → (align) → (quantize) → adapter init."""
    small_plan, small_params, spec = pruning.prune(
        full_plan, full_params, loram_cfg, scores=scores)

    if loram_cfg.align and align_batches is not None and align_steps > 0:
        small_params, _ = alignment_mod.align(
            small_plan, small_params, align_batches, steps=align_steps,
            learning_rate=align_lr)

    if loram_cfg.quantize:
        small_params = quantize_base(small_params)

    lora0 = init_lora(small_plan, lora_cfg, rng)
    return LoRAMSetup(full_plan, small_plan, small_params, lora0, spec,
                      lora_cfg, loram_cfg)


def quantize_base(params, block: int = nf4.DEFAULT_BLOCK):
    """NF4-quantize the frozen base: all stacked/shared 2-D-per-layer mats.
    Norms, embeddings and SSM scalars stay in bf16 (QLoRA keeps sensitive
    tensors high-precision)."""

    def visit_block(bp: dict) -> dict:
        out = {}
        for name, w in bp.items():
            if name in ("ln", "out_norm", "dt_bias", "a_log", "d_skip", "conv_w",
                        "router"):
                out[name] = w
            elif (isinstance(w, jax.Array) and w.ndim >= 3
                  and w.shape[-2] % block == 0 and w.shape[-2] >= block):
                out[name] = nf4.quantize_stacked(w, block=block)
            elif isinstance(w, jax.Array) and w.ndim == 2 and w.shape[0] % block == 0 and w.size >= 4096:
                out[name] = nf4.quantize(w, block=block)
            else:
                out[name] = w
        return out

    out = dict(params)
    for key in ("stages", "enc_stages"):
        if key not in params:
            continue
        sec = {}
        for stn, st in params[key].items():
            sec[stn] = {
                "stacked": {bn: visit_block(bp) for bn, bp in st["stacked"].items()},
                "shared": {bn: visit_block(bp) for bn, bp in st["shared"].items()},
            }
        out[key] = sec
    # lm_head / embed stay bf16: they carry the logits scale (QLoRA practice)
    return out


def finalize(setup_: LoRAMSetup, trained_lora, full_params):
    """Online inference prep: recover adapters, merge into the full model."""
    lora_full = recovery.recover_lora(trained_lora, setup_.spec,
                                      setup_.full_plan, setup_.small_plan)
    merged = recovery.merge_lora(full_params, lora_full, setup_.lora_cfg.scale)
    return lora_full, merged


def storage_report(full_params, small_params) -> Dict[str, float]:
    """The paper's headline metric: parameter reduction ratio + HBM bytes."""
    n_full = pruning.param_count(full_params)
    n_small = pruning.param_count(small_params)
    bytes_full = nf4.param_bytes(full_params)
    bytes_small = nf4.param_bytes(small_params)
    return {
        "full_params": n_full,
        "small_params": n_small,
        "reduction_ratio": n_full / max(n_small, 1),
        "full_bytes": bytes_full,
        "small_bytes": bytes_small,
        "hbm_reduction": bytes_full / max(bytes_small, 1),
    }

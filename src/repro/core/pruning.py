"""P(·): the pruning stage of LoRAM.

Four variants, faithful to the paper's §3.1 baselines:

* ``rand`` — randomly structured (LoRAM-Rand): random group removal.
* ``stru`` — LLM-Pruner-style structured (LoRAM-Stru): first-order Taylor
  importance ``|w · ∂L/∂w|`` summed per *coupled group* (GQA KV-group across
  q/k/v/o, FFN channel across gate/up/down, whole MoE expert, whole SSD
  head), local (per-layer) uniform ratio, first/last layers kept unpruned.
* ``semi`` — SparseGPT-style 4:8 semi-structured masks (magnitude criterion).
* ``unst`` — unstructured magnitude masks at a global per-matrix ratio.

TPU adaptation (DESIGN.md §3): structured keep-counts are rounded so pruned
FFN widths stay multiples of 128 (MXU lane) and SSD head counts stay even
(64-wide heads → 128-aligned channel blocks).  Non-structured variants keep
full-shape weights with masks — the paper's own ▲ "theoretical reduction"
caveat; on TPU they reduce neither memory nor FLOPs and exist for fidelity.

A :class:`PruneSpec` records, per (stage, block, param), the kept *flat
channel indices* on each pruned axis.  The same indices drive both
``prune_params`` (gather) and ``recovery.recover_lora`` (scatter) — which is
what makes the prune→train→recover→merge cycle exact.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LoRAMConfig, ModelConfig, Stage, StageDims, round_to
from repro.models.model import Plan

Array = jax.Array


# ---------------------------------------------------------------------------
# Spec types
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class WeightPrune:
    """One pruned axis of one stacked parameter.

    axis: axis in the *stacked* array (leading dim = layer repetition)
    idx:  (n_rep, n_keep) kept flat-channel indices, sorted ascending
    role: "in" | "out" | "aux" — whether the axis is the matmul input dim,
          output dim (relevant for LoRA recovery) or a non-matmul param.
    """

    axis: int
    idx: Any  # np.ndarray (n_rep, n_keep)
    role: str


@dataclass
class PruneSpec:
    method: str
    ratio: float
    # stage_specs[new_stage_name] -> block -> param -> [WeightPrune, ...]
    stage_specs: Dict[str, Dict[str, Dict[str, List[WeightPrune]]]]
    # mapping new (split) stage -> (orig stage name, rep slice)
    stage_slices: Dict[str, Tuple[str, int, int]]
    # semi/unst: masks[stage][block][param] = bool array, full stacked shape
    masks: Optional[Dict] = None

    @property
    def structured(self) -> bool:
        return self.method in ("rand", "stru")


# ---------------------------------------------------------------------------
# Importance scores
# ---------------------------------------------------------------------------

def _group_scores_from_tree(plan: Plan, tree, agg) -> Dict:
    """Reduce a params-shaped tree to per-group scores.

    Returns scores[stage][block] = dict of score arrays:
      mlp:   {"ff": (L, F)}
      attn:  {"kv": (L, G)}
      moe:   {"expert": (L, E), "ff": (L, F_resid)?}
      mamba: {"head": (L, H)}
    ``agg(stacked_param) -> |w∘g|``-style elementwise magnitude.
    """
    out: Dict[str, Dict[str, Dict[str, Array]]] = {}
    for st in plan.stages:
        d = st.dims
        st_scores: Dict[str, Dict[str, Array]] = {}
        for spec in st.superblock:
            if spec.shared:
                continue  # shared blocks are never pruned (DESIGN.md §4)
            bp = tree["stages"][st.name]["stacked"].get(spec.name)
            if bp is None:
                continue
            s: Dict[str, Array] = {}
            if spec.kind == "mlp":
                wg, wu, wd = (jnp.asarray(agg(bp[k]), jnp.float32) for k in ("wg", "wu", "wd"))
                s["ff"] = wg.sum(1) + wu.sum(1) + wd.sum(2)          # (L, F)
            elif spec.kind in ("attn", "cross_attn"):
                G, gs, hd = d.n_kv_heads, d.n_heads // d.n_kv_heads, d.head_dim
                L = bp["wq"].shape[0]
                wq = jnp.asarray(agg(bp["wq"]), jnp.float32).reshape(L, d.d_model, G, gs * hd)
                wk = jnp.asarray(agg(bp["wk"]), jnp.float32).reshape(L, d.d_model, G, hd)
                wv = jnp.asarray(agg(bp["wv"]), jnp.float32).reshape(L, d.d_model, G, hd)
                wo = jnp.asarray(agg(bp["wo"]), jnp.float32).reshape(L, G, gs * hd, d.d_model)
                s["kv"] = wq.sum((1, 3)) + wk.sum((1, 3)) + wv.sum((1, 3)) + wo.sum((2, 3))
            elif spec.kind == "moe":
                we = sum(jnp.asarray(agg(bp[k]), jnp.float32).sum((2, 3))
                         for k in ("we_g", "we_u", "we_d"))          # (L, E)
                s["expert"] = we
                if "wr_g" in bp:
                    s["resid_ff"] = (jnp.asarray(agg(bp["wr_g"]), jnp.float32).sum(1)
                                     + jnp.asarray(agg(bp["wr_u"]), jnp.float32).sum(1)
                                     + jnp.asarray(agg(bp["wr_d"]), jnp.float32).sum(2))
            elif spec.kind == "mamba":
                H, P = d.ssm_heads, d.ssm_head_dim
                L = bp["in_proj"].shape[0]
                ip = jnp.asarray(agg(bp["in_proj"]), jnp.float32)
                di = d.d_inner
                z = ip[:, :, :di].reshape(L, d.d_model, H, P).sum((1, 3))
                xx = ip[:, :, di:2 * di].reshape(L, d.d_model, H, P).sum((1, 3))
                op = jnp.asarray(agg(bp["out_proj"]), jnp.float32).reshape(L, H, P, d.d_model).sum((2, 3))
                s["head"] = z + xx + op
            if s:
                st_scores[spec.name] = s
        out[st.name] = st_scores
    return out


def magnitude_scores(plan: Plan, params) -> Dict:
    return _group_scores_from_tree(plan, params, lambda w: jnp.abs(jnp.asarray(w, jnp.float32)))


def taylor_scores(plan: Plan, params, grads) -> Dict:
    """LLM-Pruner first-order Taylor: |w ∘ ∂L/∂w| per group."""
    prod = jax.tree.map(lambda w, g: jnp.abs(w.astype(jnp.float32) * g.astype(jnp.float32)),
                        params, grads)
    return _group_scores_from_tree(plan, prod, lambda x: x)


def random_scores(plan: Plan, seed: int) -> Dict:
    key = jax.random.PRNGKey(seed)
    out: Dict = {}
    for st in plan.stages:
        d = st.dims
        st_s: Dict = {}
        for spec in st.superblock:
            if spec.shared:
                continue
            k = jax.random.fold_in(key, hash((st.name, spec.name)) % (2**31))
            s: Dict[str, Array] = {}
            if spec.kind == "mlp":
                s["ff"] = jax.random.uniform(k, (st.n_rep, d.d_ff))
            elif spec.kind in ("attn", "cross_attn"):
                s["kv"] = jax.random.uniform(k, (st.n_rep, d.n_kv_heads))
            elif spec.kind == "moe":
                s["expert"] = jax.random.uniform(k, (st.n_rep, d.n_experts))
                if d.dense_residual_d_ff:
                    s["resid_ff"] = jax.random.uniform(jax.random.fold_in(k, 1),
                                                       (st.n_rep, d.dense_residual_d_ff))
            elif spec.kind == "mamba":
                s["head"] = jax.random.uniform(k, (st.n_rep, d.ssm_heads))
            if s:
                st_s[spec.name] = s
        out[st.name] = st_s
    return out


def calibration_taylor_scores(plan: Plan, params, batch, loss_fn) -> Dict:
    """Compute grads of the SFT loss wrt *base* params on a calibration batch
    (the offline step of LLM-Pruner) and reduce to group scores."""
    grads = jax.grad(lambda p: loss_fn(p, batch))(params)
    return taylor_scores(plan, params, grads)


# ---------------------------------------------------------------------------
# Keep-count policy (TPU-aligned)
# ---------------------------------------------------------------------------

def _keep_counts(d: StageDims, ratio: float,
                 prunable_kinds: Optional[set] = None) -> Dict[str, int]:
    """prunable_kinds: block kinds present NON-shared in the superblock —
    shared blocks (zamba2's attn/mlp, deepseek's shared experts) keep full
    params, so their dims must not shrink."""
    ok = prunable_kinds if prunable_kinds is not None else {
        "mlp", "attn", "moe", "mamba"}
    keep = {}
    if d.d_ff and "mlp" in ok:
        keep["ff"] = min(d.d_ff, round_to(int(round(d.d_ff * (1 - ratio))), 128))
    if "attn" in ok and d.n_kv_heads > 1:
        keep["kv"] = max(1, int(round(d.n_kv_heads * (1 - ratio))))
    elif "attn" in ok and d.n_kv_heads == 1:
        keep["kv"] = 1  # MQA: head pruning would break the single KV head
    if d.n_experts and "moe" in ok:
        keep["expert"] = max(d.top_k + 1, int(round(d.n_experts * (1 - ratio))))
    if d.dense_residual_d_ff and "moe" in ok:
        keep["resid_ff"] = min(
            d.dense_residual_d_ff,
            round_to(int(round(d.dense_residual_d_ff * (1 - ratio))), 128))
    if d.ssm_heads and "mamba" in ok:
        k = max(2, int(round(d.ssm_heads * (1 - ratio))))
        keep["head"] = k - (k % 2)  # even head count → 128-aligned channels
    return keep


def pruned_dims(d: StageDims, keep: Dict[str, int]) -> StageDims:
    kw: Dict[str, Any] = {}
    if "ff" in keep:
        kw["d_ff"] = keep["ff"]
    if "kv" in keep and d.n_kv_heads:
        gs = d.n_heads // d.n_kv_heads
        kw["n_kv_heads"] = keep["kv"]
        kw["n_heads"] = keep["kv"] * gs
    if "expert" in keep:
        kw["n_experts"] = keep["expert"]
    if "resid_ff" in keep:
        kw["dense_residual_d_ff"] = keep["resid_ff"]
    if "head" in keep:
        kw["ssm_heads"] = keep["head"]
        kw["d_inner"] = keep["head"] * d.ssm_head_dim
    return replace(d, **kw)


# ---------------------------------------------------------------------------
# Index building: group scores → flat channel indices per weight
# ---------------------------------------------------------------------------

def _topk_idx(scores: Array, k: int) -> np.ndarray:
    """(L, N) scores → (L, k) kept indices, sorted ascending per layer."""
    s = np.asarray(scores, np.float64)
    part = np.argpartition(-s, kth=min(k, s.shape[1] - 1), axis=1)[:, :k]
    return np.sort(part, axis=1).astype(np.int32)


def _expand_groups(group_idx: np.ndarray, width: int) -> np.ndarray:
    """(L, G_keep) group ids → (L, G_keep·width) flat channel indices."""
    L, g = group_idx.shape
    base = group_idx[:, :, None] * width + np.arange(width)[None, None, :]
    return base.reshape(L, g * width).astype(np.int32)


def _block_weight_prunes(kind: str, d: StageDims, keep: Dict[str, int],
                         scores: Dict[str, Array]) -> Dict[str, List[WeightPrune]]:
    out: Dict[str, List[WeightPrune]] = {}
    if kind == "mlp" and "ff" in keep and keep["ff"] < d.d_ff:
        idx = _topk_idx(scores["ff"], keep["ff"])
        out["wg"] = [WeightPrune(2, idx, "out")]
        out["wu"] = [WeightPrune(2, idx, "out")]
        out["wd"] = [WeightPrune(1, idx, "in")]
    elif kind in ("attn", "cross_attn") and "kv" in keep and keep["kv"] < d.n_kv_heads:
        G, gs, hd = d.n_kv_heads, d.n_heads // d.n_kv_heads, d.head_dim
        gi = _topk_idx(scores["kv"], keep["kv"])
        q_idx = _expand_groups(gi, gs * hd)
        kv_idx = _expand_groups(gi, hd)
        out["wq"] = [WeightPrune(2, q_idx, "out")]
        out["wk"] = [WeightPrune(2, kv_idx, "out")]
        out["wv"] = [WeightPrune(2, kv_idx, "out")]
        out["wo"] = [WeightPrune(1, q_idx, "in")]
    elif kind == "moe":
        if "expert" in keep and keep["expert"] < d.n_experts:
            ei = _topk_idx(scores["expert"], keep["expert"])
            out["we_g"] = [WeightPrune(1, ei, "aux")]
            out["we_u"] = [WeightPrune(1, ei, "aux")]
            out["we_d"] = [WeightPrune(1, ei, "aux")]
            out["router"] = [WeightPrune(2, ei, "out")]
        if "resid_ff" in keep and keep["resid_ff"] < d.dense_residual_d_ff:
            ri = _topk_idx(scores["resid_ff"], keep["resid_ff"])
            out["wr_g"] = [WeightPrune(2, ri, "out")]
            out["wr_u"] = [WeightPrune(2, ri, "out")]
            out["wr_d"] = [WeightPrune(1, ri, "in")]
    elif kind == "mamba" and "head" in keep and keep["head"] < d.ssm_heads:
        H, P, N, di = d.ssm_heads, d.ssm_head_dim, d.ssm_state, d.d_inner
        hi = _topk_idx(scores["head"], keep["head"])
        ch = _expand_groups(hi, P)                       # kept d_inner channels
        L, nk = hi.shape
        nch = ch.shape[1]
        # in_proj column layout: [z(di), x(di), B(N), C(N), dt(H)]
        bc = np.broadcast_to(np.arange(2 * N, dtype=np.int32)[None], (L, 2 * N))
        cols = np.concatenate([ch, di + ch, 2 * di + bc, 2 * di + 2 * N + hi], axis=1)
        out["in_proj"] = [WeightPrune(2, cols, "out")]
        # conv channels: [x(di), B(N), C(N)]
        conv_cols = np.concatenate([ch, di + bc], axis=1)
        out["conv_w"] = [WeightPrune(2, conv_cols, "aux")]
        out["dt_bias"] = [WeightPrune(1, hi, "aux")]
        out["a_log"] = [WeightPrune(1, hi, "aux")]
        out["d_skip"] = [WeightPrune(1, hi, "aux")]
        out["out_norm"] = [WeightPrune(1, ch, "aux")]
        out["out_proj"] = [WeightPrune(1, ch, "in")]
    return out


# ---------------------------------------------------------------------------
# Plan-level structured pruning
# ---------------------------------------------------------------------------

def build_structured_spec(
    plan: Plan, loram: LoRAMConfig, scores: Dict,
) -> Tuple[Plan, PruneSpec]:
    """Split each stage into [head|mid|tail], prune the mid stage."""
    assert loram.method in ("rand", "stru")
    new_stages: List[Stage] = []
    stage_specs: Dict = {}
    stage_slices: Dict = {}

    for st in plan.stages:
        kf, kl = loram.keep_first, loram.keep_last
        # layers → superblock repetitions (round up to superblock boundary)
        mixers = max(1, sum(1 for b in st.superblock if b.kind in ("attn", "enc_attn", "mamba")))
        kf_rep = -(-kf // mixers) if kf else 0
        kl_rep = -(-kl // mixers) if kl else 0
        if st.n_rep - kf_rep - kl_rep < 1:
            kf_rep = kl_rep = 0  # stage too shallow to split: prune everything
        mid = st.n_rep - kf_rep - kl_rep

        prunable = {b.kind for b in st.superblock if not b.shared}
        if "cross_attn" in prunable:
            prunable.add("attn")   # enc-dec: self+cross pruned together
        keep = _keep_counts(st.dims, loram.ratio, prunable)
        pd = pruned_dims(st.dims, keep)

        def add(name, rep, dims, lo, hi):
            new_stages.append(Stage(st.superblock, rep, dims, name))
            stage_slices[name] = (st.name, lo, hi)

        if kf_rep:
            add(st.name + "_head", kf_rep, st.dims, 0, kf_rep)
        mid_name = st.name + "_mid" if (kf_rep or kl_rep) else st.name
        add(mid_name, mid, pd, kf_rep, kf_rep + mid)
        if kl_rep:
            add(st.name + "_tail", kl_rep, st.dims, kf_rep + mid, st.n_rep)

        blocks: Dict = {}
        for spec in st.superblock:
            if spec.shared or spec.name not in scores.get(st.name, {}):
                continue
            sc = {k: np.asarray(v)[kf_rep:kf_rep + mid] for k, v in scores[st.name][spec.name].items()}
            wp = _block_weight_prunes(spec.kind, st.dims, keep, sc)
            if wp:
                blocks[spec.name] = wp
        stage_specs[mid_name] = blocks

    small_plan = Plan(plan.cfg, tuple(new_stages), plan.enc_stages)
    spec = PruneSpec(loram.method, loram.ratio, stage_specs, stage_slices)
    return small_plan, spec


def prune_params(params, plan: Plan, small_plan: Plan, spec: PruneSpec):
    """Gather the full param tree into the pruned (small) tree."""
    new_stages = {}
    for st in small_plan.stages:
        orig, lo, hi = spec.stage_slices[st.name]
        src = params["stages"][orig]
        sliced = jax.tree.map(lambda x: x[lo:hi], src["stacked"])
        blocks = spec.stage_specs.get(st.name, {})
        for bname, wps in blocks.items():
            bp = dict(sliced[bname])
            for pname, plist in wps.items():
                w = bp[pname]
                for wp in plist:
                    idx = jnp.asarray(wp.idx)
                    shape = [1] * w.ndim
                    shape[0] = idx.shape[0]
                    shape[wp.axis] = idx.shape[1]
                    w = jnp.take_along_axis(w, idx.reshape(shape), axis=wp.axis)
                bp[pname] = w
            sliced[bname] = bp
        new_stages[st.name] = {"stacked": sliced, "shared": src["shared"]}
    out = dict(params)
    out["stages"] = new_stages
    return out


# ---------------------------------------------------------------------------
# Lossless-prune construction (bench / test harness)
# ---------------------------------------------------------------------------

def zero_prunable_tail(params, plan: Plan, ratio: float):
    """Zero exactly the FFN channels / KV groups that magnitude-structured
    pruning at ``ratio`` will remove, making P(·) LOSSLESS: the pruned model
    computes the full model's function, so a speculative draft built from it
    accepts ~100% of proposals.  Dense (mlp + attn) blocks only — callers
    benchmarking MoE/SSM acceptance need their own construction.  Keep counts
    come from the same :func:`_keep_counts` policy pruning itself uses, so
    the two can never drift apart."""
    out = jax.tree.map(lambda x: x, params)
    for st in plan.stages:
        d = st.dims
        keep = _keep_counts(d, ratio)
        for spec in st.superblock:
            if spec.shared:
                continue
            bp = dict(out["stages"][st.name]["stacked"][spec.name])
            if spec.kind == "mlp" and "ff" in keep:
                bp["wg"] = bp["wg"].at[:, :, keep["ff"]:].set(0.0)
                bp["wu"] = bp["wu"].at[:, :, keep["ff"]:].set(0.0)
                bp["wd"] = bp["wd"].at[:, keep["ff"]:, :].set(0.0)
            elif spec.kind == "attn" and "kv" in keep:
                gs, hd = d.n_heads // d.n_kv_heads, d.head_dim
                bp["wq"] = bp["wq"].at[:, :, keep["kv"] * gs * hd:].set(0.0)
                bp["wk"] = bp["wk"].at[:, :, keep["kv"] * hd:].set(0.0)
                bp["wv"] = bp["wv"].at[:, :, keep["kv"] * hd:].set(0.0)
                bp["wo"] = bp["wo"].at[:, keep["kv"] * gs * hd:, :].set(0.0)
            out["stages"][st.name]["stacked"][spec.name] = bp
    return out


# ---------------------------------------------------------------------------
# Non-structured masks (semi 4:8 / unstructured)
# ---------------------------------------------------------------------------

_MASKABLE = {"wq", "wk", "wv", "wo", "wg", "wu", "wd", "in_proj", "out_proj",
             "ws_g", "ws_u", "ws_d", "wr_g", "wr_u", "wr_d"}


def _semi_mask(w: Array, n: int, m: int) -> Array:
    """Keep the n largest-magnitude of every m consecutive weights along the
    input axis (axis -2 of the stacked (L, d_in, d_out) weight)."""
    l, d_in, d_out = w.shape
    assert d_in % m == 0
    wa = jnp.abs(w.astype(jnp.float32)).reshape(l, d_in // m, m, d_out)
    thresh = -jnp.sort(-wa, axis=2)[:, :, n - 1 : n, :]
    mask = wa >= thresh
    return mask.reshape(l, d_in, d_out)


def _unst_mask(w: Array, ratio: float) -> Array:
    l = w.shape[0]
    wa = jnp.abs(w.astype(jnp.float32)).reshape(l, -1)
    k = int(wa.shape[1] * (1 - ratio))
    thresh = -jnp.sort(-wa, axis=1)[:, k - 1 : k]
    return (wa >= thresh).reshape(w.shape)


def build_mask_spec(plan: Plan, params, loram: LoRAMConfig) -> Tuple[Plan, PruneSpec]:
    assert loram.method in ("semi", "unst")
    masks: Dict = {}
    for st in plan.stages:
        st_m: Dict = {}
        for spec_b in st.superblock:
            if spec_b.shared:
                continue
            bp = params["stages"][st.name]["stacked"].get(spec_b.name, {})
            bm = {}
            for pname, w in bp.items():
                if pname not in _MASKABLE or w.ndim != 3:
                    continue
                if loram.method == "semi":
                    if w.shape[1] % loram.semi_m:
                        continue
                    bm[pname] = _semi_mask(w, loram.semi_n, loram.semi_m)
                else:
                    bm[pname] = _unst_mask(w, loram.ratio)
            if bm:
                st_m[spec_b.name] = bm
        masks[st.name] = {"stacked": st_m}
    slices = {st.name: (st.name, 0, st.n_rep) for st in plan.stages}
    spec = PruneSpec(loram.method, loram.ratio, {}, slices, masks={"stages": masks})
    return plan, spec  # plan unchanged: masked-dense


def apply_masks_to_params(params, spec: PruneSpec):
    """Bake masks into the frozen base (W0∘M) so training needn't re-mask."""
    if not spec.masks:
        return params
    out = jax.tree.map(lambda x: x, params)  # shallow-ish copy
    for stn, stm in spec.masks["stages"].items():
        for bn, bm in stm["stacked"].items():
            for pn, m in bm.items():
                w = out["stages"][stn]["stacked"][bn][pn]
                out["stages"][stn]["stacked"][bn][pn] = (w * m.astype(w.dtype)).astype(w.dtype)
    return out


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def prune(plan: Plan, params, loram: LoRAMConfig, *, scores: Optional[Dict] = None):
    """Full P(·): returns (small_plan, small_params, spec).

    For ``rand``/``stru``, ``scores`` defaults to random / magnitude resp.
    (callers wanting true Taylor importance pass ``calibration_taylor_scores``
    output — used by the e2e example and tests).
    """
    if loram.method == "none" or loram.ratio == 0.0:
        slices = {st.name: (st.name, 0, st.n_rep) for st in plan.stages}
        return plan, params, PruneSpec("none", 0.0, {}, slices)
    if loram.method in ("rand", "stru"):
        if scores is None:
            scores = (random_scores(plan, loram.seed) if loram.method == "rand"
                      else magnitude_scores(plan, params))
        small_plan, spec = build_structured_spec(plan, loram, scores)
        small_params = prune_params(params, plan, small_plan, spec)
        return small_plan, small_params, spec
    small_plan, spec = build_mask_spec(plan, params, loram)
    small_params = apply_masks_to_params(params, spec)
    return small_plan, small_params, spec


def param_count(params) -> int:
    from repro.quant.nf4 import QTensor
    total = 0
    for leaf in jax.tree_util.tree_leaves(params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += int(np.prod(leaf.shape))
        else:
            total += leaf.size
    return total


def reduction_ratio(full_params, small_params) -> float:
    return param_count(full_params) / max(1, param_count(small_params))

"""R(·): recovery of trained low-rank matrices + Eq.(6) merge.

Structured LoRAM: the adapters were trained at pruned widths; recovery
scatters their rows/cols back to the original coordinates (zeros at pruned
positions), so the full-rank delta ``Bᴿ Aᴿ`` is non-zero **only on the
retained coordinates** — merging never perturbs weights that were pruned
away during training (they are "essential for inference" and stay at their
pre-trained values).

Note on the paper's Eq.(5)/(6): as printed they mask with ``(1−Mᴾ)``, which
would place the delta on *pruned* coordinates — contradicting the paper's own
Fig. 1, §1 intuition ("updating the weights retained through pruning …
employing the pruned weights during inference") and Appendix C's dimension
walk-through.  We implement the semantics of the figure/appendix (delta on
retained coordinates); see DESIGN.md §7.

Non-structured LoRAM (paper C₃): recovery is the identity on (B, A).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import PruneSpec, WeightPrune
from repro.models.model import Plan

Array = jax.Array


def _scatter_rows(full_n: int, idx: Array, x: Array) -> Array:
    """x: (L, k, ...) → (L, full_n, ...) with rows placed at idx (L, k)."""
    L = x.shape[0]
    out = jnp.zeros((L, full_n) + x.shape[2:], x.dtype)
    return jax.vmap(lambda o, i, v: o.at[i].set(v))(out, jnp.asarray(idx), x)


def _recover_block_lora(blora: dict, wps: Dict[str, list], shapes: Dict[str, tuple]) -> dict:
    """Scatter one block's LoRA adapters back to full dims.

    blora[param] = {"a": (L, r, d_in_small), "b": (L, d_out_small, r)}
    shapes[param] = full (d_in, d_out).
    """
    out = {}
    for pname, ab in blora.items():
        a, b = ab["a"], ab["b"]
        full_in, full_out = shapes[pname]
        for wp in wps.get(pname, []):
            if wp.role == "in":      # pruned input dim → scatter A columns
                a_t = jnp.swapaxes(a, 1, 2)                     # (L, d_in_s, r)
                a = jnp.swapaxes(_scatter_rows(full_in, wp.idx, a_t), 1, 2)
            elif wp.role == "out":   # pruned output dim → scatter B rows
                b = _scatter_rows(full_out, wp.idx, b)
        out[pname] = {"a": a, "b": b}
    return out


def recover_lora(small_lora, spec: PruneSpec, full_plan: Plan, small_plan: Plan):
    """Map LoRA adapters trained on the small plan back onto the full plan.

    Handles the [head|mid|tail] stage split: head/tail adapters pass through,
    mid adapters are scattered, then the three are re-stacked in layer order.
    """
    if not spec.structured or spec.method == "none":
        return small_lora

    from repro.models.model import _block_param_shapes  # full-dim shapes

    # group small stages by their original stage, in slice order
    by_orig: Dict[str, list] = {}
    for st in small_plan.stages:
        orig, lo, hi = spec.stage_slices[st.name]
        by_orig.setdefault(orig, []).append((lo, st))
    for v in by_orig.values():
        v.sort(key=lambda t: t[0])

    full_stage_by_name = {st.name: st for st in full_plan.stages}
    out_stages = {}
    for orig, parts in by_orig.items():
        full_st = full_stage_by_name[orig]
        shapes = {spec_b.name: {p: s for p, s in _block_param_shapes(spec_b, full_st.dims).items()
                                if len(s) == 2}
                  for spec_b in full_st.superblock}

        pieces = []  # list of per-part stacked lora dicts (full dims)
        shared = None
        for _, st in parts:
            sl = small_lora["stages"][st.name]
            stacked = sl["stacked"]
            wps_blocks = spec.stage_specs.get(st.name, {})
            fixed = {}
            for bname, blora in stacked.items():
                fixed[bname] = _recover_block_lora(blora, wps_blocks.get(bname, {}),
                                                   shapes[bname])
            pieces.append(fixed)
            if sl.get("shared"):
                shared = sl["shared"]

        merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *pieces)
        out_stages[orig] = {"stacked": merged, "shared": shared or {}}

    out = {"stages": out_stages}
    for k in ("enc_stages", "lm_head"):
        if k in small_lora:
            out[k] = small_lora[k]
    return out


# ---------------------------------------------------------------------------
# Eq.(6): merge recovered adapters into the full model for inference
# ---------------------------------------------------------------------------

def _merge_one(w: Array, ab: dict, scale: float) -> Array:
    a = ab["a"].astype(jnp.float32)          # (..., r, d_in)
    b = ab["b"].astype(jnp.float32)          # (..., d_out, r)
    if w.ndim == 2:
        delta = (b @ a).T                     # (d_in, d_out)
    else:
        delta = jnp.einsum("lor,lri->lio", b, a)
    return (w.astype(jnp.float32) + scale * delta).astype(w.dtype)


def merge_lora(params, lora, scale: float):
    """W ← W + scale·(BA)ᵀ everywhere an adapter exists.  Returns new params."""
    out = jax.tree.map(lambda x: x, params)

    def merge_section(psec, lsec):
        for bname, blora in (lsec or {}).items():
            for pname, ab in blora.items():
                psec[bname] = dict(psec[bname])
                psec[bname][pname] = _merge_one(psec[bname][pname], ab, scale)

    for key in ("stages", "enc_stages"):
        if key not in lora or key not in out:
            continue
        for stn, sl in lora[key].items():
            sec = out[key][stn]
            sec["stacked"] = dict(sec["stacked"])
            merge_section(sec["stacked"], sl.get("stacked"))
            sec["shared"] = dict(sec["shared"])
            merge_section(sec["shared"], sl.get("shared"))
    if "lm_head" in lora and "lm_head" in out:
        out["lm_head"] = _merge_one(out["lm_head"], lora["lm_head"], scale)
    return out


def delta_support_check(spec: PruneSpec, full_plan: Plan, lora_full) -> bool:
    """Invariant (tested): the recovered delta is zero on pruned coordinates."""
    for st_name, blocks in spec.stage_specs.items():
        orig = spec.stage_slices[st_name][0]
        for bname, wps in blocks.items():
            blora = lora_full["stages"][orig]["stacked"].get(bname)
            if blora is None:
                continue
            for pname, plist in wps.items():
                if pname not in blora:
                    continue
                for wp in plist:
                    if wp.role == "aux":
                        continue
                    lo, hi = spec.stage_slices[st_name][1:]
                    if wp.role == "out":
                        b = np.asarray(blora[pname]["b"][lo:hi], np.float32)
                        full = np.ones(b.shape[1], bool)
                        for li in range(b.shape[0]):
                            mask = full.copy()
                            mask[np.asarray(wp.idx)[li]] = False
                            if np.abs(b[li][mask]).max(initial=0) != 0:
                                return False
                    else:
                        a = np.asarray(blora[pname]["a"][lo:hi], np.float32)
                        for li in range(a.shape[0]):
                            mask = np.ones(a.shape[2], bool)
                            mask[np.asarray(wp.idx)[li]] = False
                            if np.abs(a[li][:, mask]).max(initial=0) != 0:
                                return False
    return True

"""Deterministic, seeded fault injection for the serving engines.

A :class:`FaultPlan` bundles one :class:`FaultSpec` per injection site;
the engines consult it (when installed via ``engine.install_faults``)
at their existing host-side choke points:

* ``tick``    — raise :class:`TransientFault` immediately BEFORE the
  jitted decode tick / speculative round dispatch.  The engine's
  bounded retry-with-backoff absorbs it; exhaustion escalates to
  snapshot-and-restart.  Injection happens pre-dispatch, so donated
  device buffers are never left half-consumed.
* ``alloc``   — raise :class:`repro.serving.pages.PoolExhausted` at the
  page-growth sites that already handle exhaustion, exercising the
  reclaim/preempt machinery on demand.
* ``stall``   — ``time.sleep(spec.sleep_s)`` inside the watchdog's tick
  window, so the EWMA straggler detector (and its escalation ladder)
  sees a genuine wall-clock stall.
* ``adapter`` — fail a request at admission (adapter-load failure); the
  engine terminates it with ``status="failed"``.

Determinism: each site draws from its own ``random.Random`` stream
seeded from ``(plan seed, site name)``, advanced once per consult.
Because the engines consult sites in a deterministic order for a given
workload, the same plan + workload always fires the same faults.  A
spec can also name explicit consult indices (``at``), which is the
sharpest tool for regression tests.  ``max_fires`` bounds any
probabilistic site (an unbounded p=1.0 ``alloc`` site would starve the
reclaim loop's progress guarantee).

The plan is JSON-representable for the launcher's ``--fault-plan``::

    {"seed": 7,
     "tick":  {"p": 0.3, "max_fires": 4},
     "alloc": {"at": [1, 3]},
     "stall": {"p": 0.2, "sleep_s": 0.002, "max_fires": 3}}
"""
from __future__ import annotations

import json
import random
import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, Tuple

FAULT_SITES = ("tick", "alloc", "stall", "adapter")


class TransientFault(RuntimeError):
    """Injected transient failure of a tick/round dispatch."""


@dataclass(frozen=True)
class FaultSpec:
    """When one site fires.

    p:         per-consult probability (seeded stream).
    at:        explicit 1-based consult indices that always fire.
    max_fires: cap on total fires for this site (0 → unlimited; applies
               to the probabilistic part AND the explicit indices).
    sleep_s:   stall duration (``stall`` site only).
    """

    p: float = 0.0
    at: Tuple[int, ...] = ()
    max_fires: int = 0
    sleep_s: float = 0.0

    def __post_init__(self):
        assert 0.0 <= self.p <= 1.0, self.p
        assert all(i >= 1 for i in self.at), self.at
        assert self.max_fires >= 0 and self.sleep_s >= 0.0


class FaultPlan:
    """Seeded injectors, one stream per site, consult-counted."""

    def __init__(self, seed: int = 0, **sites):
        self.seed = seed
        self.specs: Dict[str, FaultSpec] = {}
        for name, spec in sites.items():
            assert name in FAULT_SITES, name
            if isinstance(spec, dict):
                spec = FaultSpec(p=spec.get("p", 0.0),
                                 at=tuple(spec.get("at", ())),
                                 max_fires=spec.get("max_fires", 0),
                                 sleep_s=spec.get("sleep_s", 0.0))
            self.specs[name] = spec
        self._rng = {name: random.Random((seed << 32)
                                         ^ zlib.crc32(name.encode()))
                     for name in self.specs}
        self.consults = {name: 0 for name in FAULT_SITES}
        self.fires = {name: 0 for name in FAULT_SITES}

    @classmethod
    def from_json(cls, src) -> "FaultPlan":
        """Build from a JSON string, a parsed dict, or a file path."""
        if isinstance(src, str):
            src = src.strip()
            if src.startswith("{"):
                src = json.loads(src)
            else:
                with open(src) as f:
                    src = json.load(f)
        assert isinstance(src, dict), type(src)
        src = dict(src)
        seed = src.pop("seed", 0)
        return cls(seed, **src)

    # -- consultation -------------------------------------------------------
    def fire(self, site: str) -> bool:
        """Advance the site's consult counter; True if the fault fires.

        The RNG stream advances on EVERY consult (fired or not, capped
        or not) so adding ``max_fires`` never re-times later faults.
        """
        self.consults[site] += 1
        spec = self.specs.get(site)
        if spec is None:
            return False
        i = self.consults[site]
        draw = self._rng[site].random() if spec.p else 1.0
        hit = (i in spec.at) or (draw < spec.p)
        if not hit:
            return False
        if spec.max_fires and self.fires[site] >= spec.max_fires:
            return False
        self.fires[site] += 1
        return True

    # -- site-shaped helpers the engines call -------------------------------
    def raise_if_tick(self):
        if self.fire("tick"):
            raise TransientFault(
                f"injected tick fault #{self.fires['tick']}")

    def check_alloc(self):
        if self.fire("alloc"):
            # imported lazily: testing.faults must not drag serving in
            # at module import time (serving imports are heavyweight)
            from repro.serving.pages import PoolExhausted
            raise PoolExhausted(
                f"injected allocation failure #{self.fires['alloc']}")

    def maybe_stall(self):
        if self.fire("stall"):
            time.sleep(self.specs["stall"].sleep_s)

    def adapter_load_fails(self) -> bool:
        return self.fire("adapter")

    def report(self) -> dict:
        """Consult/fire tallies (for logs and bench sections)."""
        return {"seed": self.seed,
                "consults": dict(self.consults),
                "fires": dict(self.fires)}

"""Deterministic test harnesses for the serving stack.

repro.testing.faults — seeded fault injection (FaultPlan) consulted by
the serving engines at their existing host-side choke points.
"""
from repro.testing.faults import (  # noqa: F401
    FAULT_SITES,
    FaultPlan,
    FaultSpec,
    TransientFault,
)

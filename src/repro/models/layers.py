"""Layer primitives shared by every architecture in the zoo.

All functions are pure; parameters are plain dicts of jnp arrays.  Every
weight-bearing projection routes through :func:`dense`, which is the single
LoRA / NF4-quantization / sparsity-mask injection point for the whole
framework — the LoRAM technique composes with any architecture that uses it.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed import sharding
from repro.kernels import nf4_matmul as _nf4k
from repro.kernels import ops as kops
from repro.quant import nf4

Array = jax.Array


def _nf4_fusable(q: nf4.QTensor, M: int, mask) -> bool:
    """Can this projection run through the fused NF4 matmul kernel?

    2-D codes only (stacked stage params become 2-D inside the decode-tick
    ``lax.scan``, so the serving hot path qualifies), plain (not
    double-quantized) scales, no sparsity mask, and tile-divisible shapes —
    the Pallas kernel clamps each block dim to the array dim but requires
    the remainder to divide evenly.  Anything else falls back to
    dequantize-then-matmul.
    """
    if q.codes.ndim != 2 or mask is not None:
        return False
    if isinstance(q.scales, nf4.DQScales):
        return False
    K = q.codes.shape[0] * 2
    N = q.codes.shape[1]
    if q.scales.shape[0] * _nf4k.QBLOCK != K:
        return False
    return (M % min(_nf4k.DEFAULT_BM, M) == 0
            and N % min(_nf4k.DEFAULT_BN, N) == 0
            and K % min(_nf4k.DEFAULT_BK, K) == 0
            and min(_nf4k.DEFAULT_BK, K) % _nf4k.QBLOCK == 0)

# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


# ---------------------------------------------------------------------------
# The universal projection: base weight (+NF4) (+mask) (+LoRA)
# ---------------------------------------------------------------------------

def dense(
    x: Array,
    w,                               # Array | nf4.QTensor
    lora: Optional[dict] = None,     # {"a": (r, d_in), "b": (d_out, r)}
    lora_scale: float = 2.0,
    mask: Optional[Array] = None,    # element mask for semi/unst LoRAM
    accum_fp32: bool = False,        # fp32 MXU accumulation (lm_head/loss path)
    adapter_ids: Optional[Array] = None,  # (B,) routes a stacked adapter bank
) -> Array:
    """``y = x @ W (∘M) + scale · (x @ Aᵀ) @ Bᵀ (∘M applied to BA via stop-grad
    masking of the delta contribution — see DESIGN.md C2 note)``.

    x: (..., d_in); returns (..., d_out).

    Multi-adapter serving: when ``lora`` holds a stacked bank —
    ``a: (K, r, d_in)``, ``b: (K, d_out, r)`` — each leading-axis row of ``x``
    is routed to adapter ``adapter_ids[row]`` via a gather, so one batched
    matmul serves K different LoRAM-recovered adapters at once.  Under the
    paged adapter bank ``adapter_ids`` carry device-bank ROWS (resolved at
    admission by ``serving/adapters.AdapterResidency``); the gather is
    unchanged, and padding is free by construction: a zeroed row (evicted /
    never uploaded / the reserved base row 0) contributes ``B·A = 0``, and
    a rank-bucketed adapter's zero tail rows of ``A`` / columns of ``B``
    likewise cancel in the two einsums — zero-padding is exactly
    zero-delta, so the bank serves mixed-rank adapters and base traffic
    through one fixed-shape gather.
    """
    lead = x.shape[:-1]
    M = 1
    for s in lead:
        M *= s
    if isinstance(w, nf4.QTensor) and _nf4_fusable(w, M, mask):
        # QLoRAM serving hot path: the frozen base matmul runs fused —
        # packed codes stream from HBM and dequantize in-kernel (VREG
        # unpack + codebook selection tree), never materialising the fp
        # weight.  kernels/ops dispatches Pallas on TPU, the jnp oracle
        # elsewhere; numerics match dequantize-then-matmul (tested in
        # tests/test_quant.py).
        out_dt = jnp.float32 if accum_fp32 else x.dtype
        y = kops.nf4_matmul(x.reshape(M, x.shape[-1]), w.codes, w.scales,
                            out_dtype=out_dt)
        y = y.reshape(*lead, w.codes.shape[1])
    else:
        if isinstance(w, nf4.QTensor):
            wd = (nf4.dequantize_stacked(w, dtype=x.dtype)
                  if w.codes.ndim == 3 else nf4.dequantize(w, dtype=x.dtype))
        else:
            wd = w.astype(x.dtype) if w.dtype != x.dtype else w
        if mask is not None:
            wd = wd * mask.astype(wd.dtype)
        if accum_fp32:
            y = jnp.matmul(x, wd, preferred_element_type=jnp.float32)
        else:
            y = x @ wd
    if lora is not None:
        a = lora["a"].astype(x.dtype)    # (r, d_in) or (K, r, d_in)
        b = lora["b"].astype(x.dtype)    # (d_out, r) or (K, d_out, r)
        if mask is not None:
            # Non-structured LoRAM (paper C2): the delta must live on the same
            # support as the pruned base.  Materialising (BA)∘M is O(d_in·d_out)
            # per call; we instead mask the *base* above and keep the low-rank
            # path dense — per paper C3 the recovery for non-structured LoRAM
            # is the identity, so the trained factors are used as-is.
            pass
        scale = jnp.asarray(lora_scale, x.dtype)
        if a.ndim == 3:
            assert adapter_ids is not None, (
                "stacked LoRA bank requires per-row adapter_ids")
            a_sel = a[adapter_ids]       # (B, r, d_in)
            b_sel = b[adapter_ids]       # (B, d_out, r)
            u = jnp.einsum("b...i,bri->b...r", x, a_sel)
            y = y + jnp.einsum("b...r,bor->b...o", u, b_sel) * scale
        else:
            y = y + ((x @ a.T) @ b.T) * scale
    return y


# ---------------------------------------------------------------------------
# Backward-dtype hygiene
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def grad_cast(x, dtype):
    """Identity forward; casts the cotangent to ``dtype`` on the way back.

    Inserted at the lm-head boundary: the CE loss and logits stay fp32, but
    without this the fp32 cotangent propagates through every backward matmul,
    forcing f32 copies of all weights (observed: +14 TB HBM traffic / step on
    yi-34b train_4k — see EXPERIMENTS.md §Perf iteration 1)."""
    return x


def _grad_cast_fwd(x, dtype):
    return x, ()


def _grad_cast_bwd(dtype, _res, g):
    return (g.astype(dtype),)


grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs     # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def repeat_kv(k: Array, n_rep: int) -> Array:
    """(B, S, K, D) → (B, S, K·n_rep, D) by group broadcast."""
    if n_rep == 1:
        return k
    b, s, kh, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, d)).reshape(b, s, kh * n_rep, d)


def _softmax_attn(q, k, v, mask, scale):
    # q: (B, Sq, H, D), k/v: (B, Sk, H, D), mask broadcastable to (B, H, Sq, Sk)
    # Scope name is load-bearing: hlo_analysis attributes the S² score traffic
    # to "attention_core" and substitutes the flash-kernel traffic for the
    # kernel-projected roofline (the Pallas kernel can't lower on this CPU
    # host; kernels/flash_attention.py is the TPU execution path).
    with jax.named_scope("attention_core"):
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    chunk_q: int = 0,
    segment_mask: Optional[Array] = None,
) -> Array:
    """Multi-head attention with GQA already expanded.

    chunk_q > 0 enables a flash-style jnp implementation: scan over query
    chunks with online softmax over key blocks — O(chunk·S) live memory, which
    is what keeps the 32k-prefill dry-run from materialising S² score tensors.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / (d ** 0.5)

    def mask_for(qpos, kpos):
        m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
        if causal:
            m &= kpos[None, :] <= qpos[:, None]
        if window:
            m &= kpos[None, :] > qpos[:, None] - window
        return m

    qpos_all = jnp.arange(sq) + q_offset
    kpos_all = jnp.arange(sk)

    # banded path: sliding-window attention only ever needs the last
    # ``window`` keys per query — compute (chunk_q, window+chunk_q) tiles
    # instead of masked (S, S) scores (gemma3 local layers: 25 GiB → ~2 GiB
    # live at train_4k; see EXPERIMENTS.md §Perf iteration 12)
    # (gated to ≥8k: at 4k the per-chunk K/V re-reads beat the score savings
    # — measured, §Perf iteration 12)
    banded = (causal and window and sq == sk and sq >= 2 * window
              and q_offset == 0 and sq >= 8192)

    if not banded and (not chunk_q or sq <= chunk_q):
        m = mask_for(qpos_all, kpos_all)[None, None]
        if segment_mask is not None:
            m = m & segment_mask
        return _softmax_attn(q, k, v, m, scale)

    if banded:
        cq = max(128, min(chunk_q or window, window))
        cq = min(cq, sq)
        while sq % cq:
            cq //= 2
        span = min(window + cq, sk)
        n_chunks = sq // cq
        qc = q.reshape(b, n_chunks, cq, h, d).transpose(1, 0, 2, 3, 4)

        def body_w(_, args):
            i, qi = args
            start = jnp.maximum(i * cq + cq - span, 0)
            kw = lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vw = lax.dynamic_slice_in_dim(v, start, span, axis=1)
            qpos = i * cq + jnp.arange(cq)
            kpos = start + jnp.arange(span)
            m = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - window)
            out = _softmax_attn(qi, kw, vw, m[None, None], scale)
            return None, out

        _, outs = lax.scan(body_w, None, (jnp.arange(n_chunks), qc))
        return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)

    assert sq % chunk_q == 0, (sq, chunk_q)
    n_chunks = sq // chunk_q
    qc = q.reshape(b, n_chunks, chunk_q, h, d).transpose(1, 0, 2, 3, 4)

    def body(_, args):
        i, qi = args
        qpos = i * chunk_q + jnp.arange(chunk_q) + q_offset
        m = (kpos_all[None, :] <= qpos[:, None]) if causal else jnp.ones((chunk_q, sk), bool)
        if window:
            m &= kpos_all[None, :] > qpos[:, None] - window
        out = _softmax_attn(qi, k, v, m[None, None], scale)
        return None, out

    _, outs = lax.scan(body, None, (jnp.arange(n_chunks), qc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)


def decode_attention(q: Array, k_cache: Array, v_cache: Array, cache_len: Array,
                     window: int = 0) -> Array:
    """Single-token attention against a KV cache.

    q: (B, 1, H, D); caches: (B, S_max, H, D); cache_len: () current length
    (the new token's K/V must already be written at position cache_len-1).

    The head constraint is context-gated (no-op outside a ``head_shard``
    mesh scope): under tensor-parallel serving each shard attends its own
    heads, and the whole per-head softmax/contraction stays local.
    """
    q = sharding.head_constraint(q)
    b, smax, h, d = k_cache.shape
    scale = 1.0 / (d ** 0.5)
    kpos = jnp.arange(smax)
    valid = kpos < cache_len
    if window:
        valid &= kpos >= cache_len - window
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache).astype(jnp.float32) * scale
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    return sharding.head_constraint(
        jnp.einsum("bhqk,bkhd->bqhd", probs, v_cache))


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def swiglu(x: Array, p: dict, lora: Optional[dict], lora_scale: float,
           masks: Optional[dict] = None,
           adapter_ids: Optional[Array] = None) -> Array:
    def l(name):
        return None if lora is None or name not in lora else lora[name]

    def m(name):
        return None if masks is None else masks.get(name)

    g = dense(x, p["wg"], l("wg"), lora_scale, m("wg"), adapter_ids=adapter_ids)
    u = dense(x, p["wu"], l("wu"), lora_scale, m("wu"), adapter_ids=adapter_ids)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return dense(h, p["wd"], l("wd"), lora_scale, m("wd"),
                 adapter_ids=adapter_ids)

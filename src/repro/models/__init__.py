from repro.models.model import (  # noqa: F401
    Plan,
    decode_step,
    forward,
    init_cache,
    init_lora,
    init_params,
    lora_param_count,
    make_plan,
    prefill,
)

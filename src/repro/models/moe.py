"""Mixture-of-Experts MLP (Switch/Mixtral-style top-k with capacity buffers).

Covers both assigned MoE archs:
  * arctic-480b      — 128 routed experts top-2 **plus a dense FFN residual**
  * deepseek-moe-16b — 64 fine-grained routed experts top-6 **plus 2 shared
                       experts** (never pruned by LoRAM — see DESIGN.md)

Dispatch is sort-free: top-k one-hot → per-expert position via cumsum →
scatter into (E, C, d) capacity buffers → experts run as a single stacked
einsum (EP: expert dim sharded over the ``model`` mesh axis) → weighted
combine.  Compute is O(E·C·d·f) with C ≈ S·k/E·cf, i.e. proportional to
*active* parameters — which is what makes the 6·N_active·D roofline term
honest for MoE cells.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding
from repro.models.layers import dense, swiglu
from repro.quant.nf4 import maybe_dequant

Array = jax.Array


def _capacity(n_tokens: int, n_experts: int, top_k: int, cf: float) -> int:
    c = int(n_tokens * top_k * cf / n_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_mlp(
    x: Array,                      # (B, S, D)
    p: dict,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    lora: Optional[dict] = None,
    lora_scale: float = 2.0,
    adapter_ids: Optional[Array] = None,   # (B,) multi-adapter routing
    lossless: bool = False,                # force drop-free capacity (verify)
) -> tuple[Array, Array]:
    """Returns (output, aux_loss)."""
    b, s, d = x.shape
    n_tok = b * s
    xe = x.reshape(n_tok, d)
    # shared/residual expert LoRA runs on flattened (B·S, D) tokens — expand
    # per-sequence adapter ids to per-token ids to match
    ids_tok = None if adapter_ids is None else jnp.repeat(adapter_ids, s)
    router = maybe_dequant(p["router"], jnp.float32)      # (D, E)
    e = router.shape[-1]
    cap = _capacity(n_tok, e, top_k, capacity_factor)
    if s == 1 or lossless:
        # single-token decode (and speculative verify, which batches B·T
        # tokens): capacity must be lossless.  With statistical capacity,
        # garbage tokens from free serving slots (or an unlucky routing draw)
        # can displace a live request's token from an expert buffer and
        # silently corrupt its output; n_tok is the decode batch (× the short
        # verify length), so the worst case (every token's k routes on one
        # expert) is cheap.
        cap = max(cap, n_tok * top_k)

    logits = (xe.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)               # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)     # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch): E * mean(f_e * p_e)
    me = jnp.mean(probs, axis=0)
    one_hot_all = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (T, k, E)
    fe = jnp.mean(jnp.sum(one_hot_all, axis=1), axis=0)
    aux = e * jnp.sum(me * fe)

    # position of each (token, k) inside its expert's capacity buffer
    flat_idx = gate_idx.reshape(-1)                               # (T·k,)
    one_hot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)        # (T·k, E)
    pos = jnp.cumsum(one_hot, axis=0) * one_hot                   # 1-based
    pos = jnp.sum(pos, axis=-1) - 1                               # (T·k,)
    keep = pos < cap                                              # drop overflow

    dest = flat_idx * cap + jnp.where(keep, pos, 0)
    buf = jnp.zeros((e * cap, d), xe.dtype)
    src = jnp.repeat(xe, top_k, axis=0)                           # (T·k, D)
    src = jnp.where(keep[:, None], src, 0)
    buf = buf.at[dest].add(src)                                   # scatter
    # expert-parallel constraint (context-gated; no-op without a mesh):
    # E → model keeps each expert's stacked SwiGLU wholly on one shard, so
    # the vmap below runs E/m experts per device with exact numerics — only
    # the scatter/gather either side of it crosses shards
    buf = sharding.expert_constraint(buf.reshape(e, cap, d))

    # stacked expert SwiGLU: weights (E, D, F) / (E, F, D)
    def ffn(buf_e, wg, wu, wd):
        g = jax.nn.silu((buf_e @ wg).astype(jnp.float32)).astype(buf_e.dtype)
        u = buf_e @ wu
        return (g * u) @ wd

    out_buf = jax.vmap(ffn)(buf, maybe_dequant(p["we_g"], xe.dtype),
                            maybe_dequant(p["we_u"], xe.dtype),
                            maybe_dequant(p["we_d"], xe.dtype))     # (E, C, D)
    out_buf = sharding.expert_constraint(out_buf).reshape(e * cap, d)

    gathered = out_buf[dest]                                       # (T·k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered * gate_vals.reshape(-1)[:, None].astype(gathered.dtype)
    out = jnp.sum(weighted.reshape(n_tok, top_k, d), axis=1)

    # shared experts (deepseek) — always-on dense SwiGLU path
    if "ws_g" in p:
        sp = {"wg": p["ws_g"], "wu": p["ws_u"], "wd": p["ws_d"]}
        out = out + swiglu(xe, sp, _strip(lora, "ws_"), lora_scale,
                           adapter_ids=ids_tok).reshape(n_tok, d)
    # dense residual FFN (arctic)
    if "wr_g" in p:
        rp = {"wg": p["wr_g"], "wu": p["wr_u"], "wd": p["wr_d"]}
        out = out + swiglu(xe, rp, _strip(lora, "wr_"), lora_scale,
                           adapter_ids=ids_tok).reshape(n_tok, d)

    return out.reshape(b, s, d), aux


def _strip(lora: Optional[dict], prefix: str) -> Optional[dict]:
    if lora is None:
        return None
    sub = {k[len(prefix):]: v for k, v in lora.items() if k.startswith(prefix)}
    # swiglu looks up "wg"/"wu"/"wd"; stripped keys are e.g. "g"→ need "wg"
    sub = {("w" + k if not k.startswith("w") else k): v for k, v in sub.items()}
    return sub or None

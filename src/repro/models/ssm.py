"""Mamba2 (state-space duality) mixer — used by mamba2-370m and zamba2-2.7b.

Implements the chunked SSD algorithm (Dao & Gu, 2024): within a chunk the
recurrence is evaluated as a masked quadratic form (MXU-friendly), across
chunks a ``lax.scan`` carries the (H, P, N) state.  Decode is the O(1)
recurrent update — this is why the SSM/hybrid archs own the ``long_500k``
cell.  A Pallas kernel for the intra-chunk quadratic lives in
``repro/kernels/ssd_scan.py``; this file is the pure-jnp reference path used
by default (and by the dry-run).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense, rms_norm

Array = jax.Array

DEFAULT_CHUNK = 128


def ssd_chunked(
    x: Array,        # (B, S, H, P)   inputs per head
    dt: Array,       # (B, S, H)      softplus'd step sizes
    a: Array,        # (H,)           negative decay rates  (A = -exp(A_log))
    b_mat: Array,    # (B, S, N)      input projections (G=1 group)
    c_mat: Array,    # (B, S, N)      output projections
    chunk: int = DEFAULT_CHUNK,
    h0: Optional[Array] = None,       # (B, H, P, N) initial state
):
    """Returns (y, h_final) with y: (B, S, H, P)."""
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    la = dt * a[None, None, :]                        # log-decay per step (B,S,H) ≤ 0
    xr = x.reshape(B, nc, chunk, H, P)
    dtr = dt.reshape(B, nc, chunk, H)
    lar = la.reshape(B, nc, chunk, H)
    br = b_mat.reshape(B, nc, chunk, N)
    cr = c_mat.reshape(B, nc, chunk, N)

    # move chunk axis to the front for scan
    xr, dtr, lar, br, cr = (t.transpose(1, 0, *range(2, t.ndim)) for t in (xr, dtr, lar, br, cr))

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]

    def body(h, inp):
        xc, dtc, lac, bc, cc = inp                    # (B, chunk, ...)
        cum = jnp.cumsum(lac, axis=1)                 # (B, chunk, H)
        # ---- intra-chunk (quadratic, MXU) ----
        scores = jnp.einsum("bin,bjn->bij", cc.astype(jnp.float32), bc.astype(jnp.float32))
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B, i, j, H)
        decay = jnp.where(causal[None, :, :, None], decay, 0.0)
        dx = (dtc.astype(jnp.float32)[..., None] * xc.astype(jnp.float32))  # (B,chunk,H,P)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", scores, decay, dx)
        # ---- inter-chunk: contribution of carried state ----
        state_decay = jnp.exp(cum)                    # (B, chunk, H)
        y_inter = jnp.einsum("bin,bhpn->bihp", cc.astype(jnp.float32), h) * state_decay[..., None]
        # ---- state update ----
        total = cum[:, -1, :]                         # (B, H)
        rem = jnp.exp(total[:, None, :] - cum)        # decay from step j to chunk end
        dh = jnp.einsum("bjn,bjhp,bjh->bhpn", bc.astype(jnp.float32), dx, rem)
        h_new = h * jnp.exp(total)[:, :, None, None] + dh
        return h_new, (y_intra + y_inter)

    h_final, ys = lax.scan(body, h0, (xr, dtr, lar, br, cr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y.astype(x.dtype), h_final


def ssd_decode_step(h: Array, x: Array, dt: Array, a: Array, b_mat: Array, c_mat: Array):
    """One-token recurrent update.  x: (B, H, P); dt: (B, H); b/c: (B, N)."""
    la = dt * a[None, :]                              # (B, H)
    decay = jnp.exp(la)[:, :, None, None]
    dx = (dt[..., None] * x).astype(jnp.float32)      # (B, H, P)
    h_new = h * decay + jnp.einsum("bn,bhp->bhpn", b_mat.astype(jnp.float32), dx)
    y = jnp.einsum("bn,bhpn->bhp", c_mat.astype(jnp.float32), h_new)
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Full Mamba2 block (projections + conv + SSD + gating)
# ---------------------------------------------------------------------------

def _split_proj(zxbcdt: Array, d_inner: int, n_state: int, n_heads: int):
    z, xc, b, c, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n_state, 2 * d_inner + 2 * n_state], axis=-1
    )
    return z, xc, b, c, dt  # dt: (..., H)


def causal_conv(x: Array, w: Array, state: Optional[Array] = None,
                valid_len=None):
    """Depthwise causal conv.  x: (B, S, C); w: (K, C).  If ``state`` (B, K-1, C)
    is given, runs in streaming mode and returns (y, new_state).  With
    ``valid_len`` (bucketed prefill: positions >= valid_len are padding) the
    returned state is the window ending at the LAST REAL position, not the
    padded tail."""
    k = w.shape[0]
    if state is not None:
        xa = jnp.concatenate([state, x], axis=1)
        if valid_len is None:
            new_state = xa[:, -(k - 1):, :]
        else:
            # row for position p sits at index p + (k-1); the state after
            # valid_len tokens is rows valid_len .. valid_len + k - 2
            new_state = lax.dynamic_slice_in_dim(
                xa, jnp.asarray(valid_len, jnp.int32), k - 1, axis=1)
    else:
        xa = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_state = xa[:, -(k - 1):, :]
    # (B, S, C) windows dot (K, C)
    y = sum(xa[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return y, new_state


def mamba_block(
    x: Array,                     # (B, S, D)
    p: dict,
    dims,
    lora: Optional[dict] = None,
    lora_scale: float = 2.0,
    cache: Optional[dict] = None,
    chunk: int = DEFAULT_CHUNK,
    adapter_ids: Optional[Array] = None,
    verify: bool = False,
    valid_len=None,
):
    """Returns (out, new_cache).  cache = {"conv": (B,K-1,Cc), "ssm": (B,H,P,N)}.

    ``verify=True`` (speculative decoding): S is the draft length; the
    recurrence is stepped token by token from the cached state, and
    ``new_cache`` holds PER-STEP snapshots ``{"conv": (B,S,K-1,Cc), "ssm":
    (B,S,H,P,N)}`` — snapshot j is the state after consuming token j.  The
    engine commits the snapshot at the accept boundary, which is how rejected
    speculative tokens are rolled out of a recurrence that has no positions
    to mask.
    """
    di, N, H, P = dims.d_inner, dims.ssm_state, dims.ssm_heads, dims.ssm_head_dim
    resid_dtype = x.dtype
    xn = rms_norm(x, p["ln"])

    def l(name):
        return None if lora is None or name not in lora else lora[name]

    proj = dense(xn, p["in_proj"], l("in_proj"), lora_scale,
                 adapter_ids=adapter_ids)                         # (B,S, 2di+2N+H)
    z, xc, b_mat, c_mat, dt = _split_proj(proj, di, N, H)

    conv_in = jnp.concatenate([xc, b_mat, c_mat], axis=-1)
    conv_state = None if cache is None else cache["conv"]
    conv_snaps = None
    if verify and cache is not None:
        # streaming conv + per-step (k-1)-window snapshots: after token j the
        # conv state is inputs j+1-(k-1) .. j of the padded stream
        kw = p["conv_w"].shape[0]
        xa = jnp.concatenate([conv_state, conv_in], axis=1)
        conv_out = sum(xa[:, i: i + conv_in.shape[1], :]
                       * p["conv_w"][i][None, None, :] for i in range(kw))
        new_conv = xa[:, -(kw - 1):, :]
        snap_idx = (jnp.arange(conv_in.shape[1])[:, None] + 1
                    + jnp.arange(kw - 1)[None, :])
        conv_snaps = xa[:, snap_idx]                      # (B, S, k-1, Cc)
    else:
        conv_out, new_conv = causal_conv(conv_in, p["conv_w"], conv_state,
                                         valid_len=valid_len)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(resid_dtype)
    xc, b_mat, c_mat = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if valid_len is not None:
        # bucketed prefill: dt = 0 at padded steps makes the recurrence an
        # exact identity there (decay exp(0·a) = 1, input term dt·x = 0), so
        # the final SSM state is precisely the state after the last REAL
        # token regardless of what garbage the padding projects to.
        real = jnp.arange(dt.shape[1])[None, :, None] < jnp.asarray(
            valid_len, jnp.int32)
        dt = jnp.where(real, dt, 0.0)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                  # (H,)

    B, S = x.shape[:2]
    xh = xc.reshape(B, S, H, P)

    if cache is not None and verify:
        # token-by-token recurrence (bitwise-identical to sequential decode
        # steps), collecting the state after every token
        def step(h, inp):
            x_t, dt_t, b_t, c_t = inp
            y_t, h_new = ssd_decode_step(h, x_t, dt_t, a, b_t, c_t)
            return h_new, (y_t, h_new)

        seq = (xh.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
               b_mat.transpose(1, 0, 2), c_mat.transpose(1, 0, 2))
        _, (ys, hs) = lax.scan(step, cache["ssm"], seq)
        y = ys.transpose(1, 0, 2, 3)                      # (B, S, H, P)
        new_ssm = jnp.moveaxis(hs, 0, 1)                  # (B, S, H, P, N)
        new_conv = conv_snaps
    elif cache is not None and S == 1:
        y1, new_ssm = ssd_decode_step(
            cache["ssm"], xh[:, 0], dt[:, 0], a, b_mat[:, 0], c_mat[:, 0]
        )
        y = y1[:, None]
    else:
        h0 = None if cache is None else cache["ssm"]
        ck = min(chunk, S)
        pad = (-S) % ck
        if pad:
            # zero-pad to a chunk multiple; dt=0 at padded steps → decay 1,
            # zero input → state passes through untouched (exactness preserved)
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
            y, h_final = ssd_chunked(xh_p, dt_p, a, b_p, c_p, chunk=ck, h0=h0)
            y = y[:, :S]
        else:
            y, h_final = ssd_chunked(xh, dt, a, b_mat, c_mat, chunk=ck, h0=h0)
        new_ssm = h_final

    y = y + xh * p["d_skip"].astype(jnp.float32)[None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)    # gate
    y = rms_norm(y, p["out_norm"])
    out = dense(y, p["out_proj"], l("out_proj"), lora_scale,
                adapter_ids=adapter_ids)
    new_cache = None if cache is None else {"conv": new_conv, "ssm": new_ssm}
    return x + out.astype(resid_dtype), new_cache

"""Unified model: every assigned architecture runs through this file.

A model is a :class:`Plan` — the config plus an expanded list of stages
(scan-over-superblock).  LoRAM structured pruning rewrites the Plan (smaller
``StageDims``, possibly split stages for keep-first/last), which is the
"train small" model; the original Plan is the "infer large" model.

Three entry points:
  * :func:`forward`      — full-sequence logits (training / eval / prefill)
  * :func:`prefill`      — forward + populated KV/SSM caches
  * :func:`decode_step`  — one-token generation against caches

Params, LoRA adapters, masks and caches are plain nested dicts; stacked
(leading ``n_rep`` axis) inside each stage so the whole depth runs under one
``lax.scan``.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import BlockSpec, LoRAConfig, ModelConfig, Stage, StageDims
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models.moe import moe_mlp
from repro.models.ssm import mamba_block
from repro.quant import kv as qkv, nf4

Array = jax.Array
PyTree = Any

LONG_SEQ_CHUNK = 512        # flash-style q-chunking threshold for jnp attention
LONG_SEQ_THRESHOLD = 8192   # chunk for 32k+ prefill; at 4k full scores beat re-reading KV per chunk


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Plan:
    cfg: ModelConfig
    stages: Tuple[Stage, ...]
    enc_stages: Tuple[Stage, ...] = ()

    @property
    def name(self):
        return self.cfg.name


def make_plan(cfg: ModelConfig) -> Plan:
    return Plan(cfg, cfg.stages(), cfg.encoder_stages())


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_dense(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def _block_param_shapes(spec: BlockSpec, d: StageDims) -> Dict[str, tuple]:
    dm, hd = d.d_model, d.head_dim
    if spec.kind in ("attn", "enc_attn", "cross_attn"):
        return {
            "ln": (dm,),
            "wq": (dm, d.n_heads * hd),
            "wk": (dm, d.n_kv_heads * hd),
            "wv": (dm, d.n_kv_heads * hd),
            "wo": (d.n_heads * hd, dm),
        }
    if spec.kind == "mlp":
        return {"ln": (dm,), "wg": (dm, d.d_ff), "wu": (dm, d.d_ff), "wd": (d.d_ff, dm)}
    if spec.kind == "moe":
        sh: Dict[str, tuple] = {
            "ln": (dm,),
            "router": (dm, d.n_experts),
            "we_g": (d.n_experts, dm, d.moe_d_ff),
            "we_u": (d.n_experts, dm, d.moe_d_ff),
            "we_d": (d.n_experts, d.moe_d_ff, dm),
        }
        if d.n_shared_experts:
            sh.update({"ws_g": (dm, d.shared_d_ff), "ws_u": (dm, d.shared_d_ff),
                       "ws_d": (d.shared_d_ff, dm)})
        if d.dense_residual_d_ff:
            sh.update({"wr_g": (dm, d.dense_residual_d_ff), "wr_u": (dm, d.dense_residual_d_ff),
                       "wr_d": (d.dense_residual_d_ff, dm)})
        return sh
    if spec.kind == "mamba":
        di, N, H = d.d_inner, d.ssm_state, d.ssm_heads
        return {
            "ln": (dm,),
            "in_proj": (dm, 2 * di + 2 * N + H),
            "conv_w": (d.conv_width, di + 2 * N),
            "dt_bias": (H,),
            "a_log": (H,),
            "d_skip": (H,),
            "out_norm": (di,),
            "out_proj": (di, dm),
        }
    raise ValueError(spec.kind)


def _init_block(key, spec: BlockSpec, d: StageDims, dtype):
    shapes = _block_param_shapes(spec, d)
    out = {}
    keys = jax.random.split(key, len(shapes))
    for (name, shp), k in zip(sorted(shapes.items()), keys):
        if name in ("ln", "out_norm"):
            out[name] = jnp.zeros(shp, dtype)
        elif name == "dt_bias":
            out[name] = jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, shp[0]))).astype(jnp.float32)
        elif name == "a_log":
            out[name] = jnp.log(jnp.linspace(1.0, 16.0, shp[0])).astype(jnp.float32)
        elif name == "d_skip":
            out[name] = jnp.ones(shp, jnp.float32)
        elif len(shp) == 1:
            out[name] = jnp.zeros(shp, dtype)
        elif len(shp) == 3:  # stacked experts / conv
            if name == "conv_w":
                out[name] = (jax.random.normal(k, shp, jnp.float32) * (shp[0] ** -0.5)).astype(dtype)
            else:
                out[name] = (jax.random.normal(k, shp, jnp.float32) * (shp[1] ** -0.5)).astype(dtype)
        else:
            out[name] = _init_dense(k, shp[0], shp[1], dtype)
    return out


def _init_stage(key, stage: Stage, dtype):
    """Non-shared blocks stacked over n_rep; shared blocks unstacked."""
    stacked, shared = {}, {}
    for i, spec in enumerate(stage.superblock):
        bk = jax.random.fold_in(key, i)
        if spec.shared:
            shared[spec.name] = _init_block(bk, spec, stage.dims, dtype)
        else:
            reps = [_init_block(jax.random.fold_in(bk, r), spec, stage.dims, dtype)
                    for r in range(stage.n_rep)]
            stacked[spec.name] = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    return {"stacked": stacked, "shared": shared}


def init_params(plan: Plan, rng: Array, dtype=jnp.bfloat16) -> PyTree:
    cfg = plan.cfg
    k_embed, k_head, k_st, k_enc = jax.random.split(rng, 4)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "final_ln": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _init_dense(k_head, cfg.d_model, cfg.vocab_size, dtype)
    params["stages"] = {st.name: _init_stage(jax.random.fold_in(k_st, i), st, dtype)
                        for i, st in enumerate(plan.stages)}
    if plan.enc_stages:
        params["enc_stages"] = {st.name: _init_stage(jax.random.fold_in(k_enc, i), st, dtype)
                                for i, st in enumerate(plan.enc_stages)}
        params["enc_final_ln"] = jnp.zeros((cfg.d_model,), dtype)
    return params


# ---------------------------------------------------------------------------
# LoRA init  (B zero-init, A gaussian — Hu et al. 2022)
# ---------------------------------------------------------------------------

LORA_TARGET_SHAPES = {
    # block-kind → param names eligible for adapters
    "attn": ("wq", "wk", "wv", "wo"),
    "enc_attn": ("wq", "wk", "wv", "wo"),
    "cross_attn": ("wq", "wk", "wv", "wo"),
    "mlp": ("wg", "wu", "wd"),
    "moe": ("ws_g", "ws_u", "ws_d", "wr_g", "wr_u", "wr_d"),
    "mamba": ("in_proj", "out_proj"),
}

# generic-target → per-kind param-name aliases (so LoRAConfig.targets stays
# family-agnostic: "wg" covers mlp.wg, moe.ws_g and moe.wr_g, etc.)
_ALIAS = {
    "wq": ("wq",), "wk": ("wk",), "wv": ("wv",), "wo": ("wo",),
    "wg": ("wg", "ws_g", "wr_g"), "wu": ("wu", "ws_u", "wr_u"),
    "wd": ("wd", "ws_d", "wr_d"),
    "in_proj": ("in_proj",), "out_proj": ("out_proj",),
}
# mamba projections always get adapters when family is ssm/hybrid
DEFAULT_SSM_EXTRA = ("in_proj", "out_proj")


def _lora_names_for(spec: BlockSpec, lora_cfg: LoRAConfig):
    allowed = set()
    targets = set(lora_cfg.targets) | set(DEFAULT_SSM_EXTRA)
    for t in targets:
        allowed.update(_ALIAS.get(t, (t,)))
    return tuple(n for n in LORA_TARGET_SHAPES[spec.kind] if n in allowed)


def _init_lora_block(key, spec: BlockSpec, d: StageDims, lora_cfg: LoRAConfig, dtype):
    shapes = _block_param_shapes(spec, d)
    out = {}
    for i, name in enumerate(_lora_names_for(spec, lora_cfg)):
        if name not in shapes:
            continue
        d_in, d_out = shapes[name]
        k = jax.random.fold_in(key, i)
        out[name] = {
            "a": (jax.random.normal(k, (lora_cfg.rank, d_in), jnp.float32) * (d_in ** -0.5)).astype(dtype),
            "b": jnp.zeros((d_out, lora_cfg.rank), dtype),
        }
    return out


def init_lora(plan: Plan, lora_cfg: LoRAConfig, rng: Array) -> PyTree:
    dtype = jnp.dtype(lora_cfg.dtype)
    cfg = plan.cfg

    def stage_lora(key, stage: Stage):
        stacked, shared = {}, {}
        for i, spec in enumerate(stage.superblock):
            bk = jax.random.fold_in(key, i)
            blk = _init_lora_block(bk, spec, stage.dims, lora_cfg, dtype)
            if not blk:
                continue
            if spec.shared:
                shared[spec.name] = blk
            else:
                reps = [
                    _init_lora_block(jax.random.fold_in(bk, r + 1), spec, stage.dims, lora_cfg, dtype)
                    for r in range(stage.n_rep)
                ]
                stacked[spec.name] = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
        return {"stacked": stacked, "shared": shared}

    out: Dict[str, Any] = {
        "stages": {st.name: stage_lora(jax.random.fold_in(rng, i), st)
                   for i, st in enumerate(plan.stages)}
    }
    if plan.enc_stages:
        out["enc_stages"] = {st.name: stage_lora(jax.random.fold_in(rng, 100 + i), st)
                             for i, st in enumerate(plan.enc_stages)}
    if "lm_head" in lora_cfg.targets and not cfg.tie_embeddings:
        k = jax.random.fold_in(rng, 999)
        out["lm_head"] = {
            "a": (jax.random.normal(k, (lora_cfg.rank, cfg.d_model), jnp.float32)
                  * (cfg.d_model ** -0.5)).astype(dtype),
            "b": jnp.zeros((cfg.vocab_size, lora_cfg.rank), dtype),
        }
    return out


def lora_param_count(lora: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(lora))


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _sub(d: Optional[dict], name: str) -> Optional[dict]:
    if d is None:
        return None
    return d.get(name)


def ring_pages(window: int, n_tbl: int, page_size: int) -> int:
    """Block-table entries a windowed attention layer's ring maps onto: a
    sliding window of ``window`` tokens needs only ``ceil(window/page)``
    pages — the ring reuses the slot's LOW table entries forever, so a
    windowed layer's footprint stays bounded no matter how long the
    sequence grows.  Full attention (window=0) uses the whole table."""
    if not window:
        return n_tbl
    return min(-(-window // page_size), n_tbl)


def paged_pos_to_page(block_table, pos, window: int, page_size: int):
    """THE per-slot position → (pool page, in-page offset) map: ring index
    ``pos % (ring_pages·page)`` looked up through the block table.  Every
    paged single-position read/write site (decode scatter, draft-loop
    rollback rows) derives from this one function so the ring semantics
    can never drift apart; the multi-position commit helpers in
    repro.serving.speculative and the validity masks in repro.kernels
    mirror the same ``ring_pages`` sizing."""
    n_tbl = block_table.shape[1]
    ring_len = ring_pages(window, n_tbl, page_size) * page_size
    ridx = pos % ring_len
    bidx = jnp.arange(pos.shape[0])
    return block_table[bidx, ridx // page_size], ridx % page_size


def _attn_block(
    x, bp, blora, d: StageDims, *,
    kind: str, window: int, positions, theta: float, scale_l: float,
    enc_out=None, cache=None, pos=None, masks=None, adapter_ids=None,
    verify: bool = False, chunk: bool = False, block_table=None,
    valid_len=None,
):
    B = x.shape[0]
    hd, H, K = d.head_dim, d.n_heads, d.n_kv_heads
    xn = L.rms_norm(x, bp["ln"])
    kv_src = enc_out if kind == "cross_attn" else xn

    def pr(n):
        return L.dense(xn if n == "wq" else kv_src, bp[n], _sub(blora, n), scale_l,
                       None if masks is None else masks.get(n),
                       adapter_ids=adapter_ids)

    q = pr("wq").reshape(B, -1, H, hd)
    if kind == "cross_attn" and cache is not None and "k" in cache:
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        k = pr("wk").reshape(B, -1, K, hd)
        v = pr("wv").reshape(B, -1, K, hd)
        new_cache = None

    if kind != "cross_attn":
        q = L.apply_rope(q, positions, theta)
        k = L.apply_rope(k, positions, theta)

    if cache is not None and kind != "cross_attn":
        # decode, speculative verify, or prefill-write — tensor-parallel
        # serving shards the head axis here (context-gated: a no-op outside
        # a head_shard mesh scope), so every branch below computes its
        # (slot, head) attention wholly on one shard
        q = _shard_heads(q)
        paged = block_table is not None
        if paged:
            # cache holds a page POOL (n_pages, page, kv, hd); the slot's
            # block table maps logical pages to pool pages.  The virtual
            # dense view below has ring length R·page (== max_seq_len for
            # full attention, a bounded ring for windowed layers).
            page = cache["k"].shape[1]
            n_tbl = block_table.shape[1]
            tbl = block_table[:, :ring_pages(window, n_tbl, page)]
            cache_size = tbl.shape[1] * page
        else:
            cache_size = cache["k"].shape[1]
        if verify:
            # Speculative verify: T draft tokens per slot, each slot at its own
            # depth.  The persistent cache is NOT written — the engine commits
            # only the accepted prefix (see serving.speculative.commit_cache) —
            # so each query attends (a) the pre-round cache, masked to
            # positions it may see, and (b) the in-block keys causally.  This
            # keeps windowed ring caches exact under rollback: rejected tokens
            # never touch the ring, so no slot ever aliases a stale write.
            T = q.shape[1]
            pos_v = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
            qpos = pos_v[:, None] + jnp.arange(T)[None, :]          # (B, T)
            karange = jnp.arange(cache_size)
            # absolute position held by each ring slot before this round
            last = pos_v[:, None] - 1
            slot_pos = last - ((last - karange[None, :]) % cache_size)
            valid_old = jnp.broadcast_to(
                (slot_pos >= 0)[:, None, :], (B, T, cache_size))
            if window:
                valid_old = valid_old & (
                    slot_pos[:, None, :] > qpos[:, :, None] - window)
            tidx = jnp.arange(T)
            blk = tidx[None, :] <= tidx[:, None]                    # (Tq, Tk)
            if window:
                blk = blk & (tidx[None, :] > tidx[:, None] - window)
            gs = H // K
            scale = 1.0 / (hd ** 0.5)
            qg = q.reshape(B, T, K, gs, hd).transpose(0, 2, 3, 1, 4)
            if paged:
                # gather the slot's pages into the virtual dense ring; the
                # verify pass is read-only, so no scatter-back is needed —
                # the engine commits pending rows into pages itself.  int8
                # pools dequantize here against their per-row scales (the
                # shared reconstruction every reader uses).
                ck = cache["k"][tbl].reshape(B, cache_size, K, hd)
                cv = cache["v"][tbl].reshape(B, cache_size, K, hd)
                if qkv.quant_cache_keys(cache):
                    ck = qkv.dequantize_rows(
                        ck, cache["k_sc"][tbl].reshape(B, cache_size, K, 1))
                    cv = qkv.dequantize_rows(
                        cv, cache["v_sc"][tbl].reshape(B, cache_size, K, 1))
            else:
                ck, cv = cache["k"], cache["v"]
            # pending rows stay fp — the engine's commit scatter quantizes
            # the accepted prefix itself (quantize-on-commit)
            pend_dt = (jnp.float32 if qkv.quant_cache_keys(cache)
                       else cache["k"].dtype)
            kw = k.astype(pend_dt)
            vw = v.astype(pend_dt)
            lo = jnp.einsum("bkgtd,bskd->bkgts", qg,
                            ck.astype(qg.dtype)).astype(jnp.float32) * scale
            lb = jnp.einsum("bkgtd,bjkd->bkgtj", qg,
                            k).astype(jnp.float32) * scale
            lo = jnp.where(valid_old[:, None, None], lo, L.NEG_INF)
            lb = jnp.where(blk[None, None, None], lb, L.NEG_INF)
            probs = jax.nn.softmax(
                jnp.concatenate([lo, lb], axis=-1), axis=-1)
            po = probs[..., :cache_size].astype(cv.dtype)
            pb = probs[..., cache_size:].astype(v.dtype)
            out = (jnp.einsum("bkgts,bskd->bkgtd", po, cv)
                   + jnp.einsum("bkgtj,bjkd->bkgtd", pb, v))
            out = _shard_heads(out.transpose(0, 3, 1, 2, 4)
                               .reshape(B, T, H, hd))
            # pending writes: the engine scatters rows j < n_keep per slot
            new_cache = {"k": kw, "v": vw}
        elif chunk:
            # chunked prefill: C queries at positions pos..pos+C-1 attend the
            # slot's already-committed pages through the block table plus the
            # chunk's own keys causally (kernels.paged_chunk_attention — the
            # Pallas page sweep on TPU, the jnp oracle elsewhere).  The
            # persistent pool is NOT written here: the chunk's K/V comes back
            # as pending rows and repro.runtime.steps.make_paged_prefill_chunk
            # scatters the valid ones into the slot's pages (per-layer ring
            # mapping, last-writer-wins inside a wrapped windowed ring).
            assert paged, "chunked prefill requires a paged cache"
            pos_v = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
            if qkv.quant_cache_keys(cache):
                # int8 pool: the committed pages dequantize in-kernel; the
                # chunk's own K/V stays fp here and in the pending rows —
                # steps.make_paged_prefill_chunk quantizes at the scatter
                kc, vc = k.astype(jnp.float32), v.astype(jnp.float32)
                out = _shard_heads(kops.paged_chunk_attention(
                    q, kc, vc, cache["k"], cache["v"], tbl, pos_v,
                    k_scale=cache["k_sc"], v_scale=cache["v_sc"],
                    window=window))
            else:
                kc = k.astype(cache["k"].dtype)
                vc = v.astype(cache["v"].dtype)
                out = _shard_heads(kops.paged_chunk_attention(
                    q, kc, vc, cache["k"], cache["v"], tbl, pos_v,
                    window=window))
            new_cache = {"k": kc, "v": vc}
        elif q.shape[1] == 1 and paged:  # decode step, paged pool
            # scatter the new token's K/V into the slot's current page, then
            # attend through the block table (gather-then-flash — the Pallas
            # kernel on TPU, the jnp oracle everywhere else).  Free slots'
            # table rows are all-zero, so their garbage writes land on the
            # reserved trash page and can never corrupt a live slot.
            pos_v = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
            pg, off = paged_pos_to_page(block_table, pos_v, window, page)
            if qkv.quant_cache_keys(cache):
                # quantize-on-write: the new token's row is coded through the
                # one shared quantizer, scattered beside its per-row scale,
                # and the kernel dequantizes in-flight — the token attends
                # its own QUANTIZED key, same as every later reader sees it
                kq, ksc = qkv.quantize_rows(k[:, 0])
                vq, vsc = qkv.quantize_rows(v[:, 0])
                ck = cache["k"].at[pg, off].set(kq)
                cv = cache["v"].at[pg, off].set(vq)
                cks = cache["k_sc"].at[pg, off].set(
                    ksc.astype(cache["k_sc"].dtype))
                cvs = cache["v_sc"].at[pg, off].set(
                    vsc.astype(cache["v_sc"].dtype))
                new_cache = {"k": ck, "v": cv, "k_sc": cks, "v_sc": cvs}
                out = kops.paged_decode_attention(
                    q[:, 0], ck, cv, tbl, pos_v,
                    k_scale=cks, v_scale=cvs, window=window)
            else:
                ck = cache["k"].at[pg, off].set(
                    k[:, 0].astype(cache["k"].dtype))
                cv = cache["v"].at[pg, off].set(
                    v[:, 0].astype(cache["v"].dtype))
                new_cache = {"k": ck, "v": cv}
                out = kops.paged_decode_attention(q[:, 0], ck, cv, tbl, pos_v,
                                                  window=window)
            out = _shard_heads(out[:, None])
        elif q.shape[1] == 1:  # decode step
            # pos may be a scalar (whole batch at one position — legacy
            # engine) or per-slot (B,) (continuous batching: every slot sits
            # at its own depth in its own sequence).
            pos_v = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
            slot = pos_v % cache_size
            bidx = jnp.arange(B)
            ck = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            karange = jnp.arange(cache_size)
            if window:
                kpos = pos_v[:, None] - ((pos_v[:, None] - karange[None, :]) % cache_size)
                valid = kpos >= 0
            else:
                valid = karange[None, :] <= pos_v[:, None]
            # GQA-grouped decode attention: contract against the K-head cache
            # directly — repeat_kv would read H/K× (7× for yi-34b) more cache
            # bytes per token (§Perf iteration 9)
            B_, gs = q.shape[0], H // K
            scale = 1.0 / (hd ** 0.5)
            qg = q.reshape(B_, K, gs, hd)                 # (B, K, G, d)
            logits = jnp.einsum("bkgd,bskd->bkgs", qg, ck).astype(jnp.float32) * scale
            logits = jnp.where(valid[:, None, None, :], logits, L.NEG_INF)
            probs = jax.nn.softmax(logits, axis=-1).astype(cv.dtype)
            out = jnp.einsum("bkgs,bskd->bkgd", probs, cv)
            out = _shard_heads(out.reshape(B_, 1, H, hd))
        else:  # prefill: full attention then write cache (q sharded above)
            out, new_cache = _prefill_attn_and_cache(q, k, v, cache,
                                                     window, H // K,
                                                     valid_len=valid_len)
    else:
        kk = _shard_heads(L.repeat_kv(k, H // K))
        vv = _shard_heads(L.repeat_kv(v, H // K))
        q = _shard_heads(q)
        causal = kind == "attn"
        S = q.shape[1]
        # adaptive q-chunk: bound live scores to ~2^21 elems per (batch, head)
        chunk_q = max(64, (1 << 21) // S) if S >= LONG_SEQ_THRESHOLD else (
            min(window, 512) if (causal and window and S >= 2 * window) else 0)
        out = _shard_heads(
            L.attention(q, kk, vv, causal=causal, window=window if causal else 0,
                        chunk_q=chunk_q))
        if kind == "cross_attn" and cache is not None:
            new_cache = {"k": k, "v": v}

    out = out.reshape(B, -1, H * hd)
    out = L.dense(out, bp["wo"], _sub(blora, "wo"), scale_l,
                  None if masks is None else masks.get("wo"),
                  adapter_ids=adapter_ids)
    res = x + out.astype(x.dtype)
    return (res, new_cache) if cache is not None else (res, None)


def _prefill_attn_and_cache(q, k, v, cache, window, n_rep, valid_len=None):
    """``valid_len`` (traced scalar) supports bucketed prefill: the prompt is
    right-padded to a bucket length and only positions < valid_len are
    written — padded garbage K/V must never land in the cache, because ring
    readers infer a slot's absolute position from the write order."""
    S = q.shape[1]
    cache_size = cache["k"].shape[1]
    kk = L.repeat_kv(k, n_rep)
    vv = L.repeat_kv(v, n_rep)
    chunk_q = max(64, (1 << 21) // S) if S >= LONG_SEQ_THRESHOLD else 0
    out = L.attention(q, kk, vv, causal=True, window=window, chunk_q=chunk_q)
    kw = k.astype(cache["k"].dtype)
    vw = v.astype(cache["v"].dtype)
    if valid_len is not None:
        valid_len = jnp.asarray(valid_len, jnp.int32)
        if S >= cache_size:
            # the ring must hold the last cache_size REAL positions, i.e.
            # valid_len-cache_size .. valid_len-1 — slice that window out of
            # the (padded) sequence instead of taking the padded tail
            start = jnp.clip(valid_len - cache_size, 0, S - cache_size)
            tail_k = lax.dynamic_slice_in_dim(kw, start, cache_size, axis=1)
            tail_v = lax.dynamic_slice_in_dim(vw, start, cache_size, axis=1)
            p = start + jnp.arange(cache_size)
            keep = (p < valid_len)[None, :, None, None]
            slots = p % cache_size
            ck = cache["k"].at[:, slots].set(
                jnp.where(keep, tail_k, cache["k"][:, slots]))
            cv = cache["v"].at[:, slots].set(
                jnp.where(keep, tail_v, cache["v"][:, slots]))
        else:
            keep = (jnp.arange(S) < valid_len)[None, :, None, None]
            old = lax.dynamic_slice(cache["k"], (0, 0, 0, 0), kw.shape)
            oldv = lax.dynamic_slice(cache["v"], (0, 0, 0, 0), vw.shape)
            ck = lax.dynamic_update_slice(cache["k"], jnp.where(keep, kw, old),
                                          (0, 0, 0, 0))
            cv = lax.dynamic_update_slice(cache["v"], jnp.where(keep, vw, oldv),
                                          (0, 0, 0, 0))
        return out, {"k": ck, "v": cv}
    if S >= cache_size:
        tail_k, tail_v = kw[:, -cache_size:], vw[:, -cache_size:]
        pos0 = S - cache_size
        slots = (pos0 + jnp.arange(cache_size)) % cache_size
        ck = cache["k"].at[:, slots].set(tail_k)
        cv = cache["v"].at[:, slots].set(tail_v)
    else:
        ck = lax.dynamic_update_slice(cache["k"], kw, (0, 0, 0, 0))
        cv = lax.dynamic_update_slice(cache["v"], vw, (0, 0, 0, 0))
    return out, {"k": ck, "v": cv}


def _apply_block(spec: BlockSpec, bp, blora, x, aux, d: StageDims, cfg: ModelConfig,
                 *, positions, enc_out, cache, pos, scale_l, capacity_factor, masks=None,
                 adapter_ids=None, verify: bool = False, chunk: bool = False,
                 block_table=None, valid_len=None):
    new_cache = None
    if spec.kind in ("attn", "enc_attn", "cross_attn"):
        x, new_cache = _attn_block(
            x, bp, blora, d, kind=spec.kind, window=spec.window, positions=positions,
            theta=cfg.rope_theta, scale_l=scale_l, enc_out=enc_out, cache=cache, pos=pos,
            masks=masks, adapter_ids=adapter_ids, verify=verify, chunk=chunk,
            block_table=block_table, valid_len=valid_len)
    elif spec.kind == "mlp":
        xn = L.rms_norm(x, bp["ln"])
        x = x + L.swiglu(xn, bp, blora, scale_l, masks,
                         adapter_ids=adapter_ids).astype(x.dtype)
    elif spec.kind == "moe":
        xn = L.rms_norm(x, bp["ln"])
        # verify batches B·T tokens: capacity must stay lossless so garbage
        # from free slots can never displace a live request's token.
        # Bucketed prefill (valid_len set) needs no such protection: the
        # expert-buffer position cumsum runs in token order and padding sits
        # AFTER every real token, so garbage can only ever take capacity
        # slots behind the real ones — statistical capacity (now computed on
        # the slightly longer bucket) stays safe.  Chunked prefill routes
        # lossless too: per-chunk statistical capacity would make routing
        # depend on where the chunk boundaries fell — lossless keeps chunked
        # output equal to monolithic whenever monolithic dropped nothing
        # (the same documented exception as bucketing's slightly-larger
        # capacity).
        out, a = moe_mlp(xn, bp, top_k=d.top_k, capacity_factor=capacity_factor,
                         lora=blora, lora_scale=scale_l, adapter_ids=adapter_ids,
                         lossless=verify or chunk)
        x = x + out.astype(x.dtype)
        aux = aux + a
    elif spec.kind == "mamba":
        x, new_cache = mamba_block(x, bp, d, blora, scale_l, cache,
                                   adapter_ids=adapter_ids, verify=verify,
                                   valid_len=valid_len)
    else:
        raise ValueError(spec.kind)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Stage runner (scan over superblock repetitions)
# ---------------------------------------------------------------------------

def run_stage(
    stage: Stage, sp: dict, slora: Optional[dict], x: Array, aux: Array, cfg: ModelConfig,
    *, positions, enc_out=None, cache: Optional[dict] = None, pos=None,
    scale_l: float = 2.0, remat: bool = False, masks: Optional[dict] = None,
    adapter_ids=None, verify: bool = False, chunk: bool = False,
    block_table=None, valid_len=None,
):
    """sp = {"stacked": {...}, "shared": {...}} with leading n_rep on stacked."""
    stacked_p = sp["stacked"]
    shared_p = sp["shared"]
    stacked_l = (slora or {}).get("stacked", {})
    shared_l = (slora or {}).get("shared", {})
    stacked_m = (masks or {}).get("stacked", {}) if masks else {}

    has_cache = cache is not None
    cache_stacked = cache or {}

    def body(carry, xs):
        xx, aa = carry
        bp_all, bl_all, bc_all, bm_all = xs
        new_caches = {}
        for spec in stage.superblock:
            bp = shared_p[spec.name] if spec.shared else bp_all[spec.name]
            bl = shared_l.get(spec.name) if spec.shared else bl_all.get(spec.name)
            bm = bm_all.get(spec.name) if bm_all else None
            bc = bc_all.get(spec.name) if has_cache else None

            def apply(bp_, bl_, xx_, aa_, bc_, bm_, _spec=spec):
                return _apply_block(
                    _spec, bp_, bl_, xx_, aa_, stage.dims, cfg,
                    positions=positions, enc_out=enc_out, cache=bc_, pos=pos,
                    scale_l=scale_l, capacity_factor=cfg.capacity_factor,
                    masks=bm_, adapter_ids=adapter_ids, verify=verify,
                    chunk=chunk, block_table=block_table, valid_len=valid_len)

            # adaptive remat granularity (§Perf iters 11/13): deep superblocks
            # (gemma3's 12 blocks) checkpoint per block so the backward
            # transient holds ONE block's scores; shallow superblocks keep
            # whole-body remat (less recompute traffic — measured better on
            # llama2-70b).
            if remat and per_block:
                apply = jax.checkpoint(apply)
            xx, aa, nc = apply(bp, bl, xx, aa, bc, bm)
            if has_cache and nc is not None:
                new_caches[spec.name] = nc
        xx = _shard_residual(xx)
        return (xx, aa), new_caches

    per_block = len(stage.superblock) > 4
    body_fn = jax.checkpoint(body) if (remat and not per_block) else body
    xs = (stacked_p, stacked_l, cache_stacked, stacked_m)
    (x, aux), new_cache = lax.scan(body_fn, (x, aux), xs, length=stage.n_rep)
    return x, aux, (new_cache if has_cache else None)


# ---------------------------------------------------------------------------
# Full model entry points
# ---------------------------------------------------------------------------

def _embed_tokens(cfg, params, tokens, lora=None):
    e = params["embed"]
    return jnp.take(e, tokens, axis=0)


def _lm_logits(cfg, params, x, lora, scale_l, adapter_ids=None):
    if cfg.tie_embeddings or "lm_head" not in params:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                            preferred_element_type=jnp.float32)
    else:
        head_lora = None if lora is None else lora.get("lm_head")
        logits = L.dense(x, params["lm_head"], head_lora, scale_l,
                         accum_fp32=True, adapter_ids=adapter_ids)
    # vocab-sharded logits: CE runs on shards (psum'd logsumexp) instead of
    # materializing (B, S, V) fp32 per device — 4.3 GB/layer-less saving on
    # gemma3's 262k vocab (was the 25 GiB/device train_4k overflow).
    return _shard_logits(logits)


def _run_encoder(plan, params, lora, frontend, scale_l, remat):
    if not plan.enc_stages:
        return None
    h = frontend
    aux = jnp.zeros((), jnp.float32)
    for st in plan.enc_stages:
        h, aux, _ = run_stage(
            st, params["enc_stages"][st.name],
            None if lora is None else lora.get("enc_stages", {}).get(st.name),
            h, aux, plan.cfg, positions=jnp.broadcast_to(
                jnp.arange(h.shape[1])[None], h.shape[:2]),
            scale_l=scale_l, remat=remat)
    return L.rms_norm(h, params["enc_final_ln"])


def forward(
    plan: Plan, params: PyTree, tokens: Array, lora: Optional[PyTree] = None,
    *, frontend: Optional[Array] = None, positions: Optional[Array] = None,
    lora_scale: float = 2.0, remat: bool = False, masks: Optional[PyTree] = None,
):
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    cfg = plan.cfg
    enc_out = _run_encoder(plan, params, lora, frontend, lora_scale, remat)

    x = _embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm" and frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

    aux = jnp.zeros((), jnp.float32)
    for st in plan.stages:
        x, aux, _ = run_stage(
            st, params["stages"][st.name],
            None if lora is None else lora.get("stages", {}).get(st.name),
            x, aux, cfg, positions=positions, enc_out=enc_out,
            scale_l=lora_scale, remat=remat,
            masks=None if masks is None else masks.get("stages", {}).get(st.name))
        x = _shard_residual(x)

    x = L.grad_cast(x, x.dtype)   # keep the backbone backward in bf16
    x = L.rms_norm(x, params["final_ln"])
    if cfg.family == "vlm" and frontend is not None:
        x = x[:, frontend.shape[1]:]
    logits = _lm_logits(cfg, params, x, lora, lora_scale)
    return logits, aux


# activation sharding constraint hooks (set by repro.distributed.sharding)
_RESIDUAL_CONSTRAINT = None
_HEAD_CONSTRAINT = None
_LOGITS_CONSTRAINT = None


def set_residual_constraint(fn):
    global _RESIDUAL_CONSTRAINT
    _RESIDUAL_CONSTRAINT = fn


def set_head_constraint(fn):
    global _HEAD_CONSTRAINT
    _HEAD_CONSTRAINT = fn


def set_logits_constraint(fn):
    global _LOGITS_CONSTRAINT
    _LOGITS_CONSTRAINT = fn


def _shard_logits(x):
    if _LOGITS_CONSTRAINT is not None:
        return _LOGITS_CONSTRAINT(x)
    return x


def _shard_residual(x):
    if _RESIDUAL_CONSTRAINT is not None:
        return _RESIDUAL_CONSTRAINT(x)
    return x


def _shard_heads(x):
    if _HEAD_CONSTRAINT is not None:
        return _HEAD_CONSTRAINT(x)
    return x


# ---------------------------------------------------------------------------
# Caches / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(plan: Plan, batch: int, max_len: int, dtype=jnp.bfloat16) -> PyTree:
    cfg = plan.cfg
    caches = {}
    for st in plan.stages:
        d = st.dims
        stage_cache = {}
        for spec in st.superblock:
            if spec.kind == "attn":
                size = min(spec.window, max_len) if spec.window else max_len
                stage_cache[spec.name] = {
                    "k": jnp.zeros((st.n_rep, batch, size, d.n_kv_heads, d.head_dim), dtype),
                    "v": jnp.zeros((st.n_rep, batch, size, d.n_kv_heads, d.head_dim), dtype),
                }
            elif spec.kind == "cross_attn":
                stage_cache[spec.name] = {
                    "k": jnp.zeros((st.n_rep, batch, cfg.enc_len, d.n_kv_heads, d.head_dim), dtype),
                    "v": jnp.zeros((st.n_rep, batch, cfg.enc_len, d.n_kv_heads, d.head_dim), dtype),
                }
            elif spec.kind == "mamba":
                stage_cache[spec.name] = {
                    "conv": jnp.zeros((st.n_rep, batch, d.conv_width - 1, d.d_inner + 2 * d.ssm_state), dtype),
                    "ssm": jnp.zeros((st.n_rep, batch, d.ssm_heads, d.ssm_head_dim, d.ssm_state), jnp.float32),
                }
        caches[st.name] = stage_cache
    return caches


def init_paged_cache(plan: Plan, batch: int, n_pages: int, page_size: int,
                     dtype=jnp.bfloat16, quant_kv: bool = False) -> PyTree:
    """Paged variant of :func:`init_cache`: attention K/V live in a global
    pool of fixed-size pages (``n_pages`` × ``page_size`` tokens per layer,
    page 0 reserved as the trash page free slots write into), indexed through
    a per-slot block table held by the serving engine.  Recurrent state (SSM
    conv/ssm) is O(1) per slot and stays dense — paging it would buy nothing.
    Cross-attention caches stay dense too (encoder length is fixed).

    ``quant_kv=True`` (ServeConfig.quant.kv == "int8") stores the attention
    pools as int8 codes plus per-row absmax scale pools ``"k_sc"``/``"v_sc"``
    of shape (n_rep, n_pages, page, K, 1) — every scatter site writes codes
    and scales together (see repro.quant.kv)."""
    cfg = plan.cfg
    caches = {}
    for st in plan.stages:
        d = st.dims
        stage_cache = {}
        for spec in st.superblock:
            if spec.kind == "attn":
                pool = (st.n_rep, n_pages, page_size,
                        d.n_kv_heads, d.head_dim)
                pool_dt = jnp.int8 if quant_kv else dtype
                stage_cache[spec.name] = {
                    "k": jnp.zeros(pool, pool_dt),
                    "v": jnp.zeros(pool, pool_dt),
                }
                if quant_kv:
                    sc = pool[:3] + (d.n_kv_heads, 1)
                    stage_cache[spec.name]["k_sc"] = jnp.zeros(
                        sc, qkv.KV_SCALE_DTYPE)
                    stage_cache[spec.name]["v_sc"] = jnp.zeros(
                        sc, qkv.KV_SCALE_DTYPE)
            elif spec.kind == "cross_attn":
                stage_cache[spec.name] = {
                    "k": jnp.zeros((st.n_rep, batch, cfg.enc_len, d.n_kv_heads, d.head_dim), dtype),
                    "v": jnp.zeros((st.n_rep, batch, cfg.enc_len, d.n_kv_heads, d.head_dim), dtype),
                }
            elif spec.kind == "mamba":
                stage_cache[spec.name] = {
                    "conv": jnp.zeros((st.n_rep, batch, d.conv_width - 1, d.d_inner + 2 * d.ssm_state), dtype),
                    "ssm": jnp.zeros((st.n_rep, batch, d.ssm_heads, d.ssm_head_dim, d.ssm_state), jnp.float32),
                }
        caches[st.name] = stage_cache
    return caches


def _dec_cross_kv(plan, params, lora, enc_out, scale_l):
    """Precompute cross-attention K/V caches from encoder output."""
    caches = {}
    for st in plan.stages:
        d = st.dims
        st_c = {}
        for spec in st.superblock:
            if spec.kind != "cross_attn":
                continue
            bp = params["stages"][st.name]["stacked"][spec.name]
            bl = None if lora is None else lora.get("stages", {}).get(st.name, {}).get("stacked", {}).get(spec.name)

            def one(bp_r, bl_r):
                k = L.dense(enc_out, bp_r["wk"], _sub(bl_r, "wk"), scale_l)
                v = L.dense(enc_out, bp_r["wv"], _sub(bl_r, "wv"), scale_l)
                B = enc_out.shape[0]
                return {"k": k.reshape(B, -1, d.n_kv_heads, d.head_dim),
                        "v": v.reshape(B, -1, d.n_kv_heads, d.head_dim)}

            if bl is None:
                st_c[spec.name] = jax.vmap(lambda p: one(p, None))(bp)
            else:
                st_c[spec.name] = jax.vmap(one)(bp, bl)
        if st_c:
            caches[st.name] = st_c
    return caches


def prefill(
    plan: Plan, params: PyTree, tokens: Array, cache: PyTree,
    lora: Optional[PyTree] = None, *, frontend: Optional[Array] = None,
    lora_scale: float = 2.0, valid_len=None,
):
    """Run the prompt through the model, filling caches.  Returns
    (last_token_logits, cache, next_pos).

    ``valid_len`` (traced scalar) enables bucketed prefill: ``tokens`` is the
    prompt right-padded to a bucket length, only the first ``valid_len``
    positions are real.  Cache writes beyond ``valid_len`` are masked,
    recurrent (SSM/conv) state freezes at the boundary, and the returned
    logits are the ones at position ``valid_len - 1``.  Causal attention
    makes every real position's activations independent of the padding, so
    the result is exactly the unpadded prefill's — with one documented
    exception: MoE expert capacity is computed on the bucket length (padding
    cannot displace real tokens, it sorts after them in the buffer cumsum,
    but the slightly larger capacity may RETAIN a marginal token that
    exact-length routing would have dropped).  (Text-only: the serving
    engines that bucket never pass a vlm frontend.)"""
    cfg = plan.cfg
    enc_out = _run_encoder(plan, params, lora, frontend, lora_scale, remat=False)

    x = _embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm" and frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], x.shape[:2])

    if enc_out is not None:
        cross = _dec_cross_kv(plan, params, lora, enc_out, lora_scale)
        for stn, stc in cross.items():
            for bn, bc in stc.items():
                cache[stn][bn] = bc

    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    for st in plan.stages:
        x, aux, st_cache = run_stage(
            st, params["stages"][st.name],
            None if lora is None else lora.get("stages", {}).get(st.name),
            x, aux, cfg, positions=positions, enc_out=enc_out,
            cache=cache[st.name], pos=S - 1, scale_l=lora_scale,
            valid_len=valid_len)
        new_cache[st.name] = st_cache
    if valid_len is None:
        x = x[:, -1:]
    else:
        x = lax.dynamic_slice_in_dim(x, jnp.asarray(valid_len, jnp.int32) - 1,
                                     1, axis=1)
    x = L.rms_norm(x, params["final_ln"])
    logits = _lm_logits(cfg, params, x, lora, lora_scale)
    return logits[:, 0], new_cache, (S if valid_len is None else valid_len)


def decode_step(
    plan: Plan, params: PyTree, token: Array, cache: PyTree, pos,
    lora: Optional[PyTree] = None, *, lora_scale: float = 2.0,
    adapter_ids: Optional[Array] = None, block_table: Optional[Array] = None,
):
    """One decode step.  token: (B,) int32; pos: scalar int32 (next position,
    whole batch in lockstep) or (B,) int32 (per-slot positions — continuous
    batching).  ``adapter_ids`` (B,) routes each slot through its own adapter
    when ``lora`` is a stacked bank.  ``block_table`` (B, n_tbl) int32 marks
    the cache as PAGED (see :func:`init_paged_cache`): attention K/V reads
    and the new token's write go through page indirection.  Returns
    (logits (B, V), new_cache)."""
    cfg = plan.cfg
    x = _embed_tokens(cfg, params, token[:, None])
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (x.shape[0],))
    positions = pos[:, None]

    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    for st in plan.stages:
        x, aux, st_cache = run_stage(
            st, params["stages"][st.name],
            None if lora is None else lora.get("stages", {}).get(st.name),
            x, aux, cfg, positions=positions, enc_out=None,
            cache=cache[st.name], pos=pos, scale_l=lora_scale,
            adapter_ids=adapter_ids, block_table=block_table)
        new_cache[st.name] = st_cache
    x = L.rms_norm(x, params["final_ln"])
    logits = _lm_logits(cfg, params, x, lora, lora_scale, adapter_ids)
    return logits[:, 0], new_cache


def prefill_chunk(
    plan: Plan, params: PyTree, tokens: Array, cache: PyTree, pos,
    block_table: Array, lora: Optional[PyTree] = None, *,
    lora_scale: float = 2.0, valid_len=None,
):
    """One chunk of a chunked prefill: score ``tokens`` (B, C) at absolute
    positions ``pos .. pos+C-1`` against a PAGED cache whose pages already
    hold the slot's positions ``< pos``.

    Attention reads the committed pages through ``block_table`` plus the
    chunk's own keys causally (:func:`repro.kernels.ops.paged_chunk_attention`)
    and returns its K/V as PENDING rows — the caller scatters the first
    ``valid_len`` of them into the slot's pages
    (:func:`repro.runtime.steps.make_paged_prefill_chunk`).  Recurrent
    (SSM/conv) state continues from the cached state and freezes at
    ``valid_len`` exactly like bucketed prefill (``dt = 0`` past the real
    length); MoE routes lossless so chunk boundaries can never change which
    tokens fit an expert's capacity.  Returns ``(logits, new_cache)`` with
    logits (B, V) taken at the chunk's LAST REAL position — only the final
    chunk's logits feed sampling, the engine discards the rest.
    """
    cfg = plan.cfg
    if plan.enc_stages:
        raise NotImplementedError(
            "chunked prefill does not cover encoder-decoder frontends")
    B, C = tokens.shape
    x = _embed_tokens(cfg, params, tokens)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None] + jnp.arange(C)[None, :]

    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    for st in plan.stages:
        x, aux, st_cache = run_stage(
            st, params["stages"][st.name],
            None if lora is None else lora.get("stages", {}).get(st.name),
            x, aux, cfg, positions=positions, enc_out=None,
            cache=cache[st.name], pos=pos, scale_l=lora_scale,
            chunk=True, block_table=block_table, valid_len=valid_len)
        new_cache[st.name] = st_cache
    if valid_len is None:
        x = x[:, -1:]
    else:
        x = lax.dynamic_slice_in_dim(x, jnp.asarray(valid_len, jnp.int32) - 1,
                                     1, axis=1)
    x = L.rms_norm(x, params["final_ln"])
    logits = _lm_logits(cfg, params, x, lora, lora_scale)
    return logits[:, 0], new_cache


def verify_step(
    plan: Plan, params: PyTree, tokens: Array, cache: PyTree, pos,
    lora: Optional[PyTree] = None, *, lora_scale: float = 2.0,
    adapter_ids: Optional[Array] = None, block_table: Optional[Array] = None,
):
    """Speculative-decoding verify: score T tokens per slot in ONE forward.

    tokens: (B, T) int32 — per slot the already-emitted last token followed by
    T-1 draft proposals; pos: (B,) int32 — the position at which each slot's
    first token lands.  Returns ``(logits (B, T, V), pending)``: logits[:, j]
    conditions on tokens[:, :j+1], and ``pending`` mirrors the cache tree but
    holds this round's UNCOMMITTED state — attention blocks carry the block
    K/V ``(n_rep, B, T, kv, hd)`` to scatter, mamba blocks carry per-step
    conv/SSM snapshots ``(n_rep, B, T, ...)``.  The persistent cache is left
    untouched; ``repro.serving.speculative.commit_cache`` applies the accepted
    prefix once the accept length is known, which is what lets one fixed-shape
    verify step serve every accept/reject outcome without recompiling.
    """
    cfg = plan.cfg
    if plan.enc_stages:
        raise NotImplementedError(
            "speculative verify does not cover encoder-decoder frontends")
    B, T = tokens.shape
    x = _embed_tokens(cfg, params, tokens)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None] + jnp.arange(T)[None, :]

    aux = jnp.zeros((), jnp.float32)
    pending = {}
    for st in plan.stages:
        x, aux, st_pend = run_stage(
            st, params["stages"][st.name],
            None if lora is None else lora.get("stages", {}).get(st.name),
            x, aux, cfg, positions=positions, enc_out=None,
            cache=cache[st.name], pos=pos, scale_l=lora_scale,
            adapter_ids=adapter_ids, verify=True, block_table=block_table)
        pending[st.name] = st_pend
    x = L.rms_norm(x, params["final_ln"])
    logits = _lm_logits(cfg, params, x, lora, lora_scale, adapter_ids)
    return logits, pending

"""Sharding rules: logical param/activation/cache axes → mesh axes.

Mesh axes (see launch/mesh.py):
  * ``pod``   — cross-pod data parallelism (lowest bandwidth; carries only the
                rank-r adapter gradient all-reduce under LoRAM)
  * ``data``  — in-pod data parallelism / FSDP weight sharding
  * ``model`` — tensor/expert parallelism

Rules are shape-driven (divisibility-checked) rather than name-driven so the
same code shards every architecture in the zoo, including LoRAM-pruned
shapes whose widths changed:

  * stacked weights (L, a, b): largest-divisible non-layer axis → ``model``;
    with ``fsdp=True`` a second divisible axis → ``data`` (frozen-base FSDP:
    all-gather on use, no grad reduce-scatter since the base is frozen).
  * expert weights (L, E, a, b): E → ``model`` (EP), then a/b → ``data``.
  * embeddings / lm_head (V, D): V → ``model``, D → ``data`` (fsdp).
  * LoRA adapters: pruned-axis → ``model`` when divisible, else replicated
    (rank-r factors are tiny; replication is usually the right call).
  * activations (B, S, D): B → (pod, data); optionally S → ``model`` between
    blocks (sequence sharding of the residual stream, bounds live-activation
    memory for 4k×256 training cells).
  * KV caches (L, B, S, K, hd): B → (pod, data), then hd or K → ``model``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.quant.nf4 import QTensor

# ---------------------------------------------------------------------------
# Current-mesh context (lets model code apply constraints without plumbing)
# ---------------------------------------------------------------------------
# THREAD-LOCAL: a serving engine's host loop and a bench warmup (or a second
# engine on another thread) can interleave ``use_mesh`` scopes; a module-
# global dict would let one thread's __exit__ clobber the other's mesh
# mid-trace.  Each thread gets its own context, seeded from the defaults —
# entering a scope on thread A is invisible on thread B (regression-tested
# in tests/test_mesh_serving.py).

_DEFAULTS = {"mesh": None, "seq_shard": False, "head_shard": False}
_TLS = threading.local()


def _ctx() -> dict:
    state = getattr(_TLS, "state", None)
    if state is None:
        state = dict(_DEFAULTS)
        _TLS.state = state
    return state


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], seq_shard: bool = False,
             head_shard: bool = False):
    """Scope the current thread's mesh context.  ``seq_shard`` turns on
    sequence sharding of the residual stream between blocks; ``head_shard``
    turns on head-axis (tensor-parallel) activation constraints — training
    leaves it off by default (measured slightly negative on yi-34b train_4k,
    see §Perf iter 3), serving engines turn it on for decode/verify/chunk."""
    state = _ctx()
    prev = dict(state)
    state.update(mesh=mesh, seq_shard=seq_shard, head_shard=head_shard)
    try:
        yield
    finally:
        state.clear()
        state.update(prev)


def current_mesh() -> Optional[Mesh]:
    return _ctx()["mesh"]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def residual_constraint(x):
    """Applied between scanned blocks (wired into repro.models.model)."""
    ctx = _ctx()
    mesh = ctx["mesh"]
    if mesh is None or x.ndim != 3:
        return x
    b, s, d = x.shape
    spec = [None, None, None]
    if b % dp_size(mesh) == 0:
        spec[0] = dp_axes(mesh)
    if ctx["seq_shard"] and s % model_size(mesh) == 0 and s > 1:
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def head_constraint(x):
    """(B, S, H, D) attention activations: heads → model (GSPMD pads when the
    head count doesn't divide, e.g. yi-34b's 56 heads on a 16-way axis).
    Gated on the ``head_shard`` context flag — training leaves it off by
    default, serving turns it on (tensor-parallel decode/verify/chunk)."""
    ctx = _ctx()
    mesh = ctx["mesh"]
    if (mesh is None or not ctx["head_shard"] or x.ndim != 4
            or model_size(mesh) == 1):
        return x
    spec = [None, None, "model", None]
    if x.shape[0] % dp_size(mesh) == 0:
        spec[0] = dp_axes(mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def expert_constraint(x):
    """(E, C, D) MoE capacity buffers: experts → model (expert parallelism).
    Each expert's stacked SwiGLU then runs wholly on one shard — numerics
    identical to single-device (no contraction is split)."""
    mesh = _ctx()["mesh"]
    m = 1 if mesh is None else model_size(mesh)
    if mesh is None or x.ndim != 3 or m == 1:
        return x
    if x.shape[0] % m or x.shape[0] < m:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P("model")))


def logits_constraint(x):
    """(B, S, V) fp32 logits: vocab → model (loss logsumexp psums per shard)."""
    mesh = _ctx()["mesh"]
    if mesh is None or x.ndim < 2 or model_size(mesh) == 1:
        return x
    spec = [None] * x.ndim
    if x.shape[-1] % model_size(mesh) == 0 and x.shape[-1] >= model_size(mesh):
        spec[-1] = "model"
    if x.shape[0] % dp_size(mesh) == 0 and x.shape[0] >= dp_size(mesh):
        spec[0] = dp_axes(mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def install_residual_constraint():
    """Install the activation-constraint hooks into repro.models.model.
    Every hook is context-gated (no-op without a ``use_mesh`` scope on the
    calling thread; head constraints additionally require the scope's
    ``head_shard=True``), so installation itself never changes behavior —
    trainers and serving engines install unconditionally and pick policy at
    ``use_mesh`` time."""
    from repro.models import model as model_mod

    model_mod.set_residual_constraint(residual_constraint)
    model_mod.set_head_constraint(head_constraint)
    model_mod.set_logits_constraint(logits_constraint)


# ---------------------------------------------------------------------------
# Spec derivation
# ---------------------------------------------------------------------------

def _largest_divisible(shape: Sequence[int], axes: Sequence[int], size: int,
                       taken: Sequence[int] = ()) -> Optional[int]:
    best, best_dim = None, 0
    for ax in axes:
        if ax in taken:
            continue
        if shape[ax] % size == 0 and shape[ax] >= size and shape[ax] > best_dim:
            best, best_dim = ax, shape[ax]
    return best


# Megatron-style tensor-parallel classification by (stable) param name.
# column-parallel: y = x @ W with d_out sharded  → no collective on use
# row-parallel:    y = x @ W with d_in  sharded  → psum(y) after
_COLUMN = {"wq", "wk", "wv", "wg", "wu", "in_proj", "lm_head",
           "ws_g", "ws_u", "wr_g", "wr_u"}
_ROW = {"wo", "wd", "out_proj", "ws_d", "wr_d"}


def _weight_spec(shape, mesh: Mesh, *, layer_axes: int, fsdp: bool, pname: str,
                 expert_axis: Optional[int] = None) -> P:
    ndim = len(shape)
    spec: list = [None] * ndim
    m = model_size(mesh)
    d = mesh.shape.get("data", 1)
    taken: list = []

    def try_assign(ax, axis_name, size):
        if ax is not None and spec[ax] is None and shape[ax] % size == 0 and shape[ax] >= size:
            spec[ax] = axis_name
            taken.append(ax)
            return True
        return False

    if expert_axis is not None and try_assign(expert_axis, "model", m):
        pass
    elif pname in _COLUMN and ndim - layer_axes == 2:
        try_assign(ndim - 1, "model", m)           # d_out
    elif pname in _ROW and ndim - layer_axes == 2:
        try_assign(ndim - 2, "model", m)           # d_in
    elif pname == "embed":
        try_assign(0, "model", m)                  # vocab
    elif pname == "router":
        pass                                       # tiny: replicate
    else:
        ax = _largest_divisible(shape, list(range(layer_axes, ndim)), m, taken)
        if ax is not None:
            spec[ax] = "model"
            taken.append(ax)
    if fsdp and d > 1:
        ax = _largest_divisible(shape, list(range(layer_axes, ndim)), d, taken)
        if ax is not None:
            spec[ax] = "data"
    return P(*spec)


def param_specs(params, mesh: Mesh, *, fsdp: bool = True):
    """PartitionSpec tree matching a params/lora pytree."""

    def visit(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        is_stacked = "stacked" in keys
        if isinstance(leaf, QTensor):
            # handled via its children (codes/scales are leaves of the node)
            return leaf
        shape = leaf.shape
        ndim = len(shape)
        if ndim <= 1:
            return P()
        layer_axes = 1 if is_stacked else 0
        expert_axis = None
        pname = keys[-1] if keys else ""
        field = keys[-2] if len(keys) >= 2 else ""
        if any(k.startswith("we_") for k in (pname, field)) and ndim - layer_axes >= 3:
            expert_axis = layer_axes  # (L, E, a, b) → E
        if pname in ("a", "b"):
            # LoRA factor: B of a column-parallel target shares its d_out
            # sharding; A of a row-parallel target shares its d_in sharding.
            target = keys[-2] if len(keys) >= 2 else ""
            sp = [None] * ndim
            wide = ndim - 1 if pname == "a" else ndim - 2
            eligible = ((pname == "b" and target in _COLUMN)
                        or (pname == "a" and target in _ROW))
            if (eligible and shape[wide] % model_size(mesh) == 0
                    and shape[wide] >= 4 * model_size(mesh)):
                sp[wide] = "model"
            return P(*sp)
        return _weight_spec(shape, mesh, layer_axes=layer_axes, fsdp=fsdp,
                            pname=pname, expert_axis=expert_axis)

    def qtensor_spec(q: QTensor, pname: str):
        la = q.codes.ndim - 2
        codes_spec = _weight_spec(q.codes.shape, mesh, layer_axes=la, fsdp=fsdp,
                                  pname=pname,
                                  expert_axis=la - 1 if pname.startswith("we_") and la >= 1 else None)
        # scales share the d_out layout; the block axis mirrors d_in sharding
        sc = list(codes_spec) + [None] * (q.scales.ndim - len(codes_spec))
        sc = sc[: q.scales.ndim]
        if q.scales.shape[-2] % model_size(mesh) != 0 and sc[-2] == "model":
            sc[-2] = None  # few blocks: replicate the block axis
        if sc[-2] == "data" and q.scales.shape[-2] % mesh.shape.get("data", 1) != 0:
            sc[-2] = None
        return QTensor(codes_spec, P(*sc), q.shape, q.block)

    def visit_node(path, leaf):
        if isinstance(leaf, QTensor):
            keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
            return qtensor_spec(leaf, keys[-1] if keys else "")
        return visit(path, leaf)

    return jax.tree_util.tree_map_with_path(
        visit_node, params, is_leaf=lambda x: isinstance(x, QTensor))


def batch_specs(batch, mesh: Mesh):
    dp = dp_axes(mesh)

    def visit(path, leaf):
        shape = leaf.shape
        sp: list = [None] * len(shape)
        if shape and shape[0] % dp_size(mesh) == 0 and shape[0] >= dp_size(mesh):
            sp[0] = dp
        return P(*sp)

    return jax.tree_util.tree_map_with_path(visit, batch)


def cache_specs(cache, mesh: Mesh):
    """KV/SSM cache tree: (L, B, ...) — B → dp, best trailing axis → model."""
    m = model_size(mesh)

    def visit(path, leaf):
        shape = leaf.shape
        sp: list = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % dp_size(mesh) == 0 and shape[1] >= dp_size(mesh):
            sp[1] = dp_axes(mesh)
        # prefer sharding heads or head_dim (trailing axes) over seq
        for ax in range(len(shape) - 1, 1, -1):
            if shape[ax] % m == 0 and shape[ax] >= m:
                sp[ax] = "model"
                break
        return P(*sp)

    return jax.tree_util.tree_map_with_path(visit, cache)


def serve_cache_specs(cache, mesh: Mesh, *, paged: bool):
    """Serving-cache placement (the tick's KV side of the TickState contract).

    Attention K/V leaves — dense ``(n_rep, S_slots, seq, K, hd)`` or paged
    pools ``(n_rep, n_pages, page, K, hd)``:

      * K (kv heads) → ``model`` when divisible, else hd → ``model`` —
        sharding the HEAD axis keeps each (slot, head) attention whole on one
        shard (softmax and both einsums contract unsharded axes), unlike
        :func:`cache_specs`'s trailing-axis preference which would split the
        per-head contraction and change reduction order.
      * dense slot axis → ``data`` when divisible (pure DP over slots);
        paged POOL pages stay replicated across ``data`` — page ids are a
        global namespace shared by every slot's block-table row, so carving
        the pool over data-parallel shards would make the host allocator
        device-count-DEPENDENT.  The allocator stays oblivious to the mesh.

    Everything else (SSM/conv recurrent rows) is replicated: O(1) per slot,
    and the commit/rollback scatters index it by slot from every shard."""
    m = model_size(mesh)
    dp = dp_axes(mesh)

    def visit(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        sp: list = [None] * leaf.ndim
        # int8 pools carry per-row scale pools (..., K, 1) beside the codes;
        # they MUST shard identically on the head axis so per-shard kernel
        # dispatch sees aligned pool + scale slices (hd fallback self-gates:
        # a scale leaf's trailing dim is 1, which never divides model > 1)
        if keys and keys[-1] in ("k", "v", "k_sc", "v_sc") and leaf.ndim == 5:
            if not paged and leaf.shape[1] % dp_size(mesh) == 0 \
                    and leaf.shape[1] >= dp_size(mesh):
                sp[1] = dp
            if leaf.shape[3] % m == 0 and leaf.shape[3] >= m:
                sp[3] = "model"
            elif leaf.shape[4] % m == 0 and leaf.shape[4] >= m:
                sp[4] = "model"
        return P(*sp)

    return jax.tree_util.tree_map_with_path(visit, cache)


def adapter_bank_specs(bank) -> object:
    """PartitionSpec tree for a paged adapter bank: REPLICATED everywhere.

    The bank is rank-r LoRA factors stacked over ``bank_slots`` rows —
    tiny next to the base weights — and the decode tick gathers per-slot
    rows out of it by ``TickState.adapter_ids``.  Replication keeps that
    gather local on every shard (no collective on the hot path) and keeps
    the host-side :class:`repro.serving.adapters.AdapterResidency`
    allocator device-count-agnostic, exactly like the paged KV pool's
    page-id namespace in :func:`serve_cache_specs`.

    Engines don't ``device_put`` against these specs: bank rows are
    rewritten between ticks by functional ``.at[row].set`` streaming
    commits, and an uncommitted bank lets jit place each new version
    against the committed operands (which resolves to this replicated
    layout).  The specs exist for explicitness — assertions, HBM
    attribution, and any future offload policy that wants to commit the
    bank eagerly go through here."""
    return jax.tree.map(lambda _: P(), bank)


def replicated_shardings(tree, mesh: Mesh):
    """Everywhere-replicated placements for ``jax.device_put`` (adapter
    banks per :func:`adapter_bank_specs`, tick state, host-built rows)."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def shard_serving(mesh: Mesh, params, cache, *, paged: bool):
    """Place a serving engine's weights and cache onto ``mesh``: weights via
    :func:`param_specs` with ``fsdp=False`` (tensor/expert-parallel over
    ``model``, REPLICATED over ``data`` — serving never all-gathers), cache
    via :func:`serve_cache_specs`.  Returns ``(params, cache)``."""
    params = jax.device_put(
        params, to_shardings(param_specs(params, mesh, fsdp=False), mesh))
    cache = jax.device_put(
        cache, to_shardings(serve_cache_specs(cache, mesh, paged=paged),
                            mesh))
    return params, cache


def opt_specs(lora_specs_tree, opt_state):
    """AdamW moments mirror the lora tree; step is replicated."""
    from repro.optim.adamw import AdamWState

    return AdamWState(P(), lora_specs_tree, lora_specs_tree)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, spec_tree,
        is_leaf=lambda x: isinstance(x, P))

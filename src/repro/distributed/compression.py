"""Int8 error-feedback gradient compression for cross-pod all-reduce.

Under LoRAM the cross-pod traffic is only the adapter gradients (rank-r
factors), already ~1000× smaller than a full fine-tune's. Compression is the
belt-and-braces option for large ranks or lm_head adapters (vocab × r can
reach 100s of MB at r=64 on a 256k vocab):

  quantize(g - e) to int8 with per-tensor absmax  →  psum in int32
  →  dequantize; the residual e carries quantization error to the next step
  (error feedback keeps the method unbiased over time — Seide et al. 2014).

The compressed all-reduce runs under ``shard_map`` over the ``pod`` axis so
ICI/DCN carries 1 byte/element instead of 4.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """One error-feedback compressed all-reduce (mean) over ``axis_name``.
    Returns (reduced_g, new_err).  Call inside shard_map/pmapped code."""
    comp_in = g + err
    q, scale = quantize_int8(comp_in)
    local_deq = dequantize_int8(q, scale)
    new_err = comp_in - local_deq
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)  # per-shard scales vary
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # unbiased-ish: use mean scale for the summed int32 accumulator
    return total.astype(jnp.float32) * (scale_sum / n) / n, new_err


def make_compressed_grad_allreduce(mesh, axis: str = "pod"):
    """Returns f(grads, err_tree) -> (mean_grads, new_err_tree) running the
    compressed all-reduce over the pod axis via shard_map.  Grads must be
    replicated within a pod (i.e. already psum'd over data/model)."""
    from jax.experimental.shard_map import shard_map

    def one(g, e):
        return compressed_psum(g, e, axis)

    def f(grads, errs):
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(errs)
        outs = []
        for g, e in zip(flat_g, flat_e):
            fn = shard_map(one, mesh=mesh,
                           in_specs=(P(), P()), out_specs=(P(), P()),
                           check_rep=False)
            outs.append(fn(g.astype(jnp.float32), e))
        new_g = tdef.unflatten([o[0] for o in outs])
        new_e = tdef.unflatten([o[1] for o in outs])
        return new_g, new_e

    return f


def init_error_state(grads_template) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)

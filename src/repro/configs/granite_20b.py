"""--arch granite-20b : exact assigned config (see registry.py for provenance)."""
from repro.configs.registry import ARCHS, SMOKE

ARCH_ID = "granite-20b"
CONFIG = ARCHS[ARCH_ID]
SMOKE_CONFIG = SMOKE.get(ARCH_ID)

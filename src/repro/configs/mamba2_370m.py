"""--arch mamba2-370m : exact assigned config (see registry.py for provenance)."""
from repro.configs.registry import ARCHS, SMOKE

ARCH_ID = "mamba2-370m"
CONFIG = ARCHS[ARCH_ID]
SMOKE_CONFIG = SMOKE.get(ARCH_ID)

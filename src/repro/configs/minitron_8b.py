"""--arch minitron-8b : exact assigned config (see registry.py for provenance)."""
from repro.configs.registry import ARCHS, SMOKE

ARCH_ID = "minitron-8b"
CONFIG = ARCHS[ARCH_ID]
SMOKE_CONFIG = SMOKE.get(ARCH_ID)

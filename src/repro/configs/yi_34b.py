"""--arch yi-34b : exact assigned config (see registry.py for provenance)."""
from repro.configs.registry import ARCHS, SMOKE

ARCH_ID = "yi-34b"
CONFIG = ARCHS[ARCH_ID]
SMOKE_CONFIG = SMOKE.get(ARCH_ID)

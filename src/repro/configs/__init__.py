from repro.configs.base import (  # noqa: F401
    BlockSpec,
    LoRAConfig,
    LoRAMConfig,
    ModelConfig,
    QuantPolicy,
    ResilienceConfig,
    ServeConfig,
    Stage,
    StageDims,
    TrainConfig,
    round_to,
)
from repro.configs.registry import ARCHS, SMOKE, get_arch, get_smoke  # noqa: F401

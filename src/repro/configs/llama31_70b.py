"""--arch llama31-70b : exact assigned config (see registry.py for provenance)."""
from repro.configs.registry import ARCHS, SMOKE

ARCH_ID = "llama31-70b"
CONFIG = ARCHS[ARCH_ID]
SMOKE_CONFIG = SMOKE.get(ARCH_ID)

"""--arch whisper-tiny : exact assigned config (see registry.py for provenance)."""
from repro.configs.registry import ARCHS, SMOKE

ARCH_ID = "whisper-tiny"
CONFIG = ARCHS[ARCH_ID]
SMOKE_CONFIG = SMOKE.get(ARCH_ID)

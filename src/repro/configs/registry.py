"""Assigned architectures (exact configs from the task spec) + smoke variants.

Every entry is selectable via ``--arch <id>`` in the launchers.  FULL configs
are only ever touched through ``jax.eval_shape`` / AOT lowering (no
allocation); SMOKE configs are runnable-on-CPU reductions of the same family
used by tests.
"""
from __future__ import annotations

from dataclasses import replace

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Full assigned configs — [source; verified-tier] in the task spec
# ---------------------------------------------------------------------------

ARCHS = {
    # enc-dec, conv frontend stubbed (precomputed frame embeddings)
    "whisper-tiny": ModelConfig(
        name="whisper-tiny", family="encdec", n_layers=4, d_model=384,
        n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=51865,
        enc_layers=4, enc_len=1500, tie_embeddings=True,
    ),
    "yi-34b": ModelConfig(
        name="yi-34b", family="dense", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=20480, vocab_size=64000,
        rope_theta=5_000_000.0,
    ),
    # 5:1 local:global, 128k context, giant vocab
    "gemma3-12b": ModelConfig(
        name="gemma3-12b", family="dense", n_layers=48, d_model=3840,
        n_heads=16, n_kv_heads=8, head_dim=256, d_ff=15360, vocab_size=262144,
        local_global_ratio=5, window=1024, rope_theta=1_000_000.0,
        tie_embeddings=True, supports_long_context=True,
    ),
    "minitron-8b": ModelConfig(
        name="minitron-8b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=16384, vocab_size=256000,
    ),
    # MQA (kv=1) code model
    "granite-20b": ModelConfig(
        name="granite-20b", family="dense", n_layers=52, d_model=6144,
        n_heads=48, n_kv_heads=1, d_ff=24576, vocab_size=49152,
    ),
    # 128 experts top-2 + dense residual
    "arctic-480b": ModelConfig(
        name="arctic-480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000,
        n_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
    ),
    # 2 shared + 64 routed top-6, fine-grained
    "deepseek-moe-16b": ModelConfig(
        name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=102400,
        n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
    ),
    # Mamba2 + shared attention blocks
    "zamba2-2.7b": ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, head_dim=80, d_ff=10240, vocab_size=32000,
        ssm_state=64, d_inner=5120, ssm_head_dim=64, shared_attn_period=6,
        supports_long_context=True,
    ),
    # InternViT frontend stubbed; InternLM2 backbone
    "internvl2-26b": ModelConfig(
        name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=92553,
        n_patches=256,
    ),
    # attn-free SSD
    "mamba2-370m": ModelConfig(
        name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=50280,
        ssm_state=128, d_inner=2048, ssm_head_dim=64, tie_embeddings=True,
        supports_long_context=True,
    ),
    # ---- the paper's own models (LoRAM experiments) --------------------------
    "llama2-13b": ModelConfig(
        name="llama2-13b", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=40, d_ff=13824, vocab_size=32000,
    ),
    "llama2-70b": ModelConfig(
        name="llama2-70b", family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=28672, vocab_size=32000,
    ),
    "llama31-70b": ModelConfig(
        name="llama31-70b", family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=28672, vocab_size=128256,
        rope_theta=500_000.0,
    ),
}


# ---------------------------------------------------------------------------
# Smoke (reduced) configs — same family, CPU-runnable
# ---------------------------------------------------------------------------

def _smoke(cfg: ModelConfig, **kw) -> ModelConfig:
    return replace(cfg, **kw)


SMOKE = {
    "whisper-tiny": _smoke(
        ARCHS["whisper-tiny"], name="whisper-tiny-smoke", n_layers=2,
        enc_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, enc_len=16),
    "yi-34b": _smoke(
        ARCHS["yi-34b"], name="yi-34b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256),
    "gemma3-12b": _smoke(
        ARCHS["gemma3-12b"], name="gemma3-12b-smoke", n_layers=6, d_model=64,
        n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=256,
        local_global_ratio=2, window=8),
    "minitron-8b": _smoke(
        ARCHS["minitron-8b"], name="minitron-8b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256),
    "granite-20b": _smoke(
        ARCHS["granite-20b"], name="granite-20b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256),
    # capacity_factor=8 in smoke configs: capacity-based token dropping makes
    # MoE outputs depend on the co-batched token count, which would break the
    # prefill-vs-forward consistency tests at tiny batch sizes.
    "arctic-480b": _smoke(
        ARCHS["arctic-480b"], name="arctic-480b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96, vocab_size=256,
        n_experts=8, top_k=2, moe_d_ff=64, capacity_factor=8.0),
    "deepseek-moe-16b": _smoke(
        ARCHS["deepseek-moe-16b"], name="deepseek-moe-16b-smoke", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=96,
        vocab_size=256, n_experts=8, top_k=3, moe_d_ff=48, n_shared_experts=2,
        capacity_factor=8.0),
    "zamba2-2.7b": _smoke(
        ARCHS["zamba2-2.7b"], name="zamba2-2.7b-smoke", n_layers=6, d_model=64,
        n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=256,
        ssm_state=16, d_inner=128, ssm_head_dim=32, shared_attn_period=3),
    "internvl2-26b": _smoke(
        ARCHS["internvl2-26b"], name="internvl2-26b-smoke", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, n_patches=8),
    "mamba2-370m": _smoke(
        ARCHS["mamba2-370m"], name="mamba2-370m-smoke", n_layers=2,
        d_model=64, d_ff=0, vocab_size=256, ssm_state=16, d_inner=128,
        ssm_head_dim=32),
    "llama2-13b": _smoke(
        ARCHS["llama2-13b"], name="llama2-13b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256),
    "llama2-70b": _smoke(
        ARCHS["llama2-70b"], name="llama2-70b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256),
}


def get_arch(name: str) -> ModelConfig:
    return ARCHS[name]


def get_smoke(name: str) -> ModelConfig:
    return SMOKE[name]


# ---------------------------------------------------------------------------
# Shape cells (the assigned input-shape set; applies to every LM arch)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# Sub-quadratic archs eligible for long_500k (see DESIGN.md shape-cell skips)
LONG_CONTEXT_OK = tuple(n for n, c in ARCHS.items() if c.supports_long_context)


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "full-attention arch: 500k decode requires sub-quadratic attention"
    return True, ""

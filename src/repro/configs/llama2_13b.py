"""--arch llama2-13b : exact assigned config (see registry.py for provenance)."""
from repro.configs.registry import ARCHS, SMOKE

ARCH_ID = "llama2-13b"
CONFIG = ARCHS[ARCH_ID]
SMOKE_CONFIG = SMOKE.get(ARCH_ID)

"""--arch arctic-480b : exact assigned config (see registry.py for provenance)."""
from repro.configs.registry import ARCHS, SMOKE

ARCH_ID = "arctic-480b"
CONFIG = ARCHS[ARCH_ID]
SMOKE_CONFIG = SMOKE.get(ARCH_ID)

"""--arch zamba2-2.7b : exact assigned config (see registry.py for provenance)."""
from repro.configs.registry import ARCHS, SMOKE

ARCH_ID = "zamba2-2.7b"
CONFIG = ARCHS[ARCH_ID]
SMOKE_CONFIG = SMOKE.get(ARCH_ID)

"""--arch deepseek-moe-16b : exact assigned config (see registry.py for provenance)."""
from repro.configs.registry import ARCHS, SMOKE

ARCH_ID = "deepseek-moe-16b"
CONFIG = ARCHS[ARCH_ID]
SMOKE_CONFIG = SMOKE.get(ARCH_ID)

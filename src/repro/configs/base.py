"""Config system for the LoRAM framework.

Every model in the zoo is described by a :class:`ModelConfig` which expands
into a list of :class:`Stage`s.  A stage is ``n_rep`` repetitions of a
*superblock* (an ordered tuple of :class:`BlockSpec`s) executed under a single
``lax.scan`` — this keeps HLO size O(superblock) regardless of depth, which is
what makes 60-layer × 512-device AOT compiles tractable and keeps compile
times bounded on real clusters.

Heterogeneous architectures map naturally:

* gemma3   → one stage, superblock = 5×local-attn + 1×global-attn
* zamba2   → one stage, superblock = k×mamba + 1×shared-attn (shared params)
* whisper  → encoder stage + decoder stage
* LoRAM-Stru with keep-first/last → three stages with different pruned dims
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block-level specs
# ---------------------------------------------------------------------------

# Block kinds understood by repro.models.model:
#   "attn"        causal self-attention (+ optional sliding window)
#   "enc_attn"    bidirectional self-attention (encoder)
#   "cross_attn"  causal self-attn is NOT included; attends to encoder output
#   "mlp"         SwiGLU MLP
#   "moe"         mixture-of-experts MLP (optional shared experts / dense residual)
#   "mamba"       Mamba2 SSD mixer
ALL_KINDS = ("attn", "enc_attn", "cross_attn", "mlp", "moe", "mamba")


@dataclass(frozen=True)
class BlockSpec:
    """One residual sub-block inside a superblock."""

    kind: str
    window: int = 0          # >0 → sliding-window attention (gemma3 local)
    shared: bool = False     # params shared across superblock repetitions (zamba2)
    name: str = ""           # unique name within the superblock

    def __post_init__(self):
        assert self.kind in ALL_KINDS, self.kind


@dataclass(frozen=True)
class StageDims:
    """Width parameters for one stage.  LoRAM structured pruning produces
    stages whose dims are *smaller* than the parent config's."""

    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    dense_residual_d_ff: int = 0
    # SSM (Mamba2)
    d_inner: int = 0
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    conv_width: int = 4

    def validate(self):
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.n_heads == 0
        if self.d_inner:
            assert self.d_inner % self.ssm_head_dim == 0


@dataclass(frozen=True)
class Stage:
    """``n_rep`` scanned repetitions of ``superblock`` at width ``dims``."""

    superblock: Tuple[BlockSpec, ...]
    n_rep: int
    dims: StageDims
    name: str = "stage"

    @property
    def n_layers(self) -> int:
        # "layer" = one attention-or-mixer + mlp pair, for bookkeeping only.
        mixers = sum(1 for b in self.superblock if b.kind in ("attn", "enc_attn", "mamba"))
        return self.n_rep * max(mixers, 1)


# ---------------------------------------------------------------------------
# Model-level config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // n_heads

    # attention pattern
    local_global_ratio: int = 0      # gemma3: 5 → 5 local per 1 global
    window: int = 1024

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    dense_residual: bool = False     # arctic: dense FFN residual alongside MoE
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    d_inner: int = 0                 # 0 → 2*d_model when SSM present
    ssm_head_dim: int = 64
    shared_attn_period: int = 0      # zamba2: one shared attn block per k mamba layers

    # encoder-decoder / multimodal frontend
    enc_layers: int = 0
    enc_len: int = 0                 # encoder sequence length (whisper frames)
    n_patches: int = 0               # VLM: patch embeddings prepended to text

    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    max_seq_len: int = 524_288

    # which cells apply (spec: skip long_500k for pure full-attention archs,
    # skip decode for encoder-only — none here are encoder-only)
    supports_long_context: bool = False

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def resolved_d_inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.resolved_d_inner // self.ssm_head_dim if self.ssm_state else 0

    def base_dims(self) -> StageDims:
        return StageDims(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.resolved_head_dim,
            d_ff=self.d_ff,
            n_experts=self.n_experts,
            top_k=self.top_k,
            moe_d_ff=self.moe_d_ff or self.d_ff,
            n_shared_experts=self.n_shared_experts,
            shared_d_ff=(self.moe_d_ff or self.d_ff) * max(self.n_shared_experts, 0),
            dense_residual_d_ff=self.d_ff if self.dense_residual else 0,
            d_inner=self.resolved_d_inner if self.ssm_state else 0,
            ssm_state=self.ssm_state,
            ssm_heads=self.ssm_heads,
            ssm_head_dim=self.ssm_head_dim,
        )

    # ---- stage expansion ----------------------------------------------------
    def stages(self) -> Tuple[Stage, ...]:
        """Expand the config into scanned stages (decoder side for encdec)."""
        dims = self.base_dims()
        if self.family in ("dense", "vlm"):
            if self.local_global_ratio:
                k = self.local_global_ratio
                sb = tuple(
                    b
                    for i in range(k)
                    for b in (BlockSpec("attn", window=self.window, name=f"local{i}"),
                              BlockSpec("mlp", name=f"mlp_l{i}"))
                ) + (BlockSpec("attn", name="global"), BlockSpec("mlp", name="mlp_g"))
                assert self.n_layers % (k + 1) == 0, (self.name, self.n_layers, k)
                return (Stage(sb, self.n_layers // (k + 1), dims, "lg"),)
            sb = (BlockSpec("attn", name="attn"), BlockSpec("mlp", name="mlp"))
            return (Stage(sb, self.n_layers, dims, "dense"),)
        if self.family == "moe":
            sb = (BlockSpec("attn", name="attn"), BlockSpec("moe", name="moe"))
            return (Stage(sb, self.n_layers, dims, "moe"),)
        if self.family == "ssm":
            sb = (BlockSpec("mamba", name="mamba"),)
            return (Stage(sb, self.n_layers, dims, "ssm"),)
        if self.family == "hybrid":
            p = self.shared_attn_period
            assert p and self.n_layers % p == 0
            sb = tuple(BlockSpec("mamba", name=f"mamba{i}") for i in range(p)) + (
                BlockSpec("attn", shared=True, name="shared_attn"),
                BlockSpec("mlp", shared=True, name="shared_mlp"),
            )
            return (Stage(sb, self.n_layers // p, dims, "hybrid"),)
        if self.family == "encdec":
            dec = (
                BlockSpec("attn", name="self_attn"),
                BlockSpec("cross_attn", name="cross_attn"),
                BlockSpec("mlp", name="mlp"),
            )
            return (Stage(dec, self.n_layers, dims, "dec"),)
        raise ValueError(self.family)

    def encoder_stages(self) -> Tuple[Stage, ...]:
        if not self.enc_layers:
            return ()
        dims = self.base_dims()
        sb = (BlockSpec("enc_attn", name="enc_attn"), BlockSpec("mlp", name="enc_mlp"))
        return (Stage(sb, self.enc_layers, dims, "enc"),)


# ---------------------------------------------------------------------------
# LoRA / LoRAM configs
# ---------------------------------------------------------------------------

DEFAULT_TARGETS = ("wq", "wk", "wv", "wo", "wg", "wu", "wd", "lm_head")


@dataclass(frozen=True)
class LoRAConfig:
    rank: int = 8
    alpha: float = 16.0
    targets: Tuple[str, ...] = DEFAULT_TARGETS
    dtype: str = "float32"           # adapters train in fp32 (paper: BF16 mixed)

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


@dataclass(frozen=True)
class LoRAMConfig:
    """The paper's technique knobs.

    method:   none | rand | stru | semi | unst
    ratio:    fraction of prunable units removed (paper: 0.65–0.95)
    quantize: NF4-quantize the (pruned) frozen base → QLoRAM
    align:    run continual-pretraining alignment before SFT
    keep_first/keep_last: LLM-Pruner-style unpruned boundary layers
    """

    method: str = "none"
    ratio: float = 0.0
    quantize: bool = False
    align: bool = True
    keep_first: int = 4
    keep_last: int = 2
    semi_n: int = 4                  # 4:8 semi-structured pattern
    semi_m: int = 8
    seed: int = 0

    def __post_init__(self):
        assert self.method in ("none", "rand", "stru", "semi", "unst")
        assert 0.0 <= self.ratio < 1.0


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 128
    seq_len: int = 512
    microbatch: int = 0              # 0 → one microbatch per data shard step
    learning_rate: float = 1e-3
    warmup_steps: int = 20
    total_steps: int = 400
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    remat: bool = True
    seq_shard_activations: bool = True
    param_dtype: str = "bfloat16"
    seed: int = 0


@dataclass(frozen=True)
class QuantPolicy:
    """Serving-time quantization (the QLoRAM "infer large" half).

    weights: "none" | "nf4" — NF4-quantize the frozen base projections at
             engine load; the decode tick then runs them through the fused
             dequant-matmul kernel (repro.kernels.nf4_matmul).  Embeddings,
             norms, lm_head and the LoRA banks always stay fp.
    kv:      "none" | "int8" — store the paged attention K/V pool as int8
             codes + per-row absmax scales (repro.quant.kv); requires
             kv_paging.
    block:   NF4 scale-block length along d_in (64 = the kernel's QBLOCK).
    targets: which projection names quantize under weights="nf4".
    """

    weights: str = "none"
    kv: str = "none"
    block: int = 64
    targets: Tuple[str, ...] = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")

    def __post_init__(self):
        assert self.weights in ("none", "nf4"), self.weights
        assert self.kv in ("none", "int8"), self.kv


@dataclass(frozen=True)
class ResilienceConfig:
    """Serving resilience policy (repro.serving.resilience).

    Admission control, deadlines, the graceful-degradation ladder and
    tick-retry/watchdog escalation are all host-side: with the default
    (disabled) policy the engines behave bit-identically to a build
    without the resilience layer, and nothing here adds TickState leaves.

    queue_limit:  bound on the scheduler submit queue (0 → unbounded).
    queue_policy: what happens when the queue is full —
                  "reject" sheds the NEW request, "shed-oldest" evicts
                  the oldest queued request to make room.
    ttft_deadline_s: fail a request with status="timeout" if its first
                  token has not been produced this many seconds after
                  submit (0 → no TTFT deadline).
    deadline_s:   end-to-end deadline from submit; on expiry the request
                  terminates with status="timeout" and whatever tokens
                  it generated so far (0 → no deadline).
    degradation:  enable the hysteresis degradation ladder
                  (level 0 healthy → 1 shrink-γ → 2 disable speculation
                  → 3 evict idle prefixes → 4 shrink prefill chunk
                  → 5 shed load), driven by queue depth, page-pool
                  occupancy and watchdog stalls.
    degrade_high/degrade_low: pressure thresholds (fractions) with
                  hysteresis — step up above high, step down below low.
    degrade_up_ticks/degrade_down_ticks: consecutive observations
                  required before moving a level (debounce).
    tick_retries: bounded retries (with linear backoff) when a decode
                  tick dispatch raises a transient fault; exhaustion
                  escalates to snapshot-and-restart.
    retry_backoff_s: base sleep between retries (attempt-scaled).
    stall_degrade_after: watchdog stalls before forcing the degradation
                  ladder up one level (0 → never).
    stall_restart_after: watchdog stalls before a snapshot-and-restart
                  (0 → never).
    """

    queue_limit: int = 0
    queue_policy: str = "reject"
    ttft_deadline_s: float = 0.0
    deadline_s: float = 0.0
    degradation: bool = False
    degrade_high: float = 0.85
    degrade_low: float = 0.50
    degrade_up_ticks: int = 2
    degrade_down_ticks: int = 8
    tick_retries: int = 2
    retry_backoff_s: float = 0.0
    stall_degrade_after: int = 0
    stall_restart_after: int = 0

    def __post_init__(self):
        assert self.queue_policy in ("reject", "shed-oldest"), self.queue_policy
        assert self.queue_limit >= 0 and self.tick_retries >= 0
        assert self.ttft_deadline_s >= 0.0 and self.deadline_s >= 0.0
        assert 0.0 < self.degrade_low <= self.degrade_high
        assert self.degrade_up_ticks >= 1 and self.degrade_down_ticks >= 1

    @property
    def enabled(self) -> bool:
        """Anything beyond pure pass-through behavior switched on?"""
        return bool(self.queue_limit or self.ttft_deadline_s
                    or self.deadline_s or self.degradation
                    or self.stall_degrade_after or self.stall_restart_after)


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 1
    max_seq_len: int = 4096
    merge_adapters: bool = True      # paper merges W0 + B^R A^R
    kv_cache_dtype: str = "bfloat16"
    # continuous batching (repro.serving.scheduler / ContinuousServeEngine):
    max_slots: int = 8               # fixed decode batch — jit never recompiles
    max_adapters: int = 4            # capacity of the stacked adapter bank
    max_new_tokens: int = 128        # per-slot on-device output buffer length
    # paged adapter bank (repro.serving.adapters.AdapterResidency): the
    # device bank holds adapter_bank_slots rows (row 0 reserved for the
    # base route) streamed host↔HBM on demand, LRU-evicted at refcount 0;
    # the host-side registry is unbounded.  0 → max_adapters rows, i.e.
    # the dense-equivalent bank (every registered adapter stays resident)
    adapter_bank_slots: int = 0
    # zero-padded rank buckets for mixed-rank adapters sharing one bank:
    # adapters pad up to the nearest of N even rank steps (1 → everything
    # pads to the template rank).  Padding is exactly zero-delta.
    adapter_rank_buckets: int = 1
    # speculative decoding (repro.serving.speculative):
    draft_gamma: int = 0             # draft tokens per round (0 → disabled)
    draft_stage: str = "trained"     # "trained" (pruned base + pruned LoRA)
                                     # | "base" (pruned base only)
    gamma_autotune: bool = False     # adapt draft_gamma to measured acceptance
    # paged KV cache (repro.serving.pages / ContinuousServeEngine):
    kv_paging: bool = False          # page the attention K/V cache
    kv_page_size: int = 16           # tokens per page (power of two)
    kv_pages: int = 0                # page-pool capacity incl. the reserved
                                     # trash page (0 → dense-equivalent pool)
    # prompt-length bucketing: pad prompts up to power-of-two buckets so
    # prefill compiles O(log max_seq_len) times, not once per distinct length
    prefill_buckets: bool = True
    # chunked prefill (paged engines only): admit long prompts in fixed
    # page-aligned chunks interleaved with decode ticks instead of one
    # monolithic prefill dispatch — decode never stalls behind a long prompt
    prefill_chunk: int = 0           # tokens per chunk (0 → monolithic);
                                     # must be a multiple of kv_page_size
    # copy-on-write prefix sharing (paged engines only): requests submitted
    # with a prefix_id map the shared prefix's pages read-only into their
    # block tables; the partially-filled boundary page forks on the first
    # divergent write, eviction decrements refcounts instead of freeing
    prefix_sharing: bool = False
    # serving mesh (repro.serving.engine / launch/serve.py --mesh):
    # data × model device grid the engine builds when no explicit Mesh is
    # passed.  1 × 1 (default) means no mesh at all — single-device serving,
    # the whole sharding path compiles away.  See the TickState sharding
    # table in repro/serving/engine.py for what lands on which axis.
    mesh_data: int = 1               # pure DP (dense slot axis, activations)
    mesh_model: int = 1              # tensor/expert parallel (heads, FFN, EP)
    # observability (repro.obs): metrics registry + tick tracer + lifecycle
    # event log.  Strictly host-side — instrumentation never enters a jitted
    # function, changes emitted tokens, or adds TickState leaves.  The
    # registry's counters stay on even when obs=False (they back the
    # engines' n_* accessors); the switch gates the tracer and event log.
    obs: bool = True                 # span tracer + event log on
    obs_trace_capacity: int = 512    # span ring size (old spans fall off)
    obs_event_capacity: int = 4096   # lifecycle-event ring size
    obs_device_sync: bool = False    # block_until_ready at every span close:
                                     # honest per-phase device timings at the
                                     # cost of dispatch pipelining
    # opt-in straggler detection: EWMA of tick wall-clock via
    # runtime.watchdog.StepWatchdog; a straggler tick is COUNTED
    # (serve_stalls_total + a "stall" event), never raised
    tick_watchdog: bool = False
    # serving-time quantization (QLoRAM): NF4 base weights through the fused
    # kernel and/or int8 paged KV pool — see QuantPolicy
    quant: QuantPolicy = QuantPolicy()
    # serving resilience: bounded admission, deadlines, load shedding,
    # degradation ladder, retry/restart escalation — see ResilienceConfig.
    # The default policy is fully disabled (pass-through).
    resilience: ResilienceConfig = ResilienceConfig()


def round_to(x: int, mult: int) -> int:
    """Round down to a multiple, never below one multiple (MXU lane alignment)."""
    return max(mult, (x // mult) * mult)


def replace_cfg(cfg, **kw):
    return replace(cfg, **kw)

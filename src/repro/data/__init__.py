from repro.data.synthetic import (  # noqa: F401
    AlignmentCorpus,
    SFTDataset,
    batch_iterator,
    index_for,
)

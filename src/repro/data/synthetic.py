"""Deterministic synthetic data pipeline.

The container is offline, so FineWeb/OpenWebMath (alignment) and
OpenHermes/OpenOrca (SFT) are replaced by deterministic synthetic corpora
with matched token statistics (Zipf-distributed unigrams + local n-gram
structure so models have something learnable).  The pipeline interface is
the real one — host-sharded, stateless addressing, elastic — and a real
tokenized corpus drops in by replacing the two dataset classes.

Statelessness is the fault-tolerance property: batch content is a pure
function of (seed, step, host_index, n_hosts), so restarts and elastic
re-sharding never replay or skip data (see runtime/elastic.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


def index_for(step: int, host: int, n_hosts: int, seed: int) -> np.random.Generator:
    """The stateless addressing function: one Philox stream per (step, host)."""
    return np.random.Generator(
        np.random.Philox(np.random.SeedSequence([seed, step, host, n_hosts])))


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int, a: float = 1.3):
    """Zipf-ish token draw bounded to [2, vocab)."""
    z = rng.zipf(a, size=shape).astype(np.int64)
    return (z % max(vocab - 2, 1) + 2).astype(np.int32)


def _add_ngram_structure(rng, tokens, vocab):
    """Make ~30% of positions copy t[i-2] (+1 mod V): a learnable 2-gram."""
    mask = rng.random(tokens.shape) < 0.3
    mask[:, :2] = False
    shifted = np.roll(tokens, 2, axis=1)
    tokens = np.where(mask, (shifted + 1) % vocab, tokens)
    return tokens.astype(np.int32)


@dataclasses.dataclass
class SFTDataset:
    """Instruction-tuning stand-in: (prompt, answer) pairs packed to seq_len;
    loss mask covers answer tokens only (paper: L_SFT on ground-truth
    answers)."""

    vocab: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int, host: int = 0, n_hosts: int = 1,
              batch_size: int = 8) -> Dict[str, np.ndarray]:
        rng = index_for(step, host, n_hosts, self.seed)
        toks = _zipf_tokens(rng, (batch_size, self.seq_len + 1), self.vocab)
        toks = _add_ngram_structure(rng, toks, self.vocab)
        toks[:, 0] = 1  # BOS
        prompt_len = rng.integers(self.seq_len // 8, self.seq_len // 2,
                                  size=(batch_size,))
        pos = np.arange(self.seq_len)[None, :]
        loss_mask = (pos >= prompt_len[:, None]).astype(np.float32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "loss_mask": loss_mask,
        }


@dataclasses.dataclass
class AlignmentCorpus:
    """General-corpus stand-in for the one-shot alignment stage (L_A):
    plain causal LM over every position."""

    vocab: int
    seq_len: int
    seed: int = 100

    def batch(self, step: int, host: int = 0, n_hosts: int = 1,
              batch_size: int = 8) -> Dict[str, np.ndarray]:
        rng = index_for(step, host, n_hosts, self.seed)
        toks = _zipf_tokens(rng, (batch_size, self.seq_len + 1), self.vocab)
        toks = _add_ngram_structure(rng, toks, self.vocab)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def batch_iterator(ds, *, batch_size: int, start_step: int = 0,
                   host: int = 0, n_hosts: int = 1,
                   frontend_shape: Optional[tuple] = None) -> Iterator:
    """Infinite deterministic iterator from ``start_step`` (resume-safe)."""
    step = start_step
    while True:
        b = ds.batch(step, host, n_hosts, batch_size)
        if frontend_shape is not None:
            rng = index_for(step, host + 10_000, n_hosts, ds.seed)
            b["frontend"] = rng.standard_normal(
                (batch_size,) + frontend_shape).astype(np.float32) * 0.02
        yield b
        step += 1

"""NF4 (NormalFloat-4) blockwise quantization — the Q(·) of QLoRAM.

Faithful to QLoRA (Dettmers et al., 2023): the 16 NF4 levels are the
quantiles of N(0,1) normalised to [-1, 1]; weights are scaled per block of
``block_size`` elements by the block absmax.  Optional double quantization
compresses the per-block scales with an int8 secondary quantizer.

TPU adaptation: codes are packed two-per-byte along the *input* (contraction)
axis so a (128, 128) MXU tile dequantizes from a contiguous (64, 128) uint8
VMEM tile — see ``repro/kernels/nf4_matmul.py`` for the fused kernel; this
module is the reference/storage layer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# The canonical NF4 codebook (QLoRA appendix E) — quantiles of a standard
# normal, symmetrised, with an exact zero.
NF4_CODEBOOK = np.array(
    [
        -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
        -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
        0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
        0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
        0.7229568362236023, 1.0,
    ],
    dtype=np.float32,
)

DEFAULT_BLOCK = 64


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DQScales:
    """Double-quantized per-block scales (QLoRA §3): every block's absmax is
    itself int8-quantized per group of ``group`` blocks, with one fp32
    second-level absmax per group.  Rides in :attr:`QTensor.scales` wherever
    a plain scales array would."""

    codes: jax.Array          # int8 (n_blocks, d_out)
    absmax: jax.Array         # fp32 (ceil(n_blocks / group), d_out)
    group: int

    def tree_flatten(self):
        return (self.codes, self.absmax), (self.group,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def dtype(self):
        return self.absmax.dtype

    @property
    def shape(self):
        return self.codes.shape

    @property
    def nbytes(self) -> int:
        return int(self.codes.size
                   + self.absmax.size * self.absmax.dtype.itemsize)


def _scales_f32(scales) -> jax.Array:
    """Per-block fp32 scales from either storage form."""
    if isinstance(scales, DQScales):
        nb = scales.codes.shape[-2]
        meta = jnp.repeat(scales.absmax.astype(jnp.float32) / 127.0,
                          scales.group, axis=-2)[..., :nb, :]
        return scales.codes.astype(jnp.float32) * meta
    return scales.astype(jnp.float32)


def quantize_scales(scales: jax.Array, group: int = 256) -> DQScales:
    """Double quantization of a (n_blocks, d_out) absmax-scales array."""
    nb, d_out = scales.shape
    ng = -(-nb // group)
    sf = scales.astype(jnp.float32)
    pad = ng * group - nb
    if pad:
        sf = jnp.concatenate([sf, jnp.zeros((pad, d_out), jnp.float32)])
    sf = sf.reshape(ng, group, d_out)
    meta = jnp.maximum(jnp.max(jnp.abs(sf), axis=1), 1e-12)       # (ng, d_out)
    codes = jnp.clip(jnp.round(sf / (meta[:, None, :] / 127.0)), -127, 127)
    codes = codes.reshape(ng * group, d_out)[:nb].astype(jnp.int8)
    return DQScales(codes, meta, group)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Packed NF4 tensor.  Logical shape (d_in, d_out); codes packed on d_in."""

    codes: jax.Array          # uint8 (d_in // 2, d_out), two 4-bit codes/byte
    scales: jax.Array         # fp16/fp32 (ceil(d_in / block), d_out) absmax
                              # per block — or a DQScales (double quantized)
    shape: tuple              # logical (d_in, d_out)
    block: int

    def tree_flatten(self):
        return (self.codes, self.scales), (self.shape, self.block)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scales = children
        return cls(codes, scales, aux[0], aux[1])

    @property
    def dtype(self):
        # duck-types jnp arrays for repro.models.layers.dense and the
        # sharding-spec inference: the natural carrier dtype of the
        # dequantized values is the stored scale dtype (codebook values are
        # exact in fp32; the scales bound the precision), NOT a hard-coded
        # bfloat16 — a float32-scaled QTensor dequantizes losslessly to f32.
        return jnp.dtype(self.scales.dtype)

    @property
    def nbytes_logical(self) -> int:
        sc = self.scales
        sc_bytes = (sc.nbytes if isinstance(sc, DQScales)
                    else int(np.prod(sc.shape)) * sc.dtype.itemsize)
        return int(np.prod(self.shape)) // 2 + int(sc_bytes)


def _codebook(dtype=jnp.float32):
    return jnp.asarray(NF4_CODEBOOK, dtype)


def quantize(w: jax.Array, block: int = DEFAULT_BLOCK,
             scale_dtype=jnp.float16, double_quant: bool = False) -> QTensor:
    """Quantize (d_in, d_out) weights to NF4, blocked along d_in.

    ``d_in`` need not be a multiple of ``block``: a trailing partial block
    carries its own absmax like any full block (codes still pack 2/byte, so
    ``d_in`` must stay even).  ``double_quant=True`` int8-compresses the
    per-block scales themselves (:class:`DQScales`)."""
    d_in, d_out = w.shape
    assert d_in % 2 == 0, (w.shape, block)
    nb = -(-d_in // block)
    wf = w.astype(jnp.float32)
    pad = nb * block - d_in
    if pad:
        wf = jnp.concatenate([wf, jnp.zeros((pad, d_out), jnp.float32)])
    wf = wf.reshape(nb, block, d_out)
    absmax = jnp.max(jnp.abs(wf), axis=1, keepdims=True)
    absmax = jnp.maximum(absmax, 1e-12)
    normed = wf / absmax                                          # in [-1, 1]
    # nearest codebook entry
    dist = jnp.abs(normed[..., None] - _codebook()[None, None, None, :])
    codes = jnp.argmin(dist, axis=-1).astype(jnp.uint8)           # (nb, block, d_out)
    codes = codes.reshape(nb * block, d_out)[:d_in]
    packed = (codes[0::2, :] | (codes[1::2, :] << 4)).astype(jnp.uint8)
    scales = absmax[:, 0, :]                                      # (nb, d_out)
    scales = (quantize_scales(scales) if double_quant
              else scales.astype(scale_dtype))
    return QTensor(packed, scales, (d_in, d_out), block)


def dequantize(q: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    # note: lax.scan slices the leading (layer) axis of stacked QTensors while
    # leaving aux ``shape`` untouched — always derive dims from the codes.
    # Scope name is load-bearing: hlo_analysis projects the fused
    # nf4_matmul Pallas kernel (dequant stays in VMEM) over this traffic.
    with jax.named_scope("nf4_dequant"):
        d_in, d_out = q.codes.shape[0] * 2, q.codes.shape[1]
        lo = (q.codes & 0x0F).astype(jnp.int32)
        hi = (q.codes >> 4).astype(jnp.int32)
        codes = jnp.stack([lo, hi], axis=1).reshape(d_in, d_out)  # interleave rows
        vals = _codebook()[codes]                                 # (d_in, d_out) f32
        nb = -(-d_in // q.block)
        pad = nb * q.block - d_in
        if pad:
            vals = jnp.concatenate(
                [vals, jnp.zeros((pad, d_out), jnp.float32)])
        vals = vals.reshape(nb, q.block, d_out)
        vals = vals * _scales_f32(q.scales)[:, None, :]
        return vals.reshape(nb * q.block, d_out)[:d_in].astype(dtype)


def quantize_tree(params, block: int = DEFAULT_BLOCK, min_size: int = 4096,
                  predicate=None):
    """NF4-quantize every eligible 2-D weight in a pytree (frozen base only).

    predicate(path, leaf) → bool decides eligibility; default: 2-D, both dims
    even/blocked, and ≥ min_size elements (skips norms, biases, codebooks).
    """
    def default_pred(path, leaf):
        return (
            isinstance(leaf, jax.Array)
            and leaf.ndim == 2
            and leaf.size >= min_size
            and leaf.shape[0] % block == 0
        )

    pred = predicate or default_pred

    def visit(path, leaf):
        if pred(path, leaf):
            return quantize(leaf, block=block)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def quantize_stacked(w: jax.Array, block: int = DEFAULT_BLOCK,
                     scale_dtype=jnp.float16) -> "QTensor":
    """Quantize (..., d_in, d_out) stacked weights (scan layers and/or MoE
    experts) — vmapped over all leading dims."""
    assert w.ndim >= 3
    lead = w.shape[:-2]
    d_in, d_out = w.shape[-2:]
    flat = w.reshape((-1, d_in, d_out))

    def q1(wi):
        t = quantize(wi, block=block, scale_dtype=scale_dtype)
        return t.codes, t.scales

    codes, scales = jax.vmap(q1)(flat)
    codes = codes.reshape(lead + codes.shape[1:])
    scales = scales.reshape(lead + scales.shape[1:])
    return QTensor(codes, scales, tuple(w.shape), block)


def dequantize_stacked(q: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    d_in, d_out = q.codes.shape[-2] * 2, q.codes.shape[-1]
    lead = q.codes.shape[:-2]
    flat_c = q.codes.reshape((-1,) + q.codes.shape[-2:])
    flat_s = q.scales.reshape((-1,) + q.scales.shape[-2:])

    def d1(codes, scales):
        return dequantize(QTensor(codes, scales, (d_in, d_out), q.block), dtype)

    out = jax.vmap(d1)(flat_c, flat_s)
    return out.reshape(lead + (d_in, d_out))


def maybe_dequant(w, dtype=jnp.bfloat16):
    """Transparent accessor used by call sites that matmul raw weight arrays
    (e.g. stacked MoE experts)."""
    if isinstance(w, QTensor):
        return dequantize_stacked(w, dtype) if w.codes.ndim >= 3 else dequantize(w, dtype)
    return w


# frozen-base projection names the serving QuantPolicy targets by default:
# attention + FFN matmuls (the storage/bandwidth bill); embeddings, norms,
# routers, SSM mixers and LoRA banks stay fp (LoRA's design point,
# arXiv:2106.09685)
SERVING_QUANT_TARGETS = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")


def _path_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "name", last)))


def quantize_by_name(params, targets=SERVING_QUANT_TARGETS,
                     block: int = DEFAULT_BLOCK, scale_dtype=jnp.float16):
    """NF4-quantize every pytree leaf whose dict key is in ``targets`` —
    the engine-load step behind ``ServeConfig.quant.weights == "nf4"``.
    Stacked (≥3-D) stage weights quantize per layer slice; leaves whose
    contraction dim is not block-aligned (or odd) stay fp."""
    def visit(path, leaf):
        if not isinstance(leaf, jax.Array) or leaf.ndim < 2:
            return leaf
        if _path_name(path) not in targets:
            return leaf
        d_in = leaf.shape[-2]
        if d_in % block or d_in % 2:
            return leaf
        if leaf.ndim >= 3:
            return quantize_stacked(leaf, block=block, scale_dtype=scale_dtype)
        return quantize(leaf, block=block, scale_dtype=scale_dtype)

    return jax.tree_util.tree_map_with_path(visit, params)


def param_bytes(tree) -> int:
    """Physical parameter storage in bytes (QTensors counted packed)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            sc = leaf.scales
            total += leaf.codes.size + (
                sc.nbytes if isinstance(sc, DQScales)
                else sc.size * sc.dtype.itemsize)
        elif hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total


def param_bytes_logical(tree, itemsize: int = 4) -> int:
    """What the same pytree would occupy unquantized (QTensors counted at
    their logical fp shape × ``itemsize``) — the numerator of the packed
    storage-reduction ratio in BENCH_serving.json."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += 2 * leaf.codes.size * itemsize
        elif hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total

from repro.quant import kv, nf4  # noqa: F401

from repro.quant import nf4  # noqa: F401

"""Symmetric int8 row quantization for the paged KV pool.

``ServeConfig.quant.kv == "int8"`` stores the attention page pool as int8
codes plus one absmax scale per (page row, token, kv-head) — the scales ride
in the cache dict beside the pool under ``"k_sc"`` / ``"v_sc"`` with the
head dim collapsed to 1, so every scatter site (prefill, chunk commit,
decode write, speculative commit/rollback, COW page copy) indexes codes and
scales identically.

Per-ROW scales (not per-page) are the load-bearing choice: every writer —
a single decode token, a verify commit of γ rows, a prefill chunk — can
quantize its own rows locally without reading back what else lives on the
page, so quantize-on-commit stays a pure scatter and the engine's
determinism argument (same fp row → same codes, wherever it was written
from) survives preemption/re-run and COW forks.

Dequantization happens in-kernel (``repro.kernels.paged_attention`` reads
the codes and scales per page) or at the gather sites (`ref.py` oracles,
the verify branch) via :func:`dequantize_rows` — one shared definition, so
every reader reconstructs bit-identical values.
"""
from __future__ import annotations

import jax.numpy as jnp

# int8 symmetric range; 127 keeps the code space symmetric around the exact
# zero (-128 is never emitted)
KV_LEVELS = 127.0

# fp32 scales: the pool is the bandwidth bill, the scales are 1/hd of it —
# spending 4 bytes per row keeps the commit→read round trip exact
KV_SCALE_DTYPE = jnp.float32


def quantize_rows(x, scale_dtype=KV_SCALE_DTYPE):
    """(..., hd) fp rows → (int8 codes (..., hd), scales (..., 1)).

    Deterministic: every scatter site quantizes through this one function,
    so a row holds the same codes no matter which path wrote it."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scales = jnp.maximum(absmax, 1e-12) / KV_LEVELS
    codes = jnp.clip(jnp.round(xf / scales), -KV_LEVELS, KV_LEVELS)
    return codes.astype(jnp.int8), scales.astype(scale_dtype)


def dequantize_rows(codes, scales, dtype=jnp.float32):
    """Inverse of :func:`quantize_rows` (scales broadcast over the head
    dim) — the single reconstruction every reader shares."""
    return (codes.astype(jnp.float32)
            * scales.astype(jnp.float32)).astype(dtype)


def quant_cache_keys(bc) -> bool:
    """Does this per-block cache dict hold a quantized attention pool?"""
    return "k_sc" in bc

"""jit-able train / prefill / decode steps shared by the Trainer, the serving
engine and the multi-pod dry-run.

``make_train_step`` implements the LoRAM online stage: frozen (pruned,
possibly NF4) base, trainable adapters only, gradient accumulation over
microbatches via ``lax.scan`` (XLA overlaps microbatch k+1 compute with
microbatch k collectives), AdamW on the adapter tree, warmup-cosine LR.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LoRAConfig, TrainConfig
from repro.core.objectives import sft_loss
from repro.models.model import (Plan, decode_step as model_decode, forward,
                                paged_pos_to_page, prefill as model_prefill,
                                prefill_chunk as model_prefill_chunk,
                                ring_pages, verify_step as model_verify)
from repro.optim.adamw import AdamWState, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.quant import kv as qkv


def make_train_step(
    plan: Plan,
    train_cfg: TrainConfig,
    lora_cfg: LoRAConfig,
    *,
    n_micro: int = 1,
    grad_transform: Optional[Callable] = None,
) -> Callable:
    """Returns train_step(base_params, lora, opt_state, step, batch) →
    (lora, opt_state, metrics)."""

    def train_step(base_params, lora, opt_state: AdamWState, step, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B = tokens.shape[0]
        assert B % n_micro == 0, (B, n_micro)
        mb = B // n_micro

        def reshape_micro(x):
            return x.reshape((n_micro, mb) + x.shape[1:])

        micro = jax.tree.map(reshape_micro, batch)

        def loss_fn(l, microbatch):
            loss, (ce, aux) = sft_loss(
                plan, base_params, l, microbatch,
                lora_scale=lora_cfg.scale, remat=train_cfg.remat)
            return loss, ce

        def acc_body(carry, microbatch):
            g_acc, loss_acc, ce_acc = carry
            (loss, ce), g = jax.value_and_grad(loss_fn, has_aux=True)(lora, microbatch)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, loss_acc + loss, ce_acc + ce), ()

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), lora)
        (grads, loss_sum, ce_sum), _ = lax.scan(
            acc_body, (g0, jnp.zeros(()), jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        if grad_transform is not None:
            grads = grad_transform(grads)

        lr = warmup_cosine(step, peak_lr=train_cfg.learning_rate,
                           warmup_steps=train_cfg.warmup_steps,
                           total_steps=train_cfg.total_steps)
        new_lora, new_opt = adamw_update(
            lora, grads, opt_state, lr=lr, wd=train_cfg.weight_decay,
            clip=train_cfg.grad_clip)
        metrics = {"loss": loss_sum / n_micro, "ce": ce_sum / n_micro, "lr": lr,
                   "step": step}
        return new_lora, new_opt, metrics

    return train_step


def make_eval_step(plan: Plan, lora_cfg: LoRAConfig) -> Callable:
    def eval_step(base_params, lora, batch):
        loss, (ce, aux) = sft_loss(plan, base_params, lora, batch,
                                   lora_scale=lora_cfg.scale, remat=False)
        return {"loss": loss, "ce": ce, "ppl": jnp.exp(ce)}

    return eval_step


def make_prefill_step(plan: Plan, *, lora_scale: float = 2.0,
                      with_lora: bool = False) -> Callable:
    """serve-side prefill: (params[, lora], tokens, cache[, frontend])."""

    if with_lora:
        def step(params, lora, tokens, cache, frontend=None):
            return model_prefill(plan, params, tokens, cache, lora,
                                 frontend=frontend, lora_scale=lora_scale)
    else:
        def step(params, tokens, cache, frontend=None):
            return model_prefill(plan, params, tokens, cache, None,
                                 frontend=frontend)
    return step


def make_decode_step(plan: Plan, *, lora_scale: float = 2.0,
                     with_lora: bool = False) -> Callable:
    """serve_step: one new token for every sequence in the batch, against a
    KV/SSM cache of the configured length."""

    if with_lora:
        def step(params, lora, token, cache, pos):
            return model_decode(plan, params, token, cache, pos, lora,
                                lora_scale=lora_scale)
    else:
        def step(params, token, cache, pos):
            return model_decode(plan, params, token, cache, pos, None)
    return step


# ---------------------------------------------------------------------------
# continuous-batching serve steps
# ---------------------------------------------------------------------------

def make_multi_adapter_decode_step(plan: Plan, *, lora_scale: float = 2.0,
                                   paged: bool = False) -> Callable:
    """One token for every *slot*: per-slot positions (each sequence sits at
    its own depth) and per-slot ``adapter_ids`` routed through a stacked
    adapter bank (see repro.serving.adapters).  ``paged=True`` builds the
    paged-cache variant, which additionally takes the per-slot block table
    (see repro.serving.pages)."""

    if paged:
        def step(params, bank, token, cache, pos, adapter_ids, block_table):
            return model_decode(plan, params, token, cache, pos, bank,
                                lora_scale=lora_scale, adapter_ids=adapter_ids,
                                block_table=block_table)
    else:
        def step(params, bank, token, cache, pos, adapter_ids):
            return model_decode(plan, params, token, cache, pos, bank,
                                lora_scale=lora_scale, adapter_ids=adapter_ids)

    return step


def _zeros_row(c):
    return jnp.zeros(c.shape[:1] + (1,) + c.shape[2:], c.dtype)


def _write_row(big, small, slot):
    return lax.dynamic_update_slice_in_dim(big, small.astype(big.dtype),
                                           slot, axis=1)


def make_prefill_into_slot(plan: Plan, *, lora_scale: float = 2.0,
                           bucketed: bool = False) -> Callable:
    """Prefill ONE request directly into slot ``slot`` of a live multi-slot
    cache while other slots keep decoding unchanged.

    The slot's cache row starts from zeros (a freed slot may hold the previous
    occupant's KV / SSM state — stale SSM state would corrupt the recurrence)
    and is written back with ``dynamic_update_slice`` along the batch axis, so
    the jitted computation is reused for every slot index.

    ``bucketed=True`` adds a trailing ``valid_len`` argument: ``tokens`` is
    the prompt right-padded to a power-of-two bucket and only the first
    ``valid_len`` positions are real (see ``repro.serving.pages.bucket_len``)
    — the step then compiles once per BUCKET instead of once per distinct
    prompt length.
    """

    if bucketed:
        def step(params, lora, tokens, big_cache, slot, valid_len):
            row = jax.tree.map(_zeros_row, big_cache)
            logits, row, _ = model_prefill(plan, params, tokens, row, lora,
                                           lora_scale=lora_scale,
                                           valid_len=valid_len)
            new_cache = jax.tree.map(
                lambda b, s: _write_row(b, s, slot), big_cache, row)
            return logits, new_cache
    else:
        def step(params, lora, tokens, big_cache, slot):
            # tokens: (1, S_prompt); slot: scalar int32
            row = jax.tree.map(_zeros_row, big_cache)
            logits, row, _ = model_prefill(plan, params, tokens, row, lora,
                                           lora_scale=lora_scale)
            new_cache = jax.tree.map(
                lambda b, s: _write_row(b, s, slot), big_cache, row)
            return logits, new_cache

    return step


def make_paged_prefill_into_slot(plan: Plan, bucket: int, page_size: int,
                                 n_tbl: int, *,
                                 lora_scale: float = 2.0) -> Callable:
    """Prefill ONE request into the PAGED cache: run the (bucketed) prompt
    through a dense scratch row, then scatter the row's pages into the pool
    slots named by ``pids`` — the slot's freshly allocated block-table
    entries.  Attention rows are sized to the bucket (windowed layers: to
    their bounded ring), so scratch memory is O(bucket), not O(max_seq_len);
    recurrent (SSM) state stays dense per slot and is written back with the
    same ``dynamic_update_slice`` the dense path uses.  Compiled once per
    bucket."""
    assert bucket % page_size == 0, (bucket, page_size)
    for st in plan.stages:
        for spec in st.superblock:
            if spec.kind == "cross_attn":
                raise NotImplementedError(
                    "paged serving does not cover encoder-decoder frontends")

    def step(params, lora, tokens, cache, pids, slot, valid_len):
        # tokens: (1, bucket); pids: (bucket//page_size,) pool page ids;
        # slot, valid_len: scalars
        row = {}
        for st in plan.stages:
            st_row = {}
            for spec in st.superblock:
                bc = cache[st.name].get(spec.name)
                if bc is None:
                    continue
                if spec.kind == "attn":
                    rowlen = min(
                        bucket,
                        ring_pages(spec.window, n_tbl, page_size) * page_size)
                    # the scratch row always runs fp — int8 pools quantize
                    # at the page scatter below (quantize-on-commit)
                    row_dt = (jnp.float32 if qkv.quant_cache_keys(bc)
                              else bc["k"].dtype)
                    st_row[spec.name] = {
                        n: jnp.zeros((st.n_rep, 1, rowlen) + bc[n].shape[3:],
                                     row_dt)
                        for n in ("k", "v")
                    }
                else:                                  # mamba: dense per slot
                    st_row[spec.name] = jax.tree.map(_zeros_row, bc)
            row[st.name] = st_row

        logits, row, _ = model_prefill(plan, params, tokens, row, lora,
                                       lora_scale=lora_scale,
                                       valid_len=valid_len)

        new_cache = {}
        for st in plan.stages:
            st_new = {}
            for spec in st.superblock:
                bc = cache[st.name].get(spec.name)
                if bc is None:
                    continue
                rowc = row[st.name][spec.name]
                if spec.kind == "attn":
                    rown = rowc["k"].shape[2] // page_size
                    if qkv.quant_cache_keys(bc):
                        # quantize-on-commit: code each row through the one
                        # shared quantizer and land codes + per-row scales
                        # on the same pages
                        ent = {}
                        for n in ("k", "v"):
                            vals = rowc[n].reshape(
                                (bc[n].shape[0], rown) + bc[n].shape[2:])
                            codes, sc = qkv.quantize_rows(vals)
                            ent[n] = bc[n].at[:, pids[:rown]].set(codes)
                            ent[n + "_sc"] = bc[n + "_sc"].at[
                                :, pids[:rown]].set(
                                    sc.astype(bc[n + "_sc"].dtype))
                        st_new[spec.name] = ent
                    else:
                        st_new[spec.name] = {
                            n: bc[n].at[:, pids[:rown]].set(
                                rowc[n].reshape(
                                    (bc[n].shape[0], rown) + bc[n].shape[2:]
                                ).astype(bc[n].dtype))
                            for n in ("k", "v")
                        }
                else:
                    st_new[spec.name] = jax.tree.map(
                        lambda b, s: _write_row(b, s, slot), bc, rowc)
            new_cache[st.name] = st_new
        return logits, new_cache

    return step


def make_paged_prefill_chunk(plan: Plan, chunk_len: int, page_size: int,
                             n_tbl: int, *, lora_scale: float = 2.0) -> Callable:
    """Prefill ONE chunk of one request's prompt into the PAGED cache:
    ``tokens`` (1, chunk_len) at absolute positions ``pos0 .. pos0+valid-1``
    run through :func:`repro.models.model.prefill_chunk` — attention reads
    the slot's already-committed pages via ``table_row``, the chunk's
    pending K/V rows scatter into the pages backing those positions
    (per-layer ring mapping for windowed blocks, last-writer-wins when a
    chunk wraps a bounded ring).  Compiled once per chunk length; a fixed
    ``prefill_chunk`` therefore compiles exactly ONE prefill variant no
    matter the prompt-length mix.

    Recurrent (SSM/conv) state rides OUTSIDE the engine's big cache while
    a prompt is streaming in: the decode tick (and the speculative draft
    loop) advances every slot's dense state each step — free and
    prefilling slots included — so a half-prefilled slot's row in the
    shared cache would be garbage by its next chunk.  ``state`` (this
    slot's rows, zeros before the first chunk) is an explicit operand and
    the updated rows come back as the third result; the engine keeps them
    aside and writes them into the cache only at activation
    (:func:`make_state_ops`' restore).  Attention needs no such shield: a
    prefilling slot's device block-table row stays all-zero, so tick
    garbage lands on the trash page while the chunk dispatches carry the
    real row as an operand."""
    for st in plan.stages:
        for spec in st.superblock:
            if spec.kind == "cross_attn":
                raise NotImplementedError(
                    "paged serving does not cover encoder-decoder frontends")
    windows = attn_window_map(plan)

    def step(params, lora, tokens, cache, state, table_row, pos0, valid):
        # tokens: (1, chunk_len); state: {stage: {block: {conv, ssm}}} rows
        # (empty for attention-only plans); table_row: (1, n_tbl) int32
        # pool page ids; pos0 / valid: scalars
        view = {}
        for st in plan.stages:
            st_view = {}
            for spec in st.superblock:
                bc = cache[st.name].get(spec.name)
                if bc is None:
                    continue
                if spec.kind == "attn":
                    st_view[spec.name] = bc        # pool, read via the table
                else:                              # mamba: side-channel rows
                    st_view[spec.name] = state[st.name][spec.name]
            view[st.name] = st_view

        logits, out = model_prefill_chunk(
            plan, params, tokens, view, pos0, table_row, lora,
            lora_scale=lora_scale, valid_len=valid)

        pos0 = jnp.asarray(pos0, jnp.int32)
        valid = jnp.asarray(valid, jnp.int32)
        j = jnp.arange(chunk_len)
        new_cache = {}
        new_state = {}
        for st in plan.stages:
            st_new = {}
            for spec in st.superblock:
                bc = cache[st.name].get(spec.name)
                if bc is None:
                    continue
                oc = out[st.name][spec.name]
                if spec.kind == "attn":
                    ring_len = ring_pages(spec.window, n_tbl,
                                          page_size) * page_size
                    ridx = (pos0 + j) % ring_len
                    keep = j < valid
                    if spec.window:
                        # a chunk longer than a bounded ring writes some ring
                        # slots more than once — keep only the LAST writer
                        # per slot (scatter winners are implementation-
                        # defined otherwise)
                        keep = keep & (j >= valid - ring_len)
                    pg = table_row[0, ridx // page_size]
                    off = ridx % page_size
                    # masked rows go OUT OF BOUNDS and drop — same scatter
                    # discipline as the speculative paged commit
                    pg_w = jnp.where(keep, pg, bc["k"].shape[1])
                    if qkv.quant_cache_keys(bc):
                        ent = {}
                        for n in ("k", "v"):
                            codes, sc = qkv.quantize_rows(oc[n][:, 0])
                            ent[n] = bc[n].at[:, pg_w, off].set(
                                codes, mode="drop")
                            ent[n + "_sc"] = bc[n + "_sc"].at[
                                :, pg_w, off].set(
                                    sc.astype(bc[n + "_sc"].dtype),
                                    mode="drop")
                        st_new[spec.name] = ent
                    else:
                        st_new[spec.name] = {
                            n: bc[n].at[:, pg_w, off].set(
                                oc[n][:, 0].astype(bc[n].dtype), mode="drop")
                            for n in ("k", "v")
                        }
                else:
                    # recurrent rows stay in the side channel until the
                    # engine activates the slot
                    st_new[spec.name] = bc
                    new_state.setdefault(st.name, {})[spec.name] = oc
            new_cache[st.name] = st_new
        return logits, new_cache, new_state

    return step


def make_state_ops(plan: Plan):
    """(capture, restore) jitted ops over a slot's dense recurrent rows —
    what a shared-prefix cache entry snapshots at the prefix boundary and
    clones into every sharer's slot at admission.  Returns (None, None) for
    plans with no recurrent blocks (attention needs no state beyond its
    pages)."""
    specs = [(st.name, spec.name) for st in plan.stages
             for spec in st.superblock if spec.kind == "mamba"]
    if not specs:
        return None, None

    def capture(cache, slot):
        return {stn: {bn: {n: lax.dynamic_slice_in_dim(
                               cache[stn][bn][n], slot, 1, axis=1)
                           for n in ("conv", "ssm")}
                      for s2, bn in specs if s2 == stn}
                for stn in {s for s, _ in specs}}

    def restore(cache, state, slot):
        new = {stn: dict(stc) for stn, stc in cache.items()}
        for stn, bn in specs:
            new[stn][bn] = {
                n: _write_row(cache[stn][bn][n], state[stn][bn][n], slot)
                for n in ("conv", "ssm")
            }
        return new

    return jax.jit(capture), jax.jit(restore, donate_argnums=(0,))


def make_copy_page(plan: Plan) -> Callable:
    """Jitted copy-on-write page fork: clone pool page ``src`` into ``dst``
    across every attention layer's K/V pools (one block table serves all
    layers, so a forked page id must be backed in each of them)."""
    attn = [(st.name, spec.name) for st in plan.stages
            for spec in st.superblock if spec.kind == "attn"]

    def copy(cache, src, dst):
        new = {stn: dict(stc) for stn, stc in cache.items()}
        for stn, bn in attn:
            bc = cache[stn][bn]
            # int8 pools fork codes AND scales — a byte-for-byte page copy,
            # so COW sharers reconstruct identical values
            new[stn][bn] = {n: bc[n].at[:, dst].set(bc[n][:, src])
                            for n in bc}
        return new

    return jax.jit(copy, donate_argnums=(0,))


def admit_update(st, slot, first, pos0, aid, temp, seed, max_new, use_spec):
    """THE fused per-admission tick-state update, shared by every engine.

    ``st`` is a :class:`repro.serving.tickstate.TickState`; one jitted
    dispatch flips the slot live instead of eight ``.at[].set`` round trips.
    The speculative fields (``spec``, ``max_new``) update only when the state
    CARRIES them (``st.spec is not None`` — a trace-time branch, so the plain
    engine's compiled admission never touches the extra operands it is
    handed).  Jit with ``donate_argnums=(0,)``."""
    kw = dict(
        last_tok=st.last_tok.at[slot].set(first),
        pos=st.pos.at[slot].set(pos0),
        active=st.active.at[slot].set(True),
        adapter_ids=st.adapter_ids.at[slot].set(aid),
        temps=st.temps.at[slot].set(temp),
        seeds=st.seeds.at[slot].set(seed),
        gen_idx=st.gen_idx.at[slot].set(1),
        out_buf=st.out_buf.at[slot, 0].set(first),
    )
    if st.spec is not None:
        kw["spec"] = st.spec.at[slot].set(use_spec)
        kw["max_new"] = st.max_new.at[slot].set(max_new)
    return st.replace(**kw)


# ---------------------------------------------------------------------------
# speculative-decoding serve steps (draft propose + target verify)
# ---------------------------------------------------------------------------

def request_key(seed, gen_idx, tag: Optional[int] = None):
    """THE per-request PRNG key derivation, shared by every sampling site.

    ``fold_in(PRNGKey(seed), gen_idx)`` is the key the plain engine uses for
    the token at absolute generation index ``gen_idx``; speculative streams
    fold in a ``tag`` on top (1 = draft proposal, 2 = accept draw,
    3 = residual sample).  The spec engine's plain-slot bit-identity with
    :class:`~repro.serving.engine.ContinuousServeEngine` depends on all
    call sites deriving keys through this one function — do not inline it.
    """
    k = jax.random.fold_in(jax.random.PRNGKey(seed), gen_idx)
    return k if tag is None else jax.random.fold_in(k, tag)


def make_verify_step(plan: Plan, *, lora_scale: float = 2.0,
                     paged: bool = False) -> Callable:
    """Length-γ target verify for speculative decoding: per-slot token blocks
    ``(B, γ)`` at per-slot positions through ONE forward.  Returns
    ``(logits (B, γ, V), pending)`` — the persistent cache is untouched;
    ``repro.serving.speculative.commit_cache`` scatters the accepted prefix
    (see models.model.verify_step).  The paged variant reads the cache
    through the block table; ``pending`` is identical either way (the commit
    decides where the rows land)."""

    if paged:
        def step(params, bank, tokens, cache, pos, adapter_ids, block_table):
            return model_verify(plan, params, tokens, cache, pos, bank,
                                lora_scale=lora_scale, adapter_ids=adapter_ids,
                                block_table=block_table)
    else:
        def step(params, bank, tokens, cache, pos, adapter_ids):
            return model_verify(plan, params, tokens, cache, pos, bank,
                                lora_scale=lora_scale, adapter_ids=adapter_ids)

    return step


def attn_window_map(plan: Plan) -> dict:
    """{stage name: {block name: window}} for the plan's attention blocks —
    the paged speculative commit/rollback helpers need to know which pooled
    caches are bounded rings (window > 0) and which are position-linear."""
    return {st.name: {b.name: b.window for b in st.superblock
                      if b.kind == "attn"}
            for st in plan.stages}


def make_draft_loop(plan: Plan, gamma: int, *, lora_scale: float = 2.0,
                    full_len: int = 0, sampling: bool = True) -> Callable:
    """γ-step draft-proposal loop (the "train small" model as proposer).

    One ``lax.scan`` of single-token decode steps — a single dispatch per
    round no matter γ.  Step j consumes the previous token at per-slot
    position ``pos + j`` and proposes the next; sampling slots draw from the
    draft distribution at the request temperature with a key derived from
    ``(seed, absolute generation index)`` so proposals are independent of
    scheduling.  Returns ``(cache, drafts (γ, B), qs (γ, B, V), undo)`` where
    ``cache`` contains the loop's (uncommitted) writes and ``undo`` carries
    what the engine needs to roll back rejected tokens (see
    repro.serving.speculative.commit_draft_cache): per-step conv/SSM
    snapshots for mamba blocks, pre-write K/V rows for WINDOWED attention
    blocks.  Full-length attention caches (``cache size == full_len``, the
    engine's max_seq_len) need no rollback — a slot index equals its
    position, so writes past the accept boundary are masked by the position
    check and overwritten in order as decoding resumes — and are skipped
    entirely, which keeps the rollback bookkeeping off the dense-model hot
    path.  ``full_len=0`` conservatively tracks every attention block.
    (In the final γ tokens of a near-max_seq_len request the loop's writes
    can wrap past the cache end and clobber early DRAFT rows; that only
    lowers acceptance for that tail — the verify pass owns correctness.)

    ``sampling=False`` builds the all-greedy variant: proposals are pure
    argmax and the per-step draft distributions are not materialized (qs is
    returned as None) — the same greedy/sampled split the plain engine's
    decode tick uses.  (:func:`make_paged_draft_loop` is the paged-cache
    sibling.)
    """
    return _make_draft_loop(plan, gamma, lora_scale=lora_scale,
                            full_len=full_len, sampling=sampling)


def make_paged_draft_loop(plan: Plan, gamma: int, page_size: int, n_tbl: int,
                          *, lora_scale: float = 2.0,
                          sampling: bool = True) -> Callable:
    """Paged-cache variant of :func:`make_draft_loop`: same contract, but the
    loop takes a trailing ``block_table`` and saves rollback rows only for
    windowed attention blocks (bounded rings wrap and can clobber rows the
    accept boundary still needs; position-linear pooled caches never wrap
    within a request, so their stale writes are masked and overwritten in
    order — same argument as the dense full-length fast path)."""
    decode = make_multi_adapter_decode_step(plan, lora_scale=lora_scale,
                                            paged=True)
    windows = attn_window_map(plan)

    def loop(params, bank, cache, last_tok, pos, adapter_ids, temps, seeds,
             gen_idx, block_table):
        temp = jnp.maximum(temps, 1e-6)[:, None]

        def keys_at(idx, tag):
            return jax.vmap(lambda s, i: request_key(s, i, tag))(seeds, idx)

        def body(carry, j):
            dc, tok = carry
            pre = {}
            for stn, stc in dc.items():
                for bn, bc in stc.items():
                    if "k" in bc and windows[stn][bn]:
                        pg, off = paged_pos_to_page(
                            block_table, pos + j, windows[stn][bn], page_size)
                        # int8 pools snapshot scales beside codes — rollback
                        # restores the row byte-for-byte
                        pre.setdefault(stn, {})[bn] = {
                            n: bc[n][:, pg, off] for n in bc}
            logits, dc = decode(params, bank, tok, dc, pos + j, adapter_ids,
                                block_table)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            if sampling:
                keys = keys_at(gen_idx + j, 1)
                sampled = jax.vmap(jax.random.categorical)(
                    keys, logits / temp).astype(jnp.int32)
                nxt = jnp.where(temps > 0.0, sampled, nxt)
            undo = {}
            for stn, stc in dc.items():
                undo[stn] = {}
                for bn, bc in stc.items():
                    if "k" in bc:
                        if stn in pre and bn in pre[stn]:
                            undo[stn][bn] = pre[stn][bn]
                    else:                              # mamba: post-step state
                        undo[stn][bn] = {"conv": bc["conv"], "ssm": bc["ssm"]}
            if sampling:
                q = jax.nn.softmax(logits / temp, axis=-1)
                return (dc, nxt), (nxt, q, undo)
            return (dc, nxt), (nxt, undo)

        if sampling:
            (cache, _), (drafts, qs, undo) = lax.scan(
                body, (cache, last_tok), jnp.arange(gamma))
        else:
            (cache, _), (drafts, undo) = lax.scan(
                body, (cache, last_tok), jnp.arange(gamma))
            qs = None
        return cache, drafts, qs, undo

    return loop


def _make_draft_loop(plan: Plan, gamma: int, *, lora_scale: float = 2.0,
                     full_len: int = 0, sampling: bool = True) -> Callable:
    decode = make_multi_adapter_decode_step(plan, lora_scale=lora_scale)

    def loop(params, bank, cache, last_tok, pos, adapter_ids, temps, seeds,
             gen_idx):
        B = last_tok.shape[0]
        bidx = jnp.arange(B)
        temp = jnp.maximum(temps, 1e-6)[:, None]

        def keys_at(idx, tag):
            return jax.vmap(lambda s, i: request_key(s, i, tag))(seeds, idx)

        def body(carry, j):
            dc, tok = carry
            pre = {}
            for stn, stc in dc.items():
                for bn, bc in stc.items():
                    if "k" in bc and bc["k"].shape[2] != full_len:
                        slot = (pos + j) % bc["k"].shape[2]
                        pre.setdefault(stn, {})[bn] = {
                            "k": bc["k"][:, bidx, slot],
                            "v": bc["v"][:, bidx, slot],
                        }
            logits, dc = decode(params, bank, tok, dc, pos + j, adapter_ids)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            if sampling:
                keys = keys_at(gen_idx + j, 1)
                sampled = jax.vmap(jax.random.categorical)(
                    keys, logits / temp).astype(jnp.int32)
                nxt = jnp.where(temps > 0.0, sampled, nxt)
            undo = {}
            for stn, stc in dc.items():
                undo[stn] = {}
                for bn, bc in stc.items():
                    if "k" in bc:
                        if stn in pre and bn in pre[stn]:
                            undo[stn][bn] = pre[stn][bn]
                    else:                              # mamba: post-step state
                        undo[stn][bn] = {"conv": bc["conv"], "ssm": bc["ssm"]}
            if sampling:
                q = jax.nn.softmax(logits / temp, axis=-1)
                return (dc, nxt), (nxt, q, undo)
            return (dc, nxt), (nxt, undo)

        if sampling:
            (cache, _), (drafts, qs, undo) = lax.scan(
                body, (cache, last_tok), jnp.arange(gamma))
        else:
            (cache, _), (drafts, undo) = lax.scan(
                body, (cache, last_tok), jnp.arange(gamma))
            qs = None
        return cache, drafts, qs, undo

    return loop

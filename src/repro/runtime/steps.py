"""jit-able train / prefill / decode steps shared by the Trainer, the serving
engine and the multi-pod dry-run.

``make_train_step`` implements the LoRAM online stage: frozen (pruned,
possibly NF4) base, trainable adapters only, gradient accumulation over
microbatches via ``lax.scan`` (XLA overlaps microbatch k+1 compute with
microbatch k collectives), AdamW on the adapter tree, warmup-cosine LR.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LoRAConfig, TrainConfig
from repro.core.objectives import sft_loss
from repro.models.model import Plan, decode_step as model_decode, forward, prefill as model_prefill
from repro.optim.adamw import AdamWState, adamw_update
from repro.optim.schedule import warmup_cosine


def make_train_step(
    plan: Plan,
    train_cfg: TrainConfig,
    lora_cfg: LoRAConfig,
    *,
    n_micro: int = 1,
    grad_transform: Optional[Callable] = None,
) -> Callable:
    """Returns train_step(base_params, lora, opt_state, step, batch) →
    (lora, opt_state, metrics)."""

    def train_step(base_params, lora, opt_state: AdamWState, step, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B = tokens.shape[0]
        assert B % n_micro == 0, (B, n_micro)
        mb = B // n_micro

        def reshape_micro(x):
            return x.reshape((n_micro, mb) + x.shape[1:])

        micro = jax.tree.map(reshape_micro, batch)

        def loss_fn(l, microbatch):
            loss, (ce, aux) = sft_loss(
                plan, base_params, l, microbatch,
                lora_scale=lora_cfg.scale, remat=train_cfg.remat)
            return loss, ce

        def acc_body(carry, microbatch):
            g_acc, loss_acc, ce_acc = carry
            (loss, ce), g = jax.value_and_grad(loss_fn, has_aux=True)(lora, microbatch)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, loss_acc + loss, ce_acc + ce), ()

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), lora)
        (grads, loss_sum, ce_sum), _ = lax.scan(
            acc_body, (g0, jnp.zeros(()), jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        if grad_transform is not None:
            grads = grad_transform(grads)

        lr = warmup_cosine(step, peak_lr=train_cfg.learning_rate,
                           warmup_steps=train_cfg.warmup_steps,
                           total_steps=train_cfg.total_steps)
        new_lora, new_opt = adamw_update(
            lora, grads, opt_state, lr=lr, wd=train_cfg.weight_decay,
            clip=train_cfg.grad_clip)
        metrics = {"loss": loss_sum / n_micro, "ce": ce_sum / n_micro, "lr": lr,
                   "step": step}
        return new_lora, new_opt, metrics

    return train_step


def make_eval_step(plan: Plan, lora_cfg: LoRAConfig) -> Callable:
    def eval_step(base_params, lora, batch):
        loss, (ce, aux) = sft_loss(plan, base_params, lora, batch,
                                   lora_scale=lora_cfg.scale, remat=False)
        return {"loss": loss, "ce": ce, "ppl": jnp.exp(ce)}

    return eval_step


def make_prefill_step(plan: Plan, *, lora_scale: float = 2.0,
                      with_lora: bool = False) -> Callable:
    """serve-side prefill: (params[, lora], tokens, cache[, frontend])."""

    if with_lora:
        def step(params, lora, tokens, cache, frontend=None):
            return model_prefill(plan, params, tokens, cache, lora,
                                 frontend=frontend, lora_scale=lora_scale)
    else:
        def step(params, tokens, cache, frontend=None):
            return model_prefill(plan, params, tokens, cache, None,
                                 frontend=frontend)
    return step


def make_decode_step(plan: Plan, *, lora_scale: float = 2.0,
                     with_lora: bool = False) -> Callable:
    """serve_step: one new token for every sequence in the batch, against a
    KV/SSM cache of the configured length."""

    if with_lora:
        def step(params, lora, token, cache, pos):
            return model_decode(plan, params, token, cache, pos, lora,
                                lora_scale=lora_scale)
    else:
        def step(params, token, cache, pos):
            return model_decode(plan, params, token, cache, pos, None)
    return step


# ---------------------------------------------------------------------------
# continuous-batching serve steps
# ---------------------------------------------------------------------------

def make_multi_adapter_decode_step(plan: Plan, *,
                                   lora_scale: float = 2.0) -> Callable:
    """One token for every *slot*: per-slot positions (each sequence sits at
    its own depth) and per-slot ``adapter_ids`` routed through a stacked
    adapter bank (see repro.serving.adapters)."""

    def step(params, bank, token, cache, pos, adapter_ids):
        return model_decode(plan, params, token, cache, pos, bank,
                            lora_scale=lora_scale, adapter_ids=adapter_ids)

    return step


def make_prefill_into_slot(plan: Plan, *, lora_scale: float = 2.0) -> Callable:
    """Prefill ONE request directly into slot ``slot`` of a live multi-slot
    cache while other slots keep decoding unchanged.

    The slot's cache row starts from zeros (a freed slot may hold the previous
    occupant's KV / SSM state — stale SSM state would corrupt the recurrence)
    and is written back with ``dynamic_update_slice`` along the batch axis, so
    the jitted computation is reused for every slot index.
    """

    def _zeros_row(c):
        return jnp.zeros(c.shape[:1] + (1,) + c.shape[2:], c.dtype)

    def _write_row(big, small, slot):
        return lax.dynamic_update_slice_in_dim(big, small.astype(big.dtype),
                                               slot, axis=1)

    def step(params, lora, tokens, big_cache, slot):
        # tokens: (1, S_prompt); slot: scalar int32
        row = jax.tree.map(_zeros_row, big_cache)
        logits, row, _ = model_prefill(plan, params, tokens, row, lora,
                                       lora_scale=lora_scale)
        new_cache = jax.tree.map(
            lambda b, s: _write_row(b, s, slot), big_cache, row)
        return logits, new_cache

    return step

"""Fault-tolerant LoRAM Trainer.

Orchestrates the online training stage on top of the substrates:

  data (stateless host-sharded batches) → jitted train_step (frozen base +
  adapter AdamW, microbatch scan) → watchdog (straggler alarm) →
  CheckpointManager (async, atomic, validated) → restore_or_init (resume
  from the newest valid checkpoint after any crash/preemption).

The same class drives smoke-scale CPU runs (tests, examples) and the
production mesh (launch/train.py) — only the mesh and config differ.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import LoRAConfig, TrainConfig
from repro.distributed import sharding
from repro.models.model import Plan
from repro.optim.adamw import AdamWState, adamw_init
from repro.runtime.steps import make_eval_step, make_train_step
from repro.runtime.watchdog import StepWatchdog, StragglerAlarm


@dataclasses.dataclass
class TrainState:
    step: int
    lora: Any
    opt: AdamWState


class Trainer:
    def __init__(
        self,
        plan: Plan,
        base_params: Any,
        lora0: Any,
        train_cfg: TrainConfig,
        lora_cfg: LoRAConfig,
        *,
        mesh=None,
        n_micro: int = 1,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 50,
        keep: int = 3,
        watchdog: Optional[StepWatchdog] = None,
        on_straggler: str = "checkpoint_and_continue",   # or "raise"
    ):
        self.plan = plan
        self.base_params = base_params
        self.train_cfg = train_cfg
        self.lora_cfg = lora_cfg
        self.mesh = mesh
        self.n_micro = n_micro
        self.ckpt = (CheckpointManager(checkpoint_dir, keep=keep)
                     if checkpoint_dir else None)
        self.checkpoint_every = checkpoint_every
        self.watchdog = watchdog or StepWatchdog(threshold=10.0)
        self.on_straggler = on_straggler
        self.metrics_log: list = []

        step_fn = make_train_step(plan, train_cfg, lora_cfg, n_micro=n_micro)
        if mesh is not None:
            sharding.install_residual_constraint()
            base_sh = sharding.to_shardings(
                sharding.param_specs(base_params, mesh, fsdp=False), mesh)
            lspec = sharding.param_specs(lora0, mesh, fsdp=False)
            lora_sh = sharding.to_shardings(lspec, mesh)
            opt_sh = sharding.to_shardings(
                sharding.opt_specs(lspec, None), mesh)
            self._step = jax.jit(
                step_fn,
                in_shardings=(base_sh, lora_sh, opt_sh, None, None),
                donate_argnums=(1, 2))
        else:
            self._step = jax.jit(step_fn, donate_argnums=(1, 2))
        self._eval = jax.jit(make_eval_step(plan, lora_cfg))
        self._init_lora = lora0

    # ----------------------------------------------------------------- state
    def init_state(self) -> TrainState:
        # fresh copies: the step donates its lora/opt buffers, and the init
        # tree may be shared with other Trainers (tests, restarts)
        lora = jax.tree.map(jnp.copy, self._init_lora)
        return TrainState(0, lora, adamw_init(lora))

    def restore_or_init(self) -> TrainState:
        state = self.init_state()
        if self.ckpt is None:
            return state
        template = {"lora": state.lora, "opt": state.opt}
        step, tree = self.ckpt.restore_latest(template)
        if step is None:
            return state
        print(f"[trainer] resumed from step {step}")
        return TrainState(step, tree["lora"], tree["opt"])

    def save(self, state: TrainState, blocking: bool = False) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(state.step, {"lora": state.lora, "opt": state.opt},
                       blocking=blocking)

    # ------------------------------------------------------------------ loop
    def train(
        self,
        batches: Iterator[Dict[str, np.ndarray]],
        *,
        steps: Optional[int] = None,
        state: Optional[TrainState] = None,
        log_every: int = 10,
        eval_batch: Optional[Dict[str, np.ndarray]] = None,
        eval_every: int = 0,
        callback: Optional[Callable] = None,
    ) -> TrainState:
        state = state or self.restore_or_init()
        total = steps if steps is not None else self.train_cfg.total_steps
        ctx = (sharding.use_mesh(self.mesh, self.train_cfg.seq_shard_activations)
               if self.mesh is not None else _null_ctx())
        with ctx:
            while state.step < total:
                batch = next(batches)
                self.watchdog.start()
                try:
                    lora, opt, metrics = self._step(
                        self.base_params, state.lora, state.opt,
                        jnp.asarray(state.step, jnp.int32), batch)
                    jax.block_until_ready(metrics["loss"])
                    self.watchdog.stop(state.step)
                except StragglerAlarm as alarm:
                    if self.on_straggler == "raise":
                        raise
                    print(f"[trainer] straggler: {alarm}; checkpointing")
                    self.save(state, blocking=True)
                    continue  # in production: reschedule; here: proceed
                state = TrainState(state.step + 1, lora, opt)
                m = {k: float(v) for k, v in metrics.items()}
                self.metrics_log.append(m)
                if log_every and state.step % log_every == 0:
                    print(f"[trainer] step {state.step} "
                          f"loss={m['loss']:.4f} lr={m['lr']:.2e}")
                if eval_every and eval_batch is not None and state.step % eval_every == 0:
                    ev = self._eval(self.base_params, state.lora, eval_batch)
                    print(f"[trainer] eval step {state.step} "
                          f"ppl={float(ev['ppl']):.3f}")
                if callback:
                    callback(state, m)
                if self.ckpt and state.step % self.checkpoint_every == 0:
                    self.save(state)
        if self.ckpt:
            self.save(state, blocking=True)
            self.ckpt.wait()
        return state

    def evaluate(self, batch) -> Dict[str, float]:
        with (sharding.use_mesh(self.mesh, False) if self.mesh is not None
              else _null_ctx()):
            ev = self._eval(self.base_params,
                            self.restore_or_init().lora, batch)
        return {k: float(v) for k, v in ev.items()}


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False

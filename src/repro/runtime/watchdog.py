"""Straggler / hang detection for the step loop.

At thousand-node scale the common failure is not a crash but a slow or
wedged worker.  The watchdog keeps an EWMA of step wall-time; a step
exceeding ``threshold × EWMA`` raises :class:`StragglerAlarm`, which the
Trainer converts into checkpoint-and-reschedule (in a real deployment the
launcher replaces the slow host; here the policy hook is unit-tested with a
fake clock).

The serving tick loop reuses the same detector with a different policy: a
serving stall must be SURFACED, not crash the engine mid-stream.  Passing
``on_alarm`` routes the alarm to a callback instead of raising — the engines
count it (``serve_stalls_total``) and log a ``stall`` event
(``ServeConfig.tick_watchdog``); after the callback the straggler step
feeds the EWMA like any other, so a sustained slowdown becomes the new
baseline instead of alarming forever.
"""
from __future__ import annotations

import time
from typing import Callable, Optional


class StragglerAlarm(RuntimeError):
    def __init__(self, step: int, elapsed: float, ewma: float):
        super().__init__(
            f"step {step} took {elapsed:.2f}s vs EWMA {ewma:.2f}s")
        self.step = step
        self.elapsed = elapsed
        self.ewma = ewma


class StepWatchdog:
    def __init__(self, *, alpha: float = 0.2, threshold: float = 5.0,
                 warmup_steps: int = 5,
                 clock: Callable[[], float] = time.monotonic,
                 on_alarm: Optional[Callable[[StragglerAlarm], None]] = None):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup_steps = warmup_steps
        self.clock = clock
        self.on_alarm = on_alarm      # None → raise (trainer policy)
        self.ewma: Optional[float] = None
        self._t0: Optional[float] = None
        self._n = 0

    def start(self) -> None:
        self._t0 = self.clock()

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "stop() without start()"
        elapsed = self.clock() - self._t0
        self._t0 = None
        self._n += 1
        if self.ewma is None:
            self.ewma = elapsed
        else:
            if self._n > self.warmup_steps and elapsed > self.threshold * self.ewma:
                alarm = StragglerAlarm(step, elapsed, self.ewma)
                if self.on_alarm is None:
                    raise alarm
                self.on_alarm(alarm)
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * elapsed
        return elapsed

"""Elastic scaling: deterministic re-sharding of the data stream and state.

Because the data pipeline is stateless (``data.index_for(step, host,
n_hosts)``) and the trainable state under LoRAM is tiny (rank-r adapters +
Adam moments), elasticity costs exactly one checkpoint restore:

* scale-down/up → restart with a different ``n_hosts``; every host derives
  its shard for step k from the mapping below; no data is replayed or lost.
* adapter/opt state is replicated (or re-replicated on restore) — MBs, not
  the 10s-of-GB a full fine-tune would move.

``plan_transition`` computes which global batch rows move where, so a warm
handoff (live reshard, no restart) knows exactly what to transfer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class ShardAssignment:
    host: int
    n_hosts: int
    rows: Tuple[int, ...]      # global-batch row indices owned by this host


def shard_rows(global_batch: int, host: int, n_hosts: int) -> ShardAssignment:
    assert global_batch % n_hosts == 0, (global_batch, n_hosts)
    per = global_batch // n_hosts
    return ShardAssignment(host, n_hosts, tuple(range(host * per, (host + 1) * per)))


def plan_transition(global_batch: int, old_n: int, new_n: int
                    ) -> Dict[int, List[Tuple[int, int]]]:
    """rows to transfer: {new_host: [(old_host, row), ...]} — identity rows
    (already local) are omitted."""
    moves: Dict[int, List[Tuple[int, int]]] = {}
    old_owner = {}
    for h in range(old_n):
        for r in shard_rows(global_batch, h, old_n).rows:
            old_owner[r] = h
    for h in range(new_n):
        for r in shard_rows(global_batch, h, new_n).rows:
            if old_owner.get(r) != h:
                moves.setdefault(h, []).append((old_owner[r], r))
    return moves

"""Fault-tolerant checkpointing.

Design points (per DESIGN.md §5):

* **Atomicity** — writes land in ``step_XXXXXXXX.tmp-<nonce>`` and are
  ``os.replace``d into place only after the manifest (with content hashes)
  is fsync'd; a crash mid-write can never produce a directory that
  ``restore_latest`` would accept.
* **Validation** — every tensor file carries a crc32 in the manifest;
  corrupt/partial checkpoints are skipped (warn) and the next-newest valid
  one is used.
* **Async** — ``save_async`` snapshots to host memory synchronously (cheap:
  LoRA + opt state are MBs) and does file I/O on a daemon thread so the
  train loop never blocks on disk.
* **Retention** — keep the newest ``keep`` checkpoints plus every
  ``keep_period``-th step forever.
* **Multi-host** — each process writes only its addressable shard under
  ``proc_<k>``; restore reassembles per-process. On this single-process CPU
  container that collapses to proc_0, but the layout is the production one.

Tensors are stored with ``numpy.savez`` (no pickle), pytree structure in a
JSON manifest with dtype/shape — restartable across JAX versions.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in leaves_paths:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing tensor {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, keep_period: int = 0,
                 process_index: Optional[int] = None):
        self.dir = directory
        self.keep = keep
        self.keep_period = keep_period
        self.proc = process_index if process_index is not None else jax.process_index()
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, tree: Any, *, blocking: bool = True) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # device→host now
        if blocking:
            self._write(step, host_tree)
        else:
            self.wait()  # at most one outstanding write
            self._thread = threading.Thread(
                target=self._write_guard, args=(step, host_tree), daemon=True)
            self._thread.start()

    def save_async(self, step: int, tree: Any) -> None:
        self.save(step, tree, blocking=False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_guard(self, step, host_tree):
        try:
            self._write(step, host_tree)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step: int, host_tree) -> None:
        final = self._step_dir(step)
        tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=self.dir)
        try:
            flat = _flatten(host_tree)
            proc_dir = os.path.join(tmp, f"proc_{self.proc}")
            os.makedirs(proc_dir, exist_ok=True)
            tensor_path = os.path.join(proc_dir, "tensors.npz")
            np.savez(tensor_path, **{k: v for k, v in flat.items()})
            manifest = {
                "step": step,
                "time": time.time(),
                "process": self.proc,
                "tensors": {
                    k: {"shape": list(v.shape), "dtype": str(v.dtype),
                        "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes())}
                    for k, v in flat.items()
                },
            }
            mpath = os.path.join(proc_dir, "manifest.json")
            with open(mpath, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    # --------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d{8})", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _valid(self, step: int) -> bool:
        proc_dir = os.path.join(self._step_dir(step), f"proc_{self.proc}")
        mpath = os.path.join(proc_dir, "manifest.json")
        tpath = os.path.join(proc_dir, "tensors.npz")
        if not (os.path.exists(mpath) and os.path.exists(tpath)):
            return False
        try:
            with open(mpath) as f:
                manifest = json.load(f)
            with np.load(tpath) as z:
                for k, meta in manifest["tensors"].items():
                    v = z[k]
                    if zlib.crc32(np.ascontiguousarray(v).tobytes()) != meta["crc32"]:
                        return False
            return True
        except Exception:
            return False

    def restore(self, step: int, template: Any) -> Any:
        proc_dir = os.path.join(self._step_dir(step), f"proc_{self.proc}")
        with np.load(os.path.join(proc_dir, "tensors.npz")) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten_like(template, flat)

    def restore_latest(self, template: Any) -> Tuple[Optional[int], Any]:
        """Newest *valid* checkpoint, skipping corrupt ones.  Returns
        (step, tree) or (None, template)."""
        for step in reversed(self.steps()):
            if self._valid(step):
                return step, self.restore(step, template)
            print(f"[ckpt] step {step} failed validation; skipping")
        return None, template

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = self.steps()
        protected = set(steps[-self.keep:]) if self.keep else set(steps)
        if self.keep_period:
            protected |= {s for s in steps if s % self.keep_period == 0}
        for s in steps:
            if s not in protected:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)

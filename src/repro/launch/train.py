"""Production training launcher.

On a real multi-host TPU deployment each host runs this same entrypoint
(`jax.distributed.initialize()` picks up the cluster env); on this CPU
container it runs the smoke-scale config end-to-end.

  python -m repro.launch.train --arch yi-34b --variant qloram --steps 200 \
      --ratio 0.65 --ckpt /tmp/ckpt [--smoke]
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import (LoRAConfig, LoRAMConfig, TrainConfig, get_arch,
                           get_smoke)
from repro.core import loram
from repro.data import AlignmentCorpus, SFTDataset, batch_iterator
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_params, make_plan
from repro.runtime.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="qloram",
                    choices=["lora", "loram", "qloram"])
    ap.add_argument("--method", default="stru",
                    choices=["rand", "stru", "semi", "unst"])
    ap.add_argument("--ratio", type=float, default=0.65)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--align-steps", type=int, default=0)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    if "JAX_COORDINATOR" in os.environ:  # multi-host cluster
        jax.distributed.initialize()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    plan = make_plan(cfg)
    rng = jax.random.PRNGKey(0)
    params = init_params(plan, rng)

    loram_cfg = LoRAMConfig(
        method=args.method if args.variant != "lora" else "none",
        ratio=args.ratio if args.variant != "lora" else 0.0,
        quantize=args.variant == "qloram")
    lora_cfg = LoRAConfig(rank=args.rank)

    align_iter = None
    if args.align_steps:
        corpus = AlignmentCorpus(cfg.vocab_size, args.seq_len)
        align_iter = batch_iterator(corpus, batch_size=args.global_batch)

    setup = loram.setup(plan, params, loram_cfg, lora_cfg, rng,
                        align_batches=align_iter,
                        align_steps=args.align_steps)
    rep = loram.storage_report(params, setup.small_params)
    print(f"[train] {cfg.name}: parameter reduction "
          f"{rep['reduction_ratio']:.2f}x, HBM reduction "
          f"{rep['hbm_reduction']:.2f}x")

    mesh = (make_production_mesh() if args.production_mesh
            else None)
    tc = TrainConfig(global_batch=args.global_batch, seq_len=args.seq_len,
                     learning_rate=args.lr, total_steps=args.steps,
                     remat=not args.smoke)
    ds = SFTDataset(cfg.vocab_size, args.seq_len)
    fe_shape = None
    if cfg.family == "encdec":
        fe_shape = (cfg.enc_len, cfg.d_model)
    elif cfg.family == "vlm":
        fe_shape = (cfg.n_patches, cfg.d_model)

    trainer = Trainer(setup.small_plan, setup.small_params, setup.lora0, tc,
                      lora_cfg, mesh=mesh, n_micro=args.n_micro,
                      checkpoint_dir=args.ckpt)
    state = trainer.train(
        batch_iterator(ds, batch_size=args.global_batch,
                       start_step=trainer.restore_or_init().step,
                       frontend_shape=fe_shape),
        steps=args.steps)
    print(f"[train] done at step {state.step}")


if __name__ == "__main__":
    main()

"""Serving launcher: load a LoRAM-trained adapter checkpoint, recover + merge
into the FULL model, serve batched requests.

  python -m repro.launch.serve --arch yi-34b --smoke --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LoRAConfig, LoRAMConfig, ServeConfig, get_arch, get_smoke
from repro.core import loram
from repro.models import init_params, make_plan
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-seq-len", type=int, default=256)
    ap.add_argument("--no-merge", action="store_true",
                    help="serve base + adapters unmerged (multi-adapter mode)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    plan = make_plan(cfg)
    rng = jax.random.PRNGKey(0)
    params = init_params(plan, rng)

    # stand-in for a trained adapter: run the LoRAM offline path then merge
    setup = loram.setup(plan, params, LoRAMConfig(method="stru", ratio=0.5,
                                                  keep_first=0, keep_last=0),
                        LoRAConfig(rank=8), rng)
    lora_full, merged = loram.finalize(setup, setup.lora0, params)

    eng = ServeEngine(plan, params if args.no_merge else merged,
                      ServeConfig(max_seq_len=args.max_seq_len,
                                  merge_adapters=not args.no_merge),
                      lora=lora_full if args.no_merge else None)
    prompts = np.random.default_rng(0).integers(
        2, cfg.vocab_size, size=(args.batch, args.prompt_len)).astype(np.int32)
    fe = None
    if cfg.family == "encdec":
        fe = np.zeros((args.batch, cfg.enc_len, cfg.d_model), np.float32)
    elif cfg.family == "vlm":
        fe = np.zeros((args.batch, cfg.n_patches, cfg.d_model), np.float32)
    res = eng.generate(prompts, max_new_tokens=args.new_tokens,
                       temperature=args.temperature, frontend=fe)
    print(f"[serve] generated {res.tokens.shape}; prefill {res.prefill_s:.3f}s; "
          f"decode {res.decode_s:.3f}s; {res.tokens_per_s:.1f} tok/s")
    print(res.tokens[:, :12])


if __name__ == "__main__":
    main()

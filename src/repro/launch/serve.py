"""Serving launcher: load a LoRAM-trained adapter checkpoint, recover + merge
into the FULL model, serve batched requests.

  python -m repro.launch.serve --arch yi-34b --smoke --batch 4 --new-tokens 16

``--continuous`` serves the same requests through the continuous-batching
multi-adapter engine (implies ``--no-merge``; each request routes through the
adapter registry per-slot instead of a single global adapter).

``--speculative`` additionally uses the LoRAM-pruned model as a draft: γ
(``--gamma``) tokens are proposed per slot by the small model (running the
pruned adapters pre-recovery) and verified by the full model in one batched
forward — output is identical in distribution to plain serving.

``--adapter-bank-slots N`` caps the DEVICE adapter bank at N rows (row 0 is
the reserved base route) — the paged adapter bank: registration is
unbounded host-side, missing adapters stream host→HBM at admission
(overlapped with decode ticks) and rows are LRU-evicted at refcount 0.
``--adapter-rank-buckets B`` lets mixed-rank adapters share the bank
through zero-padded (exactly zero-delta) rank buckets.  The snapshot's
``adapters`` section reports hit rate, uploads/evictions and streamed
bytes.

``--mesh data,model`` serves over an explicit device mesh: weights and KV
head-sharded over the ``model`` axis, decode batch sharded over ``data``
(see the sharding table in ``repro/serving/engine.py``).  The product must
not exceed ``len(jax.devices())``; on CPU export
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` first.  ``1,1``
(default) serves single-device with the mesh machinery compiled away.
Tokens are identical to the single-device engine either way.

``--metrics-json PATH`` dumps the full observability snapshot (metrics
registry + tick-span summary + request lifecycle events + per-request
results, see ``repro.obs``) after the run, validated against the checked-in
``repro/obs/snapshot.schema.json``.  ``--metrics-port PORT`` additionally
serves live Prometheus text at ``/metrics`` (JSON at ``/metrics.json``)
while the process runs.

Resilience (``repro.serving.resilience``; continuous engines only):
``--queue-limit N`` bounds the pre-admission queue (``--queue-policy``
picks ``reject`` — shed the NEW request — or ``shed-oldest``);
``--deadline-ms`` attaches an end-to-end deadline to every request
(``--ttft-deadline-ms`` separately bounds time-to-first-token);
``--degrade`` arms the graceful-degradation ladder.  Every request then
terminates with a typed ``RequestResult.status`` (``ok``/``timeout``/
``shed``/``cancelled``/``failed``) that the metrics snapshot carries
per-request.  ``--fault-plan JSON_OR_PATH`` installs a deterministic,
seeded fault-injection plan (``repro.testing.faults.FaultPlan``) — e.g.
``'{"seed": 7, "tick": {"p": 0.3, "max_fires": 4}}'`` — which the engine
absorbs via bounded tick retries, preemption, degradation and
snapshot-and-restart; CI asserts no request is ever lost under it.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import (LoRAConfig, LoRAMConfig, QuantPolicy,
                           ResilienceConfig, ServeConfig, get_arch, get_smoke)
from repro.core import loram
from repro.models import init_params, make_plan
from repro.serving import (AdapterRegistry, ContinuousServeEngine,
                           ServeEngine, SpeculativeServeEngine,
                           draft_from_setup)


def _export_metrics(args, eng, results=None) -> None:
    """``--metrics-json``: one schema-validated snapshot per run, with the
    engine-reported per-request timings alongside the event log so the two
    clocks can be cross-checked (CI does)."""
    if not args.metrics_json:
        return
    extra = None
    if results is not None:
        extra = {"requests": {
            str(uid): {"ttft_s": r.ttft_s, "latency_s": r.latency_s,
                       "n_generated": r.n_generated,
                       "status": getattr(r, "status", "ok")}
            for uid, r in results.items()}}
    registry = getattr(eng, "registry", None)
    if registry is not None:
        res = registry.residency
        extra = dict(extra or {})
        extra["adapters"] = {
            "bank_slots": int(registry.bank_slots),
            "rank_buckets": int(registry.rank_buckets),
            "registered": len(registry),
            "in_use": int(res.in_use),
            "hits": int(res.n_hits), "misses": int(res.n_misses),
            "hit_rate": float(res.hit_rate),
            "uploads": int(res.n_uploads),
            "evictions": int(res.n_evictions),
            "upload_bytes": int(res.upload_bytes),
        }
    quant = getattr(eng, "cfg", None) and eng.cfg.quant
    if quant and (quant.weights != "none" or quant.kv != "none"):
        from repro.quant import nf4
        extra = dict(extra or {})
        extra["quant"] = {
            "weights": quant.weights,
            "kv": quant.kv,
            "weight_bytes_packed": int(nf4.param_bytes(eng.params)),
            "weight_bytes_logical": int(nf4.param_bytes_logical(eng.params)),
            "kv_cache_bytes": int(eng.kv_cache_bytes())
            if hasattr(eng, "kv_cache_bytes") else 0,
        }
    obs.write_snapshot(args.metrics_json, eng.metrics, eng.tracer,
                       eng.events, extra=extra)
    print(f"[serve] metrics snapshot -> {args.metrics_json}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-seq-len", type=int, default=256)
    ap.add_argument("--no-merge", action="store_true",
                    help="serve base + adapters unmerged (multi-adapter mode)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine (submit/step/stream)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--adapter-bank-slots", type=int, default=0,
                    metavar="N",
                    help="device adapter-bank rows (row 0 is the reserved "
                         "base route); adapters beyond the bank live host-"
                         "side and stream in on demand, LRU-evicted at "
                         "refcount 0 — the paged adapter bank (0 → every "
                         "registered adapter stays resident, the dense-"
                         "equivalent bank)")
    ap.add_argument("--adapter-rank-buckets", type=int, default=1,
                    metavar="B",
                    help="zero-padded rank buckets for mixed-rank adapters "
                         "sharing one bank: each adapter pads up to the "
                         "nearest of B even rank steps (padding is exactly "
                         "zero-delta; 1 → pad everything to the template "
                         "rank)")
    ap.add_argument("--speculative", action="store_true",
                    help="pruned-draft speculative decoding (implies "
                         "--continuous)")
    ap.add_argument("--gamma", type=int, default=4,
                    help="draft tokens per speculative round")
    ap.add_argument("--gamma-autotune", action="store_true",
                    help="adapt gamma to the measured acceptance rate")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: page-pool + block table instead "
                         "of dense per-slot max_seq_len reservation "
                         "(implies --continuous)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="page-pool capacity (0 → dense-equivalent)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: admit prompts in page-aligned "
                         "chunks of this many tokens interleaved with "
                         "decode ticks (implies --paged; 0 → monolithic)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="share the batch's common prompt prefix across "
                         "slots via copy-on-write pages (implies --paged)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared-prefix length in tokens (with "
                         "--prefix-sharing; 0 → half the prompt)")
    ap.add_argument("--quant-weights", choices=("none", "nf4"),
                    default="none",
                    help="NF4-quantize the frozen base projections at engine "
                         "load; the decode tick runs them through the fused "
                         "dequant-matmul kernel (QLoRAM serving; implies "
                         "--continuous --no-merge)")
    ap.add_argument("--quant-kv", choices=("none", "int8"), default="none",
                    help="store the paged attention K/V pool as int8 codes "
                         "+ per-row absmax scales (implies --paged)")
    ap.add_argument("--mesh", type=str, default="1,1", metavar="DATA,MODEL",
                    help="serve over a DATAxMODEL device mesh (batch over "
                         "data, heads/experts over model); 1,1 = no mesh")
    ap.add_argument("--metrics-json", type=str, default=None, metavar="PATH",
                    help="write the observability snapshot (metrics + spans "
                         "+ lifecycle events + per-request results) here "
                         "after the run")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="serve live Prometheus text on this port at "
                         "/metrics (JSON at /metrics.json) while running")
    ap.add_argument("--tick-watchdog", action="store_true",
                    help="count straggler ticks via the step watchdog "
                         "(serve_stalls_total / serve_tick_ewma_s)")
    ap.add_argument("--queue-limit", type=int, default=0,
                    help="bound the pre-admission queue; overflow is shed "
                         "per --queue-policy (0 → unbounded)")
    ap.add_argument("--queue-policy", choices=("reject", "shed-oldest"),
                    default="reject",
                    help="full-queue behaviour: shed the NEW request "
                         "(reject) or the oldest queued one (shed-oldest)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="end-to-end deadline per request; expired requests "
                         "finish with status=timeout (0 → none)")
    ap.add_argument("--ttft-deadline-ms", type=float, default=0.0,
                    help="time-to-first-token deadline (0 → none)")
    ap.add_argument("--degrade", action="store_true",
                    help="arm the graceful-degradation ladder (shrink γ → "
                         "no spec → drop idle prefixes → shrink prefill "
                         "chunk → shed)")
    ap.add_argument("--fault-plan", type=str, default=None,
                    metavar="JSON_OR_PATH",
                    help="install a seeded deterministic fault-injection "
                         "plan (repro.testing.faults.FaultPlan JSON, inline "
                         "or a file path)")
    args = ap.parse_args()
    try:
        mesh_data, mesh_model = (int(v) for v in args.mesh.split(","))
    except ValueError:
        ap.error("--mesh wants two comma-separated ints, e.g. --mesh 1,2")
    if args.quant_kv != "none":
        args.paged = True
    if args.prefill_chunk or args.prefix_sharing:
        args.paged = True
    if args.speculative or args.paged or args.quant_weights != "none":
        args.continuous = True
    resil = ResilienceConfig(
        queue_limit=args.queue_limit, queue_policy=args.queue_policy,
        deadline_s=args.deadline_ms / 1e3,
        ttft_deadline_s=args.ttft_deadline_ms / 1e3,
        degradation=args.degrade)
    if resil.enabled or args.fault_plan:
        args.continuous = True

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    plan = make_plan(cfg)
    rng = jax.random.PRNGKey(0)
    params = init_params(plan, rng)

    # stand-in for a trained adapter: run the LoRAM offline path then merge
    setup = loram.setup(plan, params, LoRAMConfig(method="stru", ratio=0.5,
                                                  keep_first=0, keep_last=0),
                        LoRAConfig(rank=8), rng)
    lora_full, merged = loram.finalize(setup, setup.lora0, params)

    prompts = np.random.default_rng(0).integers(
        2, cfg.vocab_size, size=(args.batch, args.prompt_len)).astype(np.int32)

    if args.continuous:
        bank_slots = args.adapter_bank_slots or 2
        registry = AdapterRegistry(lora_full, max_adapters=2,
                                   bank_slots=bank_slots,
                                   rank_buckets=args.adapter_rank_buckets)
        registry.add("task", lora_full)
        serve_cfg = ServeConfig(
            max_seq_len=args.max_seq_len, max_slots=args.slots,
            max_adapters=2, adapter_bank_slots=bank_slots,
            adapter_rank_buckets=args.adapter_rank_buckets,
            max_new_tokens=max(args.new_tokens, 1),
            draft_gamma=args.gamma if args.speculative else 0,
            gamma_autotune=args.gamma_autotune,
            kv_paging=args.paged, kv_page_size=args.page_size,
            kv_pages=args.kv_pages, prefill_chunk=args.prefill_chunk,
            prefix_sharing=args.prefix_sharing,
            mesh_data=mesh_data, mesh_model=mesh_model,
            tick_watchdog=args.tick_watchdog,
            quant=QuantPolicy(weights=args.quant_weights, kv=args.quant_kv),
            resilience=resil)
        if args.speculative:
            # the SAME pruned artifacts the adapter was trained on now draft;
            # its pruned-width bank mirrors the target's residency geometry
            draft = draft_from_setup(setup, max_adapters=2,
                                     bank_slots=bank_slots,
                                     rank_buckets=args.adapter_rank_buckets)
            draft.add("task", setup.lora0)
            eng = SpeculativeServeEngine(plan, params, serve_cfg, registry,
                                         draft)
        else:
            eng = ContinuousServeEngine(plan, params, serve_cfg, registry)
        if args.fault_plan:
            from repro.testing.faults import FaultPlan
            eng.install_faults(FaultPlan.from_json(args.fault_plan))
        server = (obs.serve_http(eng.metrics, args.metrics_port, eng.tracer,
                                 eng.events) if args.metrics_port else None)
        t0 = time.perf_counter()
        prefix_kw = {}
        if args.prefix_sharing:
            if args.prompt_len < 2:
                ap.error("--prefix-sharing needs --prompt-len >= 2 (the "
                         "suffix must keep at least one real token)")
            # the demo batch genuinely shares its head: overwrite every
            # prompt's first prefix_len tokens with the first prompt's
            n_p = args.prefix_len or max(args.prompt_len // 2, 1)
            n_p = min(n_p, args.prompt_len - 1)
            prompts[:, :n_p] = prompts[0, :n_p]
            prefix_kw = dict(prefix_id="system", prefix_len=n_p)
        for row in prompts:
            eng.submit(row, max_new_tokens=args.new_tokens, adapter="task",
                       temperature=args.temperature, **prefix_kw)
        results = eng.run()
        dt = time.perf_counter() - t0
        n_tok = sum(r.n_generated for r in results.values())
        mode = "speculative" if args.speculative else "continuous"
        print(f"[serve] {mode}: {len(results)} requests, {n_tok} tokens "
              f"in {dt:.3f}s ({n_tok / max(dt, 1e-9):.1f} tok/s aggregate, "
              f"{args.slots} slots)")
        if args.speculative:
            print(f"[serve] γ={args.gamma}, acceptance "
                  f"{eng.acceptance_rate:.1%}, {eng.n_rounds} rounds")
        if resil.enabled or args.fault_plan:
            tally: dict = {}
            for r in results.values():
                tally[r.status] = tally.get(r.status, 0) + 1
            line = (f"[serve] resilience: statuses={tally}, "
                    f"degradation_level={eng._degrade_level}")
            if eng._faults is not None:
                line += f", faults={eng._faults.report()}"
            print(line)
        if args.quant_weights != "none" or args.quant_kv != "none":
            from repro.quant import nf4
            packed = nf4.param_bytes(eng.params)
            logical = nf4.param_bytes_logical(eng.params)
            print(f"[serve] quant: weights={args.quant_weights} "
                  f"({logical / max(packed, 1):.1f}x packed), "
                  f"kv={args.quant_kv} "
                  f"(pool {eng.kv_cache_bytes() / 2**20:.1f} MiB)")
        if args.prefill_chunk:
            print(f"[serve] chunked prefill: {eng.n_prefill_chunks} chunks, "
                  f"{eng.n_ticks_during_prefill} decode ticks ran during "
                  f"prefill")
        if args.prefix_sharing:
            print(f"[serve] prefix sharing: {eng.n_prefix_hits} hits, "
                  f"{eng.n_prefix_tokens_saved} prefill tokens saved, "
                  f"{eng.n_prefix_pages_shared} shared page mappings")
        for uid in sorted(results)[:4]:
            print(f"  uid={uid} tokens={results[uid].tokens[:12]}")
        _export_metrics(args, eng, results)
        if server is not None:
            server.shutdown()
        return

    eng = ServeEngine(plan, params if args.no_merge else merged,
                      ServeConfig(max_seq_len=args.max_seq_len,
                                  merge_adapters=not args.no_merge,
                                  mesh_data=mesh_data, mesh_model=mesh_model),
                      lora=lora_full if args.no_merge else None)
    fe = None
    if cfg.family == "encdec":
        fe = np.zeros((args.batch, cfg.enc_len, cfg.d_model), np.float32)
    elif cfg.family == "vlm":
        fe = np.zeros((args.batch, cfg.n_patches, cfg.d_model), np.float32)
    server = (obs.serve_http(eng.metrics, args.metrics_port, eng.tracer,
                             eng.events) if args.metrics_port else None)
    res = eng.generate(prompts, max_new_tokens=args.new_tokens,
                       temperature=args.temperature, frontend=fe)
    print(f"[serve] generated {res.tokens.shape}; prefill {res.prefill_s:.3f}s; "
          f"decode {res.decode_s:.3f}s; {res.tokens_per_s:.1f} tok/s")
    print(res.tokens[:, :12])
    _export_metrics(args, eng)
    if server is not None:
        server.shutdown()


if __name__ == "__main__":
    main()

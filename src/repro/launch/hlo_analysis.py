"""Roofline-term extraction from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically — a scan of 8 matmuls reports the FLOPs of 1), which would make
every scan-over-layers model look ~n_layers× cheaper than it is.  This module
re-derives the three roofline inputs by parsing the HLO module:

  * FLOPs         — every ``dot`` (2 · prod(result) · prod(contracting dims)),
                    multiplied by the loop trip counts along its call chain.
  * HBM traffic   — Σ (operand + output bytes) over top-level materializing
                    instructions × trip count.  Fused subcomputations are
                    skipped (their traffic is the fusion node's operands and
                    outputs) — i.e. the standard "every non-fused op
                    round-trips HBM" model.
  * Collective bytes — Σ operand bytes of all-reduce / all-gather /
                    reduce-scatter / all-to-all / collective-permute /
                    ragged-all-to-all × trip count.

Trip counts come from each while-loop's condition computation (scan emits
``compare(ind, constant(N)), direction=LT``); the max integer constant in the
condition is used, which is exact for lax.scan/fori loops.

All shapes in post-SPMD HLO are per-device, so totals are per-chip; the
roofline divides by per-chip peak rates (equivalent to the spec's
global-total / (chips × rate) form).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "c64": 8, "c128": 16, "token": 0, "f4e2m1fn": 0.5, "f8e8m0fnu": 1,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

# ops that do not really materialize / move HBM bytes
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "call",
    "conditional", "custom-call",
}

# HBM-traffic model (TPU execution assumption): only ops that a TPU backend
# actually materializes move HBM bytes; bare elementwise / layout ops are
# assumed fused into their consumers (XLA:TPU fuses far more aggressively
# than the XLA:CPU HLO we parse).  The unfiltered sum is still reported as
# ``traffic_upper_bytes`` (pessimistic bound).
_TRAFFIC_OPS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
    "sort", "select-and-scatter", "triangular-solve", "cholesky", "fft",
} | set(COLLECTIVE_OPS)

_SHAPE_RE = re.compile(r"(\w[\w\d]*)\[([\d,]*)\]")
# lazy scan to the first " op(" token — types may contain /*index=N*/ comments
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([a-z][\w\-]*)\(")
# computation headers are the only lines ending in "{" that contain "->"
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(type_str: str, cap: Optional[float] = None) -> float:
    """bytes of one (possibly tuple) HLO type string.

    ``cap`` bounds the per-element width of *float* tensors: XLA:CPU (the
    dry-run backend) legalizes bf16 dots by upcasting operands to f32, so the
    compiled HLO carries f32 copies of every weight/activation that a TPU
    backend would keep in bf16.  cap=2 models the TPU dtype behaviour; raw
    (uncapped) numbers are reported alongside.
    """
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        width = _DTYPE_BYTES[dt]
        if cap is not None and dt in ("f32", "f64"):
            width = min(width, cap)
        total += n * width
    return total


def _first_dims(type_str: str) -> Optional[Tuple[int, ...]]:
    """dims of the first array shape in a type string (None for tuples with
    nothing parseable)."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()


def _shape_elems(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


def _split_operands(line: str, op: str) -> Tuple[str, str]:
    """Return (operand_segment, attr_tail) of an instruction line."""
    i = line.find(op + "(")
    if i < 0:
        return "", ""
    j = i + len(op) + 1
    depth = 1
    k = j
    while k < len(line) and depth:
        if line[k] == "(":
            depth += 1
        elif line[k] == ")":
            depth -= 1
        k += 1
    return line[j : k - 1], line[k:]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if line.endswith("{") and "->" in line and "=" not in line.split("(", 1)[0]:
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                cur = Computation(hdr.group(1), [], {})
                comps[cur.name] = cur
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        seg, _tail = _split_operands(line, op)
        operands = _OPERAND_NAME_RE.findall(seg)
        ins = Instr(name, type_str, op, operands, line)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps


def _attr(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        for c in _CONST_INT_RE.findall(ins.line):
            best = max(best, int(c))
    return best


def _entry_name(comps: Dict[str, Computation], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m:
        return m.group(1)
    return next(iter(comps))


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 0
    for _, dims in _shape_elems(ins.type_str):
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contract = 1
    if m and ins.operands:
        lhs = comp.by_name.get(ins.operands[0])
        if lhs is not None:
            shapes = _shape_elems(lhs.type_str)
            if shapes:
                dims = shapes[0][1]
                for ci in m.group(1).split(","):
                    if ci != "" and int(ci) < len(dims):
                        contract *= dims[int(ci)]
    return 2.0 * out_elems * contract


def analyze(text: str) -> Dict[str, float]:
    """Returns {"flops", "traffic_bytes", "collective_bytes",
    "collective_bytes_by_op": {...}, "dot_flops_by_comp": ...}."""
    comps = parse_hlo(text)
    entry = _entry_name(comps, text)

    # --- call-multiplier propagation ------------------------------------
    mult: Dict[str, float] = defaultdict(float)
    fused: Dict[str, bool] = defaultdict(bool)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS in call order; HLO call graphs are DAGs
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            callees: List[Tuple[str, float, bool]] = []
            if ins.op == "while":
                body = _attr(ins.line, "body")
                cond = _attr(ins.line, "condition")
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trip = int(tm.group(1))
                else:
                    trip = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    callees.append((body, float(trip), False))
                if cond:
                    callees.append((cond, float(trip), False))
            elif ins.op == "fusion":
                callee = _attr(ins.line, "calls")
                if callee:
                    callees.append((callee, 1.0, True))
            elif ins.op in ("call", "map", "reduce", "reduce-window", "scatter",
                            "select-and-scatter", "sort", "all-reduce",
                            "reduce-scatter", "conditional"):
                for key in ("to_apply", "calls", "true_computation",
                            "false_computation", "branch_computations"):
                    callee = _attr(ins.line, key)
                    if callee:
                        callees.append((callee, 1.0, ins.op != "call"))
            for callee, k, is_fused in callees:
                mult[callee] += mult[cname] * k
                fused[callee] = fused[callee] or is_fused
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    CAP = 2.0  # model bf16 on TPU for float tensors (see _shape_bytes)
    flops = 0.0
    traffic = 0.0
    traffic_raw = 0.0
    traffic_upper = 0.0
    coll_bytes = 0.0
    coll_raw = 0.0
    coll_by_op: Dict[str, float] = defaultdict(float)
    coll_count: Dict[str, int] = defaultdict(int)
    # attention-core attribution (flash-kernel projection, see layers.py)
    attn_traffic = 0.0
    attn_ideal = 0.0
    # nf4-dequant attribution (fused nf4_matmul kernel projection): kernel
    # reads packed codes (0.53 B/weight) and keeps the dequantized tile in
    # VMEM — vs the jnp path's read-codes + write-bf16 + read-bf16 (≥4 B).
    nf4_traffic = 0.0
    NF4_KERNEL_RATIO = 0.53 / 4.0

    _NF4_PASSTHROUGH = {"fusion", "convert", "copy", "bitcast", "transpose",
                        "reshape", "all-gather", "all-reduce", "dynamic-slice"}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = fused.get(cname, False)
        # dataflow pass: tensors derived from packed NF4 codes (u8 ≥ 1 MB).
        # The fused nf4_matmul kernel eliminates every HBM round-trip of the
        # dequantized weight; we track the unpack→convert→gather→dot chain.
        nf4_derived: set = set()
        for ins in comp.instrs:
            has_u8 = any(
                comp.by_name[o].type_str.startswith("u8[")
                and _shape_bytes(comp.by_name[o].type_str) >= 1e6
                for o in ins.operands if o in comp.by_name)
            from_derived = (ins.op in _NF4_PASSTHROUGH and any(
                o in nf4_derived for o in ins.operands))
            if has_u8 or from_derived or "nf4_dequant" in ins.line:
                nf4_derived.add(ins.name)
        for ins in comp.instrs:
            if ins.op == "dot" or (ins.op == "convolution"):
                flops += m * _dot_flops(ins, comp)
            if ins.op in COLLECTIVE_OPS:
                ob = sum(_shape_bytes(comp.by_name[o].type_str, CAP)
                         for o in ins.operands if o in comp.by_name)
                raw = sum(_shape_bytes(comp.by_name[o].type_str)
                          for o in ins.operands if o in comp.by_name)
                if ob == 0.0:  # fall back to result size
                    ob = _shape_bytes(ins.type_str, CAP)
                    raw = _shape_bytes(ins.type_str)
                coll_bytes += m * ob
                coll_raw += m * raw
                coll_by_op[ins.op] += m * ob
                coll_count[ins.op] += int(m)
            if not in_fusion and ins.op not in _NO_TRAFFIC:
                out_dims = _first_dims(ins.type_str)
                if ins.op == "dynamic-update-slice" or (
                        ins.op == "fusion" and "dynamic-update-slice" in ins.line):
                    # in-place aliased write: read update + write slice only
                    upd = min((_shape_bytes(comp.by_name[o].type_str, CAP)
                               for o in ins.operands[:2] if o in comp.by_name),
                              default=_shape_bytes(ins.type_str, CAP))
                    moved = m * 2 * upd
                    raw_moved = moved * 2
                elif ins.op == "dynamic-slice":
                    moved = m * 2 * _shape_bytes(ins.type_str, CAP)
                    raw_moved = moved * 2
                else:
                    # scan-buffer pattern: an operand shaped exactly like the
                    # output (or vice versa) with one extra leading dim is a
                    # stacked layer buffer sliced/updated in place — count the
                    # slice, not the buffer (XLA aliases it).
                    ob = 0.0
                    raw_ob = 0.0
                    update_bytes = None   # output aliases a stacked buffer
                    for o in ins.operands:
                        src = comp.by_name.get(o)
                        if src is None:
                            continue
                        sdims = _first_dims(src.type_str)
                        if (out_dims and sdims and len(sdims) == len(out_dims) + 1
                                and sdims[1:] == out_dims):
                            ob += _shape_bytes(ins.type_str, CAP)     # slice read
                            raw_ob += _shape_bytes(ins.type_str)
                            continue
                        if (out_dims and sdims and len(out_dims) == len(sdims) + 1
                                and out_dims[1:] == sdims):
                            # update pattern: output IS the buffer (aliased);
                            # written bytes = the update slice, not the buffer
                            update_bytes = (_shape_bytes(src.type_str, CAP),
                                            _shape_bytes(src.type_str))
                            ob += update_bytes[0]
                            raw_ob += update_bytes[1]
                            continue
                        ob += _shape_bytes(src.type_str, CAP)
                        raw_ob += _shape_bytes(src.type_str)
                    out_b = (_shape_bytes(ins.type_str, CAP), _shape_bytes(ins.type_str))
                    if update_bytes is not None:
                        out_b = update_bytes
                    moved = m * (ob + out_b[0])
                    raw_moved = m * (raw_ob + out_b[1])
                if ins.op in _TRAFFIC_OPS:
                    traffic += moved
                    traffic_raw += raw_moved
                    if ins.name in nf4_derived:
                        nf4_traffic += moved
                    elif ins.op == "dot":
                        # kernel also eliminates the bf16 weight-side read
                        nf4_traffic += m * sum(
                            _shape_bytes(comp.by_name[o].type_str, CAP)
                            for o in ins.operands
                            if o in nf4_derived and o in comp.by_name)
                    if "attention_core" in ins.line:
                        attn_traffic += moved
                        if ins.op == "dot" and "bqhd,bkhd" in ins.line:
                            # flash-kernel HBM traffic ≈ read q,k,v + write o
                            # ≈ 2 × (qk-dot operand bytes)
                            ob = sum(_shape_bytes(comp.by_name[o].type_str, CAP)
                                     for o in ins.operands if o in comp.by_name)
                            attn_ideal += m * 2.0 * ob
                traffic_upper += moved

    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "attention_core_traffic_bytes": attn_traffic,
        "attention_flash_ideal_bytes": attn_ideal,
        "nf4_dequant_traffic_bytes": nf4_traffic,
        "traffic_flash_projected_bytes": (
            traffic - attn_traffic + attn_ideal
            - nf4_traffic * (1.0 - NF4_KERNEL_RATIO)),
        "traffic_raw_bytes": traffic_raw,
        "traffic_upper_bytes": traffic_upper,
        "collective_bytes": coll_bytes,
        "collective_raw_bytes": coll_raw,
        "collective_bytes_by_op": dict(coll_by_op),
        "collective_counts": dict(coll_count),
        "n_computations": len(comps),
    }


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link


def roofline_terms(analysis: Dict[str, float]) -> Dict[str, float]:
    """Per-chip seconds for each roofline term (HLO is per-device post-SPMD,
    so dividing local totals by per-chip rates equals the spec's
    global/(chips×rate) form)."""
    compute_s = analysis["flops"] / PEAK_FLOPS_BF16
    memory_s = analysis["traffic_bytes"] / HBM_BW
    collective_s = analysis["collective_bytes"] / ICI_BW
    bound = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda t: t[1],
    )[0]
    total = max(compute_s, memory_s, collective_s)
    out = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bound": bound,
        "roofline_fraction": compute_s / total if total else 0.0,
    }
    proj = analysis.get("traffic_flash_projected_bytes")
    if proj is not None and proj < analysis["traffic_bytes"]:
        mem_p = proj / HBM_BW
        total_p = max(compute_s, mem_p, collective_s)
        out["memory_s_flash"] = mem_p
        out["roofline_fraction_flash"] = compute_s / total_p if total_p else 0.0
        out["bound_flash"] = max(
            ("compute", compute_s), ("memory", mem_p),
            ("collective", collective_s), key=lambda t: t[1])[0]
    return out

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

No real allocation ever happens for the FULL configs: parameters, adapters,
optimizer state and caches are ShapeDtypeStructs from ``jax.eval_shape``; the
proof artifacts are ``compiled.memory_analysis()`` (fits-per-device) and the
parsed HLO (FLOPs / traffic / collective bytes for §Roofline).

Cells:
  * train_4k      → LoRAM online train_step on the PRUNED (+NF4) base
                    (the paper trains small …)
  * prefill_32k / decode_32k / long_500k
                  → serve steps on the FULL model with merged adapters
                    (… and infers large).

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""
from __future__ import annotations

import os

# MUST precede any jax import — jax locks the device count at first init.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LoRAConfig, LoRAMConfig, TrainConfig
from repro.configs.registry import ARCHS, SHAPES, cell_applicable
from repro.core import pruning
from repro.core.loram import quantize_base
from repro.distributed import sharding
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.model import (
    Plan, init_cache, init_lora, init_params, make_plan)
from repro.optim.adamw import adamw_init
from repro.runtime.steps import make_decode_step, make_prefill_step, make_train_step

KEY_STRUCT = jax.ShapeDtypeStruct((2,), jnp.uint32)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def frontend_struct(cfg, batch: int) -> Optional[jax.ShapeDtypeStruct]:
    if cfg.family == "encdec":
        return jax.ShapeDtypeStruct((batch, cfg.enc_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return None


def input_specs(arch: str, shape: str, variant: str = "qloram",
                lora_rank: int = 8) -> Dict[str, Any]:
    """Build all ShapeDtypeStructs for one cell (no device allocation)."""
    cfg = ARCHS[arch]
    sh = SHAPES[shape]
    plan = make_plan(cfg)
    out: Dict[str, Any] = {"cfg": cfg, "shape": dict(sh), "kind": sh["kind"]}
    lora_cfg = LoRAConfig(rank=lora_rank)
    out["lora_cfg"] = lora_cfg

    if sh["kind"] == "train":
        # LoRAM: derive the pruned (small) training plan
        if variant == "lora":
            small_plan = plan
        else:
            loram_cfg = LoRAMConfig(method="rand", ratio=0.65,
                                    quantize=(variant == "qloram"))
            scores = pruning.random_scores(plan, seed=0)
            small_plan, _spec = pruning.build_structured_spec(plan, loram_cfg, scores)
        quant = variant == "qloram"

        def build_base(k):
            p = init_params(small_plan, k, jnp.bfloat16)
            return quantize_base(p) if quant else p

        out["plan"] = small_plan
        out["base"] = jax.eval_shape(build_base, KEY_STRUCT)
        out["lora"] = jax.eval_shape(
            lambda k: init_lora(small_plan, lora_cfg, k), KEY_STRUCT)
        out["opt"] = jax.eval_shape(adamw_init, out["lora"])
        B, S = sh["global_batch"], sh["seq_len"]
        text_s = S - (cfg.n_patches if cfg.family == "vlm" else 0)
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, text_s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, text_s), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((B, text_s), jnp.float32),
        }
        fe = frontend_struct(cfg, B)
        if fe is not None:
            batch["frontend"] = fe
        out["batch"] = batch
        out["step"] = jax.ShapeDtypeStruct((), jnp.int32)
        return out

    # serving cells: full model.  Default = merged adapters (the paper's
    # inference mode, Eq. 7).  variant="qserve" = beyond-paper weight-only
    # NF4 serving: quantized FULL base + recovered adapters unmerged (the
    # nf4_matmul kernel path) — divides decode's dominant weight-read bytes
    # by ~3.8 at the cost of the rank-r adapter matmuls.
    out["plan"] = plan
    if variant == "qserve":
        out["base"] = jax.eval_shape(
            lambda k: quantize_base(init_params(plan, k, jnp.bfloat16)),
            KEY_STRUCT)
        out["lora"] = jax.eval_shape(
            lambda k: init_lora(plan, lora_cfg, k), KEY_STRUCT)
    else:
        out["base"] = jax.eval_shape(
            lambda k: init_params(plan, k, jnp.bfloat16), KEY_STRUCT)
    B, S = sh["global_batch"], sh["seq_len"]
    out["cache"] = jax.eval_shape(
        lambda: init_cache(plan, B, S, jnp.bfloat16))
    if sh["kind"] == "prefill":
        text_s = S - (cfg.n_patches if cfg.family == "vlm" else 0)
        out["tokens"] = jax.ShapeDtypeStruct((B, text_s), jnp.int32)
        fe = frontend_struct(cfg, B)
        if fe is not None:
            out["frontend"] = fe
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Lower + compile one cell
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape: str, mesh, *, variant: str = "qloram",
               seq_shard: bool = True, fsdp: bool = True,
               n_micro: Optional[int] = None,
               head_shard: Optional[bool] = None):
    spec = input_specs(arch, shape, variant)
    cfg, plan, kind = spec["cfg"], spec["plan"], spec["kind"]
    dp = sharding.dp_size(mesh)

    # head-sharded attention activations: measured 11× collective win for
    # serving (no seq-sharding to fight) but a net loss for training
    # (§Perf iterations 3/5) — default ON for serve, OFF for train.
    if head_shard is None:
        head_shard = kind != "train"
    sharding.install_residual_constraint()
    with sharding.use_mesh(mesh, seq_shard=seq_shard and kind == "train",
                           head_shard=head_shard):
        base_sh = sharding.to_shardings(
            sharding.param_specs(spec["base"], mesh, fsdp=fsdp), mesh)
        if kind == "train":
            B = spec["shape"]["global_batch"]
            nm = n_micro if n_micro is not None else max(1, B // dp)
            tc = TrainConfig(global_batch=B, seq_len=spec["shape"]["seq_len"],
                             remat=True)
            step_fn = make_train_step(plan, tc, spec["lora_cfg"], n_micro=nm)
            lora_sh = sharding.to_shardings(
                sharding.param_specs(spec["lora"], mesh, fsdp=False), mesh)
            opt_sh = sharding.to_shardings(
                sharding.opt_specs(
                    sharding.param_specs(spec["lora"], mesh, fsdp=False),
                    spec["opt"]), mesh)
            batch_sh = sharding.to_shardings(
                sharding.batch_specs(spec["batch"], mesh), mesh)
            step_sh = sharding.to_shardings(
                jax.tree.map(lambda _: jax.sharding.PartitionSpec(), spec["step"]), mesh)
            jitted = jax.jit(
                step_fn,
                in_shardings=(base_sh, lora_sh, opt_sh, step_sh, batch_sh),
                out_shardings=(lora_sh, opt_sh, None),
                donate_argnums=(1, 2))
            lowered = jitted.lower(spec["base"], spec["lora"], spec["opt"],
                                   spec["step"], spec["batch"])
        elif kind == "prefill":
            with_lora = "lora" in spec
            step_fn = make_prefill_step(plan, with_lora=with_lora,
                                        lora_scale=spec["lora_cfg"].scale)
            cache_sh = sharding.to_shardings(
                sharding.cache_specs(spec["cache"], mesh), mesh)
            tok_sh = sharding.to_shardings(
                sharding.batch_specs({"t": spec["tokens"]}, mesh)["t"], mesh)
            args = [spec["base"], spec["tokens"], spec["cache"]]
            in_sh = [base_sh, tok_sh, cache_sh]
            donate = 2
            if with_lora:
                lora_sh = sharding.to_shardings(
                    sharding.param_specs(spec["lora"], mesh, fsdp=False), mesh)
                args.insert(1, spec["lora"])
                in_sh.insert(1, lora_sh)
                donate = 3
            if "frontend" in spec:
                args.append(spec["frontend"])
                in_sh.append(sharding.to_shardings(
                    sharding.batch_specs({"f": spec["frontend"]}, mesh)["f"], mesh))
            jitted = jax.jit(step_fn, in_shardings=tuple(in_sh),
                             donate_argnums=(donate,))
            lowered = jitted.lower(*args)
        else:  # decode
            with_lora = "lora" in spec
            step_fn = make_decode_step(plan, with_lora=with_lora,
                                       lora_scale=spec["lora_cfg"].scale)
            cache_sh = sharding.to_shardings(
                sharding.cache_specs(spec["cache"], mesh), mesh)
            tok_sh = sharding.to_shardings(
                sharding.batch_specs({"t": spec["token"]}, mesh)["t"], mesh)
            pos_sh = sharding.to_shardings(jax.sharding.PartitionSpec(), mesh)
            if with_lora:
                lora_sh = sharding.to_shardings(
                    sharding.param_specs(spec["lora"], mesh, fsdp=False), mesh)
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(base_sh, lora_sh, tok_sh, cache_sh, pos_sh),
                    donate_argnums=(3,))
                lowered = jitted.lower(spec["base"], spec["lora"],
                                       spec["token"], spec["cache"], spec["pos"])
            else:
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(base_sh, tok_sh, cache_sh, pos_sh),
                    donate_argnums=(2,))
                lowered = jitted.lower(spec["base"], spec["token"],
                                       spec["cache"], spec["pos"])
    return lowered, spec


def analyze_cell(arch: str, shape: str, *, multi_pod: bool = False,
                 variant: str = "qloram", seq_shard: bool = True,
                 fsdp: bool = True, n_micro: Optional[int] = None,
                 head_shard: Optional[bool] = None,
                 keep_text: bool = False) -> Dict[str, Any]:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, spec = lower_cell(arch, shape, mesh, variant=variant,
                               seq_shard=seq_shard, fsdp=fsdp, n_micro=n_micro,
                               head_shard=head_shard)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    text = compiled.as_text()
    hlo = hlo_analysis.analyze(text)
    terms = hlo_analysis.roofline_terms(hlo)

    n_devices = int(np.prod(list(mesh.shape.values())))
    cfg = spec["cfg"]
    result = {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": dict(mesh.shape), "n_devices": n_devices,
        "kind": spec["kind"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(mem.peak_memory_in_bytes),
            "total_per_device_gib": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 2**30, 3),
        },
        "hlo": {k: v for k, v in hlo.items() if k != "collective_bytes_by_op"},
        "collective_bytes_by_op": hlo["collective_bytes_by_op"],
        "roofline": terms,
    }
    if keep_text:
        result["hlo_text"] = text
    return result


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_all(out_path: str, *, archs=None, shapes=None, meshes=("single", "multi"),
            variant: str = "qloram"):
    results: Dict[str, Any] = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    archs = archs or list(ARCHS)
    shapes = shapes or list(SHAPES)
    for arch in archs:
        if arch not in ARCHS:
            continue
        for shape in shapes:
            ok, why = cell_applicable(arch, shape)
            for mesh_kind in meshes:
                key = f"{arch}|{shape}|{mesh_kind}"
                if key in results and results[key].get("status") in ("ok", "skip"):
                    print(f"[dryrun] {key}: cached ({results[key]['status']})",
                          flush=True)
                    continue
                if not ok:
                    results[key] = {"status": "skip", "reason": why}
                    _save(out_path, results)
                    print(f"[dryrun] {key}: SKIP ({why})", flush=True)
                    continue
                print(f"[dryrun] {key}: lowering...", flush=True)
                try:
                    r = analyze_cell(arch, shape, multi_pod=(mesh_kind == "multi"),
                                     variant=variant)
                    r["status"] = "ok"
                    results[key] = r
                    rt = r["roofline"]
                    print(f"[dryrun] {key}: OK compile={r['compile_s']}s "
                          f"mem/dev={r['memory']['total_per_device_gib']}GiB "
                          f"bound={rt['bound']} "
                          f"c/m/x={rt['compute_s']:.4f}/{rt['memory_s']:.4f}/"
                          f"{rt['collective_s']:.4f}s", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    results[key] = {"status": "error", "error": repr(e),
                                    "traceback": traceback.format_exc()[-3000:]}
                    print(f"[dryrun] {key}: ERROR {e!r}", flush=True)
                _save(out_path, results)
    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    n_skip = sum(1 for v in results.values() if v.get("status") == "skip")
    n_err = sum(1 for v in results.values() if v.get("status") == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_err} error", flush=True)
    return results


def _save(path, results):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="qloram",
                    choices=["qloram", "loram", "lora"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--meshes", default="single,multi")
    args = ap.parse_args()

    if args.all:
        run_all(args.out,
                archs=[args.arch] if args.arch else None,
                shapes=[args.shape] if args.shape else None,
                meshes=tuple(args.meshes.split(",")), variant=args.variant)
        return

    r = analyze_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                     variant=args.variant)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(json.dumps({k: v for k, v in r.items() if k != "hlo_text"}, indent=2))


if __name__ == "__main__":
    main()

"""Production mesh construction.

A v5e pod is 16×16 = 256 chips; ``multi_pod=True`` prepends a ``pod`` axis
(2 pods = 512 chips for the dry-run; the same function generalizes to N pods
for 1000+-node deployments — the pod axis is pure data parallelism whose
per-step traffic under LoRAM is only the rank-r adapter gradients).

Defined as functions (never module-level constants) so importing this module
never touches JAX device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/smoke."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))


def make_serve_mesh(data: int = 1, model: int = 1):
    """The serving engines' ``data × model`` mesh (ServeConfig.mesh_data /
    mesh_model, or ``launch/serve.py --mesh data,model``).  Unlike
    :func:`make_host_mesh` this REFUSES to silently clamp: a serving
    deployment that asks for more chips than exist is a config error, not
    something to paper over with a smaller (differently-sharded) grid."""
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1 (got {data}x{model})")
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(
            f"serve mesh {data}x{model} needs {data * model} devices but "
            f"only {n} exist (hint: XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N for CPU smoke runs)")
    return jax.make_mesh((data, model), ("data", "model"))

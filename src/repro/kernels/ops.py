"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the Pallas kernel runs natively; everywhere
else (this CPU container, the dry-run) the pure-jnp oracle executes instead
— same signature, same numerics (the oracles ARE the reference the kernels
are tested against in tests/test_kernels.py).  ``force='pallas'`` runs the
kernel in interpret mode for validation.

Mesh dispatch (PR 6): when the calling thread is inside a
``sharding.use_mesh`` scope whose ``model`` axis divides both head counts,
the PAGED ops run PER-SHARD under ``shard_map`` — each model-parallel shard
executes the whole kernel (Pallas on TPU, the jnp oracle elsewhere) on its
own contiguous block of heads against its slice of the page pools.  Head-
axis sharding keeps every (slot, head) attention wholly on one shard, so the
per-shard math is bit-identical to the unsharded op; outside a mesh scope
(or when heads don't divide) the unsharded op runs and GSPMD is free to
partition it however the surrounding jit demands.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as _dist
from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.nf4_matmul import nf4_matmul as _nf4_pallas
from repro.kernels.paged_attention import (
    paged_chunk_attention as _paged_chunk_pallas,
    paged_decode_attention as _paged_pallas)
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _tp_mesh(n_q: int, n_kv: int):
    """The active mesh IF per-shard paged-kernel dispatch is eligible: a
    ``model`` axis > 1 dividing BOTH the query and kv head counts, so each
    shard holds whole contiguous GQA groups (q head h reads kv head
    ``h // (n_q // n_kv)`` — equal splits keep every group local).  Returns
    None otherwise; the caller then emits the unsharded op."""
    mesh = _dist.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    m = mesh.shape["model"]
    if m == 1 or n_q % m or n_kv % m:
        return None
    return mesh


def nf4_matmul(x, codes, scales, *, out_dtype=jnp.float32,
               force: Optional[str] = None):
    """y = x @ dequant_nf4(codes, scales).  x: (M, K) → (M, N)."""
    if force == "pallas" or (force is None and _on_tpu()):
        return _nf4_pallas(x, codes, scales, out_dtype=out_dtype,
                           interpret=not _on_tpu())
    return _ref.nf4_matmul_ref(x, codes, scales, out_dtype=out_dtype)


def flash_attention(q, k, v, *, causal: bool = True, sm_scale=None,
                    force: Optional[str] = None):
    """q,k,v: (B, H, S, D) → (B, H, S, D); blocked online-softmax on TPU."""
    if force == "pallas" or (force is None and _on_tpu()):
        return _flash_pallas(q, k, v, causal=causal, sm_scale=sm_scale,
                             interpret=not _on_tpu())
    return _ref.flash_attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)


def _paged_decode_local(q, pool_k, pool_v, table, pos, k_scale, v_scale,
                        *, window, force):
    if force == "pallas" or (force is None and _on_tpu()):
        return _paged_pallas(q, pool_k, pool_v, table, pos,
                             k_scale=k_scale, v_scale=v_scale, window=window,
                             interpret=not _on_tpu())
    return _ref.paged_decode_attention_ref(q, pool_k, pool_v, table, pos,
                                           k_scale=k_scale, v_scale=v_scale,
                                           window=window)


def paged_decode_attention(q, pool_k, pool_v, table, pos, *,
                           k_scale=None, v_scale=None, window: int = 0,
                           force: Optional[str] = None):
    """Single-token attention through a paged KV cache.  q: (B, H, D);
    pools: (n_pages, page, K, D); table: (B, R) page ids; pos: (B,).
    int8 pools pass their per-row scale pools (n_pages, page, K, 1) as
    ``k_scale``/``v_scale``; dequantization then happens in-kernel.
    Inside an eligible mesh scope the kernel runs per model-parallel shard
    (heads split, scale pools sharded with their pools, block table /
    positions replicated)."""
    mesh = _tp_mesh(q.shape[1], pool_k.shape[2])
    quant = k_scale is not None
    if mesh is not None:
        heads = P(None, "model", None)
        pool = P(None, None, "model", None)
        args = (q, pool_k, pool_v, table, pos)
        specs = (heads, pool, pool, P(None, None), P(None))
        if quant:
            args += (k_scale, v_scale)
            specs += (pool, pool)     # scales shard WITH their pools (K axis)
        fn = (lambda q, pk, pv, t, p, ks=None, vs=None:
              _paged_decode_local(q, pk, pv, t, p, ks, vs,
                                  window=window, force=force))
        return shard_map(fn, mesh=mesh, in_specs=specs,
                         out_specs=heads, check_rep=False)(*args)
    return _paged_decode_local(q, pool_k, pool_v, table, pos,
                               k_scale, v_scale, window=window, force=force)


def _paged_chunk_local(q, k_new, v_new, pool_k, pool_v, table, pos,
                       k_scale, v_scale, *, window, force):
    if force == "pallas" or (force is None and _on_tpu()):
        return _paged_chunk_pallas(q, k_new, v_new, pool_k, pool_v, table,
                                   pos, k_scale=k_scale, v_scale=v_scale,
                                   window=window, interpret=not _on_tpu())
    return _ref.paged_chunk_attention_ref(q, k_new, v_new, pool_k, pool_v,
                                          table, pos, k_scale=k_scale,
                                          v_scale=v_scale, window=window)


def paged_chunk_attention(q, k_new, v_new, pool_k, pool_v, table, pos, *,
                          k_scale=None, v_scale=None, window: int = 0,
                          force: Optional[str] = None):
    """Chunk-query attention through a paged KV cache (chunked prefill):
    q: (B, C, H, D) at positions pos..pos+C-1; k_new/v_new: (B, C, K, D)
    the chunk's own keys/values (always fp — they are quantized at the
    scatter AFTER the call); pools: (n_pages, page, K, D); table: (B, R)
    page ids; pos: (B,).  int8 pools pass per-row scale pools
    (n_pages, page, K, 1) as ``k_scale``/``v_scale``.  Inside an eligible
    mesh scope the kernel runs per model-parallel shard (heads split,
    scale pools sharded with their pools, table/pos replicated)."""
    mesh = _tp_mesh(q.shape[2], pool_k.shape[2])
    quant = k_scale is not None
    if mesh is not None:
        qh = P(None, None, "model", None)
        kv = P(None, None, "model", None)
        pool = P(None, None, "model", None)
        args = (q, k_new, v_new, pool_k, pool_v, table, pos)
        specs = (qh, kv, kv, pool, pool, P(None, None), P(None))
        if quant:
            args += (k_scale, v_scale)
            specs += (pool, pool)     # scales shard WITH their pools (K axis)
        fn = (lambda q, kn, vn, pk, pv, t, p, ks=None, vs=None:
              _paged_chunk_local(q, kn, vn, pk, pv, t, p, ks, vs,
                                 window=window, force=force))
        return shard_map(fn, mesh=mesh, in_specs=specs,
                         out_specs=qh, check_rep=False)(*args)
    return _paged_chunk_local(q, k_new, v_new, pool_k, pool_v, table, pos,
                              k_scale, v_scale, window=window, force=force)


def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk: int = 128,
             force: Optional[str] = None):
    """Mamba2 SSD scan.  Returns (y, h_final: (B, H, P, N))."""
    if force == "pallas" or (force is None and _on_tpu()):
        return _ssd_pallas(x, dt, a, b_mat, c_mat, chunk=chunk,
                           interpret=not _on_tpu())
    return _ref.ssd_scan_ref(x, dt, a, b_mat, c_mat, chunk=chunk)

"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as a triple:
  <name>.py — pl.pallas_call + BlockSpec VMEM tiling (TPU target)
  ref.py    — pure-jnp oracle (also the CPU/dry-run execution path)
  ops.py    — jit'd public wrappers with interpret fallback

Kernels:
  nf4_matmul      — fused NF4 dequant → MXU matmul (QLoRAM base-weight path)
  flash_attention — blocked online-softmax attention (train/prefill)
  ssd_scan        — Mamba2 state-space-duality chunked scan
  paged_attention — paged-KV decode attention (block table as the
                    scalar-prefetch index map; serving hot loop)
"""

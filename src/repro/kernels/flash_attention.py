"""Blocked online-softmax attention (FlashAttention) for TPU.

The training/prefill hot spot: without it, S×S score tensors materialize in
HBM (the dominant memory-roofline term for train_4k / prefill_32k cells —
see EXPERIMENTS.md §Roofline).  TPU-native shape of the idea:

  * grid (B·H, S/bq, S/bk), K innermost; the (bq, d) output accumulator,
    running row-max m and denominator l live in VMEM scratch across the
    K sweep (no HBM round-trip);
  * q·kᵀ tile (bq, bk) on the MXU, rescale-and-accumulate on the VPU;
  * causal masking by tile: fully-masked K tiles are skipped via
    ``pl.when`` (upper-triangle tiles cost nothing — this is the 2×
    FLOP saving over dense causal attention).

Block defaults (bq=bk=512, d≤256): VMEM ≈ bq·d·4 + bk·d·2·2 + bq·bk·4
≈ 2.6 MB at d=128 — comfortably double-bufferable on v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq: int, bk: int, n_k: int, causal: bool, sm_scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # tile-level causal skip: K tile strictly above the diagonal → no work
    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0]                                  # (bq, d)
        k = k_ref[0]                                  # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                           # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "sm_scale",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, sm_scale=None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False):
    """q,k,v: (B, H, S, D) → (B, H, S, D)."""
    B, H, S, D = q.shape
    scale = float(sm_scale if sm_scale is not None else 1.0 / (D ** 0.5))
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0
    n_k = S // bk
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, n_k=n_k, causal=causal,
                          sm_scale=scale),
        grid=(B * H, S // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)

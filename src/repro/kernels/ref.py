"""Pure-jnp oracles for every Pallas kernel (and the CPU execution path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.nf4 import NF4_CODEBOOK


# ---------------------------------------------------------------------------
# nf4_matmul
# ---------------------------------------------------------------------------

def nf4_matmul_ref(x, codes, scales, block: int = 64, out_dtype=jnp.float32):
    """y = x @ dequant(codes, scales).

    x: (M, K) float; codes: (K//2, N) uint8 (two 4-bit codes per byte along
    K); scales: (K//block, N).
    """
    K = codes.shape[0] * 2
    N = codes.shape[1]
    lo = (codes & 0x0F).astype(jnp.int32)
    hi = (codes >> 4).astype(jnp.int32)
    idx = jnp.stack([lo, hi], axis=1).reshape(K, N)
    cb = jnp.asarray(NF4_CODEBOOK, jnp.float32)
    w = cb[idx].reshape(K // block, block, N) * scales.astype(jnp.float32)[:, None, :]
    w = w.reshape(K, N)
    return jnp.matmul(x.astype(jnp.float32), w).astype(out_dtype)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True, sm_scale=None):
    """q,k,v: (B, H, S, D) → (B, H, S, D).  Plain softmax attention."""
    B, H, S, D = q.shape
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged_decode_attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gather_pool(pool, scale, table, B, S, K, D):
    """Gather a slot's pages from the pool; int8 pools (``scale`` given,
    (n_pages, page, K, 1)) dequantize against their per-row absmax scales —
    the same reconstruction as the in-kernel ``_load_page``."""
    c = pool[table].reshape(B, S, K, D)
    if scale is not None:
        c = (c.astype(jnp.float32)
             * scale[table].reshape(B, S, K, 1).astype(jnp.float32))
    return c


def paged_decode_attention_ref(q, pool_k, pool_v, table, pos, *,
                               k_scale=None, v_scale=None, window: int = 0):
    """Single-token attention against a PAGED K/V cache (gather-then-flash).

    q: (B, H, D) — the new token's roped query per slot;
    pool_k/pool_v: (n_pages, page, K, D) — the global page pool;
    table: (B, R) int32 — each slot's block table, already sliced to the
    layer's ring pages (R·page == max_seq_len for full attention, a bounded
    ring ≥ window for sliding-window layers);
    pos: (B,) int32 — the position the new token was just written at.

    The gathered virtual cache is position-linear for full attention
    (token slot == position) and a ring of length R·page for windowed
    layers, so validity masking matches the dense decode path exactly:
    numerics are identical to attending a dense per-slot cache.
    """
    B, H, D = q.shape
    page = pool_k.shape[1]
    K = pool_k.shape[2]
    S = table.shape[1] * page
    ck = _gather_pool(pool_k, k_scale, table, B, S, K, D)
    cv = _gather_pool(pool_v, v_scale, table, B, S, K, D)
    karange = jnp.arange(S)
    if window:
        # ring semantics: each token slot holds the largest position <= pos
        # congruent to it mod S; out-of-window survivors are masked off
        # (the ring may be up to a page larger than the window)
        kpos = pos[:, None] - ((pos[:, None] - karange[None, :]) % S)
        valid = (kpos >= 0) & (kpos > pos[:, None] - window)
    else:
        valid = karange[None, :] <= pos[:, None]
    gs = H // K
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, K, gs, D)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, ck).astype(jnp.float32) * scale
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cv)
    return out.reshape(B, H, D)


def paged_chunk_attention_ref(q, k_new, v_new, pool_k, pool_v, table, pos, *,
                              k_scale=None, v_scale=None, window: int = 0):
    """Chunk-query attention against a PAGED K/V cache (chunked prefill).

    q: (B, C, H, D) — the chunk's roped queries at absolute positions
    ``pos .. pos+C-1`` per slot;
    k_new/v_new: (B, C, K, D) — the chunk's own K/V (NOT yet in the pool;
    the caller scatters them into pages after the call);
    pool_k/pool_v: (n_pages, page, K, D) — the global page pool holding the
    slot's ALREADY-COMMITTED positions ``< pos``;
    table: (B, R) int32 — each slot's block table, already sliced to the
    layer's ring pages;
    pos: (B,) int32 — the absolute position of the chunk's first token.

    Each query attends (a) the committed pages through the block table,
    masked exactly like the decode path (ring interpretation for windowed
    layers — only positions the slot actually wrote are ever valid, so
    stale pool garbage in freshly-allocated pages contributes nothing), and
    (b) the chunk's own keys causally (within the sliding window when set).
    """
    B, C, H, D = q.shape
    page = pool_k.shape[1]
    K = pool_k.shape[2]
    S = table.shape[1] * page
    ck = _gather_pool(pool_k, k_scale, table, B, S, K, D)
    cv = _gather_pool(pool_v, v_scale, table, B, S, K, D)
    karange = jnp.arange(S)
    qpos = pos[:, None] + jnp.arange(C)[None, :]                   # (B, C)
    # absolute position held by each ring slot before this chunk ran
    last = pos[:, None] - 1
    slot_pos = last - ((last - karange[None, :]) % S)              # (B, S)
    valid_old = jnp.broadcast_to((slot_pos >= 0)[:, None, :], (B, C, S))
    if window:
        valid_old = valid_old & (slot_pos[:, None, :]
                                 > qpos[:, :, None] - window)
    cidx = jnp.arange(C)
    blk = cidx[None, :] <= cidx[:, None]                           # (Cq, Ck)
    if window:
        blk = blk & (cidx[None, :] > cidx[:, None] - window)
    gs = H // K
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, C, K, gs, D).transpose(0, 2, 3, 1, 4)        # (B,K,G,C,D)
    lo = jnp.einsum("bkgcd,bskd->bkgcs", qg,
                    ck.astype(qg.dtype)).astype(jnp.float32) * scale
    lb = jnp.einsum("bkgcd,bjkd->bkgcj", qg,
                    k_new.astype(qg.dtype)).astype(jnp.float32) * scale
    lo = jnp.where(valid_old[:, None, None], lo, NEG_INF)
    lb = jnp.where(blk[None, None, None], lb, NEG_INF)
    probs = jax.nn.softmax(jnp.concatenate([lo, lb], axis=-1), axis=-1)
    po = probs[..., :S].astype(cv.dtype)
    pb = probs[..., S:].astype(v_new.dtype)
    out = (jnp.einsum("bkgcs,bskd->bkgcd", po, cv)
           + jnp.einsum("bkgcj,bjkd->bkgcd", pb, v_new))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, C, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# ssd_scan (Mamba2 chunked state-space duality)
# ---------------------------------------------------------------------------

def ssd_scan_ref(x, dt, a, b_mat, c_mat, chunk: int = 64):
    """Sequential (exact) SSD recurrence — the oracle for both the chunked
    jnp path (models/ssm.py) and the Pallas kernel.

    x: (B, S, H, P); dt: (B, S, H); a: (H,); b/c: (B, S, N).
    Returns (y: (B, S, H, P), h_final: (B, H, P, N)).
    """
    B, S, H, P = x.shape
    N = b_mat.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a)[:, :, None, None]            # (B,H,1,1)
        dx = (dtt[..., None] * xt).astype(jnp.float32)        # (B,H,P)
        h = h * decay + jnp.einsum("bn,bhp->bhpn", bt.astype(jnp.float32), dx)
        y = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          b_mat.transpose(1, 0, 2), c_mat.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h_final

"""Paged decode attention for TPU (gather-then-flash).

The serving hot loop under a paged KV cache: each slot's K/V lives in
scattered fixed-size pages of a global pool, indexed by a per-slot block
table.  Materializing the gather in HBM ((B, S, K, D) per layer per token)
would double decode's cache traffic; instead the BLOCK TABLE IS THE INDEX
MAP — the table and positions ride in as scalar-prefetch operands, and each
grid step's page block is DMA'd straight from its pool slot into VMEM:

  * grid (B, R): slot-major, the slot's R ring pages swept innermost;
  * per page: q·Kᵀ on the MXU per KV head (GQA grouped — the query block
    (K, G, D) contracts against the page (K, page, D) without expanding to
    H heads), online-softmax accumulate on the VPU;
  * position validity (ring interpretation for windowed layers, simple
    ``slot <= pos`` for full attention) folds into the accumulate mask, so
    trash-page garbage and not-yet-written page tails contribute exactly 0;
  * accumulator, running max and denominator live in VMEM scratch across
    the page sweep — one HBM write per slot at flush.

VMEM per step ≈ page·K·D·2·bytes + H·D·4 — a few tens of KB at serving
shapes; the kernel is bandwidth-bound on the page reads, which is the
point: it reads each page exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _load_page(ref, sc_ref):
    """One pool page from VMEM — int8 codes dequantize against their
    per-row absmax scales ((page, K, 1), broadcast over D) exactly like
    ``repro.quant.kv.dequantize_rows``, so in-kernel and gather-site
    readers reconstruct bit-identical values."""
    x = ref[0]                                     # (page, K, D)
    if sc_ref is not None:
        return x.astype(jnp.float32) * sc_ref[0].astype(jnp.float32)
    return x


def _kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, *rest, page: int,
            n_r: int, window: int, scale: float, groups: int, quant: bool):
    if quant:
        ksc_ref, vsc_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        ksc_ref = vsc_ref = None
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # (H, D)
    k = _load_page(k_ref, ksc_ref)                 # (page, K, D)
    v = _load_page(v_ref, vsc_ref)
    H, D = q.shape
    K = k.shape[1]
    qg = q.reshape(K, groups, D)
    kk = jnp.swapaxes(k, 0, 1)                     # (K, page, D)
    vv = jnp.swapaxes(v, 0, 1)
    s = lax.dot_general(
        qg.astype(jnp.float32), kk.astype(jnp.float32),
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale        # (K, G, page)

    pos_b = pos_ref[b]
    idx = r * page + lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    if window:
        ring = n_r * page
        kpos = pos_b - ((pos_b - idx) % ring)
        valid = (kpos >= 0) & (kpos > pos_b - window)
    else:
        valid = idx <= pos_b

    m_prev = m_ref[...]                            # (K, G, 1)
    m_cur = jnp.max(jnp.where(valid, s, NEG_INF), axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # explicit zeroing (not exp of a masked -1e30) keeps fully-masked pages
    # — trash pages, out-of-window rings — at exactly zero weight even while
    # the running max is still NEG_INF
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)  # (K, G, page)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = lax.dot_general(
        p.astype(jnp.float32), vv.astype(jnp.float32),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)        # (K, G, D)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(r == n_r - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).reshape(H, D).astype(o_ref.dtype)


def _chunk_kernel(tbl_ref, pos_ref, q_ref, kc_ref, vc_ref, k_ref, v_ref,
                  *rest, page: int, n_r: int, chunk: int, window: int,
                  scale: float, groups: int, quant: bool):
    if quant:
        ksc_ref, vsc_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        ksc_ref = vsc_ref = None
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # (C, H, D)
    C, H, D = q.shape
    K = k_ref.shape[2]
    qg = q.reshape(C, K, groups, D).transpose(1, 2, 0, 3)   # (K, G, C, D)
    pos_b = pos_ref[b]
    qpos = pos_b + lax.broadcasted_iota(jnp.int32, (chunk, page), 0)

    def accumulate(s, valid):
        """One online-softmax update; s, valid: (K, G, C, L)."""
        m_prev = m_ref[...]                        # (K, G, C, 1)
        m_cur = jnp.max(jnp.where(valid, s, NEG_INF), axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # explicit zeroing (not exp of a masked -1e30) keeps fully-masked
        # pages — trash pages, positions ahead of the chunk — at exactly
        # zero weight even while the running max is still NEG_INF
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        return p, alpha

    @pl.when(r < n_r)
    def _pool_page():
        k = _load_page(k_ref, ksc_ref)             # (page, K, D)
        v = _load_page(v_ref, vsc_ref)
        kk = jnp.swapaxes(k, 0, 1)                 # (K, page, D)
        vv = jnp.swapaxes(v, 0, 1)
        s = lax.dot_general(
            qg.reshape(K, groups * chunk, D).astype(jnp.float32),
            kk.astype(jnp.float32),
            (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32).reshape(
                K, groups, chunk, page) * scale
        idx = r * page + lax.broadcasted_iota(jnp.int32, (chunk, page), 1)
        if window:
            # ring interpretation: slot idx holds the largest committed
            # position <= pos_b-1 congruent to it mod the ring length
            ring = n_r * page
            kpos = (pos_b - 1) - ((pos_b - 1 - idx) % ring)
            valid = (kpos >= 0) & (kpos > qpos - window)
        else:
            valid = idx < pos_b
        p, alpha = accumulate(s, valid[None, None])
        pv = lax.dot_general(
            p.reshape(K, groups * chunk, page).astype(jnp.float32),
            vv.astype(jnp.float32),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32).reshape(
                K, groups, chunk, D)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(r == n_r)
    def _in_chunk():
        k = kc_ref[0]                              # (C, K, D)
        v = vc_ref[0]
        kk = jnp.swapaxes(k, 0, 1)                 # (K, C, D)
        vv = jnp.swapaxes(v, 0, 1)
        s = lax.dot_general(
            qg.reshape(K, groups * chunk, D).astype(jnp.float32),
            kk.astype(jnp.float32),
            (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32).reshape(
                K, groups, chunk, chunk) * scale
        ci = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        cj = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        blk = cj <= ci
        if window:
            blk = blk & (cj > ci - window)
        p, alpha = accumulate(s, blk[None, None])
        pv = lax.dot_general(
            p.reshape(K, groups * chunk, chunk).astype(jnp.float32),
            vv.astype(jnp.float32),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32).reshape(
                K, groups, chunk, D)
        acc_ref[...] = acc_ref[...] * alpha + pv
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = ((acc_ref[...] / denom)
                    .transpose(2, 0, 1, 3).reshape(C, H, D)
                    .astype(o_ref.dtype))


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_chunk_attention(q, k_new, v_new, pool_k, pool_v, table, pos, *,
                          k_scale=None, v_scale=None, window: int = 0,
                          interpret: bool = False):
    """Chunk-query variant for chunked prefill: q (B, C, H, D) at positions
    ``pos .. pos+C-1`` attends the slot's committed pages (the same block
    table / online-softmax sweep as the decode kernel, swept per page) plus
    the chunk's own K/V ``(B, C, K, D)`` causally within the chunk — the
    final grid step.  Returns (B, C, H, D); the caller scatters the chunk
    K/V into pages afterwards.

    ``k_scale`` / ``v_scale`` ((n_pages, page, K, 1)) mark an int8 pool:
    committed pages dequantize in-kernel against their per-row scales; the
    chunk's own K/V stays fp."""
    B, C, H, D = q.shape
    _, page, K, _ = pool_k.shape
    R = table.shape[1]
    scale = 1.0 / (D ** 0.5)
    quant = k_scale is not None
    assert quant == (v_scale is not None), "k_scale/v_scale go together"

    def page_spec(width):
        # the final grid step re-DMAs the last page (its index map must
        # stay in range); the kernel never reads it there
        return pl.BlockSpec(
            (1, page, K, width),
            lambda b, r, tbl, p: (tbl[b, jnp.minimum(r, R - 1)], 0, 0, 0))

    in_specs = [
        pl.BlockSpec((1, C, H, D), lambda b, r, tbl, p: (b, 0, 0, 0)),
        pl.BlockSpec((1, C, K, D), lambda b, r, tbl, p: (b, 0, 0, 0)),
        pl.BlockSpec((1, C, K, D), lambda b, r, tbl, p: (b, 0, 0, 0)),
        page_spec(D),
        page_spec(D),
    ]
    operands = (table, pos, q, k_new, v_new, pool_k, pool_v)
    if quant:
        in_specs += [page_spec(1), page_spec(1)]
        operands += (k_scale, v_scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, R + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, C, H, D), lambda b, r, tbl, p: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, H // K, C, D), jnp.float32),
            pltpu.VMEM((K, H // K, C, 1), jnp.float32),
            pltpu.VMEM((K, H // K, C, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_chunk_kernel, page=page, n_r=R, chunk=C,
                          window=window, scale=scale, groups=H // K,
                          quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, H, D), q.dtype),
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, pool_k, pool_v, table, pos, *, k_scale=None,
                           v_scale=None, window: int = 0,
                           interpret: bool = False):
    """q: (B, H, D); pools: (n_pages, page, K, D); table: (B, R) int32 page
    ids (the layer's ring pages); pos: (B,) int32.  Returns (B, H, D).
    ``k_scale`` / ``v_scale`` ((n_pages, page, K, 1)) mark an int8 pool
    dequantized in-kernel against its per-row absmax scales."""
    B, H, D = q.shape
    _, page, K, _ = pool_k.shape
    R = table.shape[1]
    scale = 1.0 / (D ** 0.5)
    quant = k_scale is not None
    assert quant == (v_scale is not None), "k_scale/v_scale go together"

    def page_spec(width):
        return pl.BlockSpec((1, page, K, width),
                            lambda b, r, tbl, p: (tbl[b, r], 0, 0, 0))

    in_specs = [
        pl.BlockSpec((1, H, D), lambda b, r, tbl, p: (b, 0, 0)),
        page_spec(D),
        page_spec(D),
    ]
    operands = (table, pos, q, pool_k, pool_v)
    if quant:
        in_specs += [page_spec(1), page_spec(1)]
        operands += (k_scale, v_scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, R),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, D), lambda b, r, tbl, p: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, H // K, D), jnp.float32),
            pltpu.VMEM((K, H // K, 1), jnp.float32),
            pltpu.VMEM((K, H // K, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page=page, n_r=R, window=window,
                          scale=scale, groups=H // K, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(*operands)

"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

One (batch, head) pair per grid row; the chunk axis is the innermost
(sequential) grid dim so the (P, N) recurrent state lives in VMEM scratch
across chunks — the inter-chunk recurrence never round-trips HBM, which is
the point: the jnp fallback (models/ssm.py) carries the state through a
lax.scan whose per-chunk carry is written back to HBM each iteration.

Per chunk (length Lc, state N, head dim P):
  intra:  (C·Bᵀ ∘ causal-decay) · (dt·x)      — two MXU matmuls
  inter:  C · h_in · segment-decay             — one MXU matmul
  state:  h_out = e^{Σa} h_in + Σ_j decay_j (dt·x)_j ⊗ B_j

VMEM at Lc=128, P=64, N=128: ~0.5 MB — double-bufferable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (Lc, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Lc, 1)
    a = a_ref[0]                                 # (1,) decay rate (negative)
    b = b_ref[0, 0].astype(jnp.float32)          # (Lc, N)
    c = c_ref[0, 0].astype(jnp.float32)          # (Lc, N)

    la = dt * a                                  # (Lc, 1) log-decay ≤ 0
    cum = jnp.cumsum(la, axis=0)                 # (Lc, 1)

    # ---- intra-chunk quadratic ----
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Lc, Lc)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum - cum[:, 0][None, :])    # (Lc,1)-(1,Lc): cum_i - cum_j
    decay = jnp.where(ii >= jj, decay, 0.0)
    dx = dt * x                                  # (Lc, P)
    y = jax.lax.dot_general(scores * decay, dx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)      # (Lc, P)

    # ---- inter-chunk: carried state contribution ----
    h = h_ref[...]                               # (N, P)
    y += jnp.exp(cum) * jax.lax.dot_general(
        c, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # ---- state update ----
    total = cum[chunk - 1]                       # (1,)
    rem = jnp.exp(total[None, :] - cum)          # (Lc, 1) decay j → chunk end
    h_new = jnp.exp(total)[:, None] * h + jax.lax.dot_general(
        b * rem, dx, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (N, P)
    h_ref[...] = h_new

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _flush():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = False):
    """x: (B, S, H, P); dt: (B, S, H); a: (H,); b/c: (B, S, N).
    Returns (y: (B, S, H, P), h_final: (B, H, N, P))."""
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    # layout: (B, H, n_chunks, Lc, ·)
    xr = x.transpose(0, 2, 1, 3).reshape(B * H, n_chunks, chunk, P)
    dtr = dt.transpose(0, 2, 1).reshape(B * H, n_chunks, chunk, 1)
    br = jnp.broadcast_to(b_mat.reshape(B, 1, n_chunks, chunk, N),
                          (B, H, n_chunks, chunk, N)).reshape(
        B * H, n_chunks, chunk, N)
    cr = jnp.broadcast_to(c_mat.reshape(B, 1, n_chunks, chunk, N),
                          (B, H, n_chunks, chunk, N)).reshape(
        B * H, n_chunks, chunk, N)
    ar = jnp.repeat(a.reshape(1, H), B, axis=0).reshape(B * H, 1)

    y, h_final = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=(B * H, 1, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda g, _, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda g, _, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1), lambda g, _, c: (g, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda g, _, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda g, _, c: (g, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda g, _, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda g, _, c: (g, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, n_chunks, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, 1, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, ar, br, cr)

    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    h_final = h_final.reshape(B, H, N, P).transpose(0, 1, 3, 2)  # (B,H,P,N)
    return y, h_final

"""Fused NF4-dequant matmul — the QLoRAM base-weight hot path on TPU.

``y = x @ dequant(codes, scales)`` with the weight stored packed (two 4-bit
codes per byte along K, per-64-row absmax scales).  The GPU original
(bitsandbytes) dequantizes into a CUDA shared-memory tile; the TPU-native
shape of the idea:

  * grid (M/bm, N/bn, K/bk), K innermost so the f32 accumulator scratch
    lives in VMEM across the K sweep;
  * the packed (bk/2, bn) uint8 tile is unpacked in VREGs (shift/mask), the
    16-entry NF4 codebook lookup is computed as a degree-15 selection tree
    (jnp.where chain) — no gather needed on the VPU;
  * per-block scales broadcast-multiply, then the bf16 tile feeds the MXU.

Arithmetic intensity doubles vs a bf16 weight load (0.5 + ~0.03 bytes/weight
instead of 2), which is exactly why QLoRAM decode shifts from memory- toward
compute-bound (see EXPERIMENTS.md §Roofline).

Block shapes default to (128, 512, 128): K-tile 128 → 64 packed rows (uint8
sublane-friendly), N-tile 512 lanes, M-tile 128 MXU rows; VMEM footprint
≈ bm·bk·2 + bk/2·bn + bk/64·bn·2 + bm·bn·4 ≈ 0.4 MB — far under the ~16 MB
v5e VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.nf4 import NF4_CODEBOOK

DEFAULT_BM = 128
DEFAULT_BN = 512
DEFAULT_BK = 128
QBLOCK = 64


def _nf4_decode(idx_i32):
    """Map 4-bit code (int32 in [0,16)) → NF4 value via a selection tree
    (vector-friendly; avoids gather)."""
    out = jnp.full(idx_i32.shape, NF4_CODEBOOK[0], jnp.float32)
    for i in range(1, 16):
        out = jnp.where(idx_i32 == i, NF4_CODEBOOK[i], out)
    return out


def _kernel(x_ref, codes_ref, scales_ref, o_ref, acc_ref, *, bk: int,
            n_k: int, out_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                  # (bm, bk)
    packed = codes_ref[...]                         # (bk//2, bn)
    lo = (packed & 0x0F).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    # interleave rows: row 2i ← lo[i], row 2i+1 ← hi[i]
    idx = jnp.stack([lo, hi], axis=1).reshape(bk, -1)
    w = _nf4_decode(idx)                            # (bk, bn) f32
    scales = scales_ref[...].astype(jnp.float32)    # (bk//QBLOCK, bn)
    w = w.reshape(bk // QBLOCK, QBLOCK, -1) * scales[:, None, :]
    w = w.reshape(bk, -1).astype(x.dtype)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def nf4_matmul(x, codes, scales, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
               bk: int = DEFAULT_BK, out_dtype=jnp.float32,
               interpret: bool = False):
    """x: (M, K); codes: (K//2, N) uint8; scales: (K//QBLOCK, N).  → (M, N)."""
    M, K = x.shape
    N = codes.shape[1]
    assert codes.shape[0] * 2 == K and scales.shape[0] * QBLOCK == K
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0 and bk % QBLOCK == 0
    n_k = K // bk

    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk, n_k=n_k, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // QBLOCK, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, scales)

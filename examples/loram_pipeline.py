"""End-to-end LoRAM driver (paper Algorithm 1, all stages) on a ~100M-param
model: the deliverable-(b) training driver.

  offline (publisher): prune 65% → continual-pretrain alignment → NF4 quantize
  online  (user):      QLoRAM SFT, checkpointed + fault-tolerant
  inference:           recover adapters → merge into the FULL model → generate

Default config is ~100M params (10 layers, d_model 640, vocab 32k).  A few
hundred steps takes a while on a 1-core CPU container — use --steps/--scale
to trade fidelity for time (CI uses --steps 30 --scale 0.25).

  PYTHONPATH=src python examples/loram_pipeline.py --steps 300
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LoRAConfig, LoRAMConfig, ModelConfig, ServeConfig, TrainConfig
from repro.core import loram
from repro.core.objectives import cross_entropy
from repro.data import AlignmentCorpus, SFTDataset, batch_iterator
from repro.models import forward, init_params, make_plan
from repro.runtime.trainer import Trainer
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--align-steps", type=int, default=40)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="width multiplier (0.25 → ~7M params for CI)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/loram_pipeline_ckpt")
    args = ap.parse_args()

    d = max(128, int(640 * args.scale) // 128 * 128)
    cfg = ModelConfig(
        name="loram-100m", family="dense", n_layers=10, d_model=d,
        n_heads=d // 64, n_kv_heads=max(1, d // 128), d_ff=4 * d,
        vocab_size=32000)
    plan = make_plan(cfg)
    rng = jax.random.PRNGKey(0)
    params = init_params(plan, rng, jnp.bfloat16)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[pipeline] model: {n/1e6:.1f}M params "
          f"(d_model={d}, layers={cfg.n_layers})")

    lora_cfg = LoRAConfig(rank=8)
    loram_cfg = LoRAMConfig(method="stru", ratio=0.65, quantize=True,
                            align=True, keep_first=2, keep_last=1)

    # ---- offline: prune → align → quantize (publisher side) ----
    t0 = time.time()
    corpus = AlignmentCorpus(cfg.vocab_size, args.seq_len)
    setup = loram.setup(
        plan, params, loram_cfg, lora_cfg, rng,
        align_batches=batch_iterator(corpus, batch_size=args.batch),
        align_steps=args.align_steps, align_lr=3e-4)
    rep = loram.storage_report(params, setup.small_params)
    print(f"[pipeline] offline done in {time.time()-t0:.1f}s: "
          f"reduction {rep['reduction_ratio']:.2f}x, "
          f"HBM {rep['hbm_reduction']:.2f}x (QLoRAM)")

    # ---- online: QLoRAM SFT with checkpointing ----
    tc = TrainConfig(global_batch=args.batch, seq_len=args.seq_len,
                     learning_rate=1e-3, total_steps=args.steps,
                     warmup_steps=max(2, args.steps // 20), remat=False)
    ds = SFTDataset(cfg.vocab_size, args.seq_len)
    trainer = Trainer(setup.small_plan, setup.small_params, setup.lora0, tc,
                      lora_cfg, n_micro=1, checkpoint_dir=args.ckpt,
                      checkpoint_every=max(10, args.steps // 5))
    state = trainer.restore_or_init()
    state = trainer.train(
        batch_iterator(ds, batch_size=args.batch, start_step=state.step),
        steps=args.steps, state=state, log_every=max(1, args.steps // 10))

    # ---- inference: recover → merge → evaluate + generate ----
    lora_full, merged = loram.finalize(setup, state.lora, params)
    eval_b = ds.batch(10_000, batch_size=16)
    for name, p in [("base", params), ("LoRAM-merged", merged)]:
        lg, _ = forward(plan, p, jnp.asarray(eval_b["tokens"]))
        ppl = float(jnp.exp(cross_entropy(lg, jnp.asarray(eval_b["labels"]),
                                          jnp.asarray(eval_b["loss_mask"]))))
        print(f"[pipeline] {name:14s} eval ppl = {ppl:.3f}")

    eng = ServeEngine(plan, merged, ServeConfig(max_seq_len=args.seq_len + 32))
    res = eng.generate(np.asarray(eval_b["tokens"][:2, :16], np.int32),
                       max_new_tokens=16, temperature=0.7)
    print(f"[pipeline] generated {res.tokens.shape} at "
          f"{res.tokens_per_s:.1f} tok/s")
    print("[pipeline] OK")


if __name__ == "__main__":
    main()

"""End-to-end LoRAM driver (paper Algorithm 1, all stages) on a ~100M-param
model: the deliverable-(b) training driver.

  offline (publisher): prune 65% → continual-pretrain alignment → NF4 quantize
  online  (user):      QLoRAM SFT, checkpointed + fault-tolerant
  inference:           recover adapters → merge into the FULL model → generate

Default config is ~100M params (10 layers, d_model 640, vocab 32k).  A few
hundred steps takes a while on a 1-core CPU container — use --steps/--scale
to trade fidelity for time (CI uses --steps 30 --scale 0.25).

  PYTHONPATH=src python examples/loram_pipeline.py --steps 300
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LoRAConfig, LoRAMConfig, ModelConfig, ServeConfig, TrainConfig
from repro.core import loram
from repro.core.objectives import cross_entropy
from repro.data import AlignmentCorpus, SFTDataset, batch_iterator
from repro.models import forward, init_params, make_plan
from repro.runtime.trainer import Trainer
from repro.serving import AdapterRegistry, ServeEngine, SpeculativeServeEngine
from repro.serving.draft import draft_from_setup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--align-steps", type=int, default=40)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="width multiplier (0.25 → ~7M params for CI)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/loram_pipeline_ckpt")
    args = ap.parse_args()

    d = max(128, int(640 * args.scale) // 128 * 128)
    cfg = ModelConfig(
        name="loram-100m", family="dense", n_layers=10, d_model=d,
        n_heads=d // 64, n_kv_heads=max(1, d // 128), d_ff=4 * d,
        vocab_size=32000)
    plan = make_plan(cfg)
    rng = jax.random.PRNGKey(0)
    params = init_params(plan, rng, jnp.bfloat16)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[pipeline] model: {n/1e6:.1f}M params "
          f"(d_model={d}, layers={cfg.n_layers})")

    lora_cfg = LoRAConfig(rank=8)
    loram_cfg = LoRAMConfig(method="stru", ratio=0.65, quantize=True,
                            align=True, keep_first=2, keep_last=1)

    # ---- offline: prune → align → quantize (publisher side) ----
    t0 = time.time()
    corpus = AlignmentCorpus(cfg.vocab_size, args.seq_len)
    setup = loram.setup(
        plan, params, loram_cfg, lora_cfg, rng,
        align_batches=batch_iterator(corpus, batch_size=args.batch),
        align_steps=args.align_steps, align_lr=3e-4)
    rep = loram.storage_report(params, setup.small_params)
    print(f"[pipeline] offline done in {time.time()-t0:.1f}s: "
          f"reduction {rep['reduction_ratio']:.2f}x, "
          f"HBM {rep['hbm_reduction']:.2f}x (QLoRAM)")

    # ---- online: QLoRAM SFT with checkpointing ----
    tc = TrainConfig(global_batch=args.batch, seq_len=args.seq_len,
                     learning_rate=1e-3, total_steps=args.steps,
                     warmup_steps=max(2, args.steps // 20), remat=False)
    ds = SFTDataset(cfg.vocab_size, args.seq_len)
    trainer = Trainer(setup.small_plan, setup.small_params, setup.lora0, tc,
                      lora_cfg, n_micro=1, checkpoint_dir=args.ckpt,
                      checkpoint_every=max(10, args.steps // 5))
    state = trainer.restore_or_init()
    state = trainer.train(
        batch_iterator(ds, batch_size=args.batch, start_step=state.step),
        steps=args.steps, state=state, log_every=max(1, args.steps // 10))

    # ---- inference: recover → merge → evaluate + generate ----
    lora_full, merged = loram.finalize(setup, state.lora, params)
    eval_b = ds.batch(10_000, batch_size=16)
    for name, p in [("base", params), ("LoRAM-merged", merged)]:
        lg, _ = forward(plan, p, jnp.asarray(eval_b["tokens"]))
        ppl = float(jnp.exp(cross_entropy(lg, jnp.asarray(eval_b["labels"]),
                                          jnp.asarray(eval_b["loss_mask"]))))
        print(f"[pipeline] {name:14s} eval ppl = {ppl:.3f}")

    eng = ServeEngine(plan, merged, ServeConfig(max_seq_len=args.seq_len + 32))
    res = eng.generate(np.asarray(eval_b["tokens"][:2, :16], np.int32),
                       max_new_tokens=16, temperature=0.7)
    print(f"[pipeline] generated {res.tokens.shape} at "
          f"{res.tokens_per_s:.1f} tok/s")

    # ---- serving: hot-registration into a RUNNING engine ----
    # The paper's fleet deployment: ONE resident full base model, many
    # cheaply-trained adapters streamed through a fixed device bank.  Build
    # a speculative engine whose draft is the pruned model itself, register
    # the first adapter (full-rank recovered tree on the target, its
    # pruned-width twin on the draft), put traffic in flight — then run the
    # WHOLE train-small pipeline again for a second task and hot-register
    # the result into the live engine.  bank_slots=2 (base row + ONE
    # adapter row) forces the two adapters to stream through a single row,
    # and the acceptance bar is strict: zero lost requests, no restart, no
    # recompile (the bank is a fixed-shape tick argument; registration is a
    # functional row write between ticks).
    bank_slots = 2
    registry = AdapterRegistry(lora_full, max_adapters=3,
                               bank_slots=bank_slots)
    draft = draft_from_setup(setup, max_adapters=3, bank_slots=bank_slots)
    live = SpeculativeServeEngine(
        plan, params,
        ServeConfig(max_seq_len=args.seq_len + 32, max_slots=4,
                    max_adapters=3, adapter_bank_slots=bank_slots,
                    max_new_tokens=16, draft_gamma=3,
                    kv_cache_dtype="float32"),
        registry, draft, lora_scale=lora_cfg.scale)
    live.register_adapter("task", lora_full, draft_lora=state.lora)

    rs = np.random.default_rng(1)
    prompts = [rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (12, 9, 14, 7, 11, 8)]
    uids = [live.submit(p, max_new_tokens=12, adapter=a)
            for p, a in zip(prompts[:3], ("task", None, "task"))]
    results = []
    for _ in range(2):          # slots are mid-decode when "fresh" lands
        results += live.step()

    # train task #2 at the pruned width (same offline artifacts, new data),
    # recover, and register into the running engine — no restart
    steps2 = max(6, args.steps // 5)
    ds2 = SFTDataset(cfg.vocab_size, args.seq_len, seed=7)
    tc2 = dataclasses.replace(tc, total_steps=steps2, warmup_steps=2)
    trainer2 = Trainer(setup.small_plan, setup.small_params, setup.lora0,
                       tc2, lora_cfg, n_micro=1)
    state2 = trainer2.train(batch_iterator(ds2, batch_size=args.batch),
                            steps=steps2, state=trainer2.init_state(),
                            log_every=steps2)
    lora2_full, _ = loram.finalize(setup, state2.lora, params)
    t_reg = time.time()
    live.register_adapter("fresh", lora2_full, draft_lora=state2.lora)
    print(f"[pipeline] hot-registered 'fresh' into the live engine in "
          f"{time.time()-t_reg:.2f}s "
          f"({len(live._sched.active_slots())} slots in flight)")

    uids += [live.submit(p, max_new_tokens=12, adapter=a)
             for p, a in zip(prompts[3:], ("fresh", "task", "fresh"))]
    results += list(live.run().values())

    st = registry.residency.state()
    print(f"[pipeline] adapter bank: {len(registry)} adapters through "
          f"{bank_slots} rows — hits={st['hits']} misses={st['misses']} "
          f"evictions={st['evictions']} "
          f"uploaded={st['upload_bytes']/1e6:.2f}MB")
    lost = [r for r in results if r.status != "ok"]
    if len(results) != len(uids) or lost:
        print(f"[pipeline] FAIL: {len(uids)} submitted, "
              f"{len(results)} finished, lost={[(r.uid, r.status) for r in lost]}")
        raise SystemExit(1)
    assert all(len(r.tokens) > 0 for r in results)
    print("[pipeline] OK")


if __name__ == "__main__":
    main()

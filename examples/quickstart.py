"""Quickstart: LoRAM in ~40 lines.

Prune a model 50%, LoRA-train the pruned ("small") model, recover the
adapters, merge into the ORIGINAL ("large") model, and verify the large
model improved — all on CPU in under a minute.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import LoRAConfig, LoRAMConfig, TrainConfig, get_smoke
from repro.core import loram
from repro.core.objectives import cross_entropy
from repro.data import SFTDataset, batch_iterator
from repro.models import forward, init_params, make_plan
from repro.runtime.trainer import Trainer

rng = jax.random.PRNGKey(0)

# 1. the "large" model (smoke-scale llama-family config)
cfg = dataclasses.replace(get_smoke("llama2-13b"), n_layers=4, d_ff=256)
plan = make_plan(cfg)
params = init_params(plan, rng, jnp.float32)

# 2. offline: prune to the "small" training model (LoRAM-Stru, 50%)
setup = loram.setup(
    plan, params,
    LoRAMConfig(method="stru", ratio=0.5, keep_first=1, keep_last=1),
    LoRAConfig(rank=8), rng)
report = loram.storage_report(params, setup.small_params)
print(f"parameter reduction: {report['reduction_ratio']:.2f}x "
      f"({report['full_params']:,} -> {report['small_params']:,})")

# 3. online: LoRA-train the PRUNED model only
tc = TrainConfig(global_batch=8, seq_len=32, learning_rate=5e-3,
                 total_steps=60, warmup_steps=5, remat=False)
ds = SFTDataset(cfg.vocab_size, tc.seq_len)
trainer = Trainer(setup.small_plan, setup.small_params, setup.lora0,
                  tc, LoRAConfig(rank=8), n_micro=1)
state = trainer.train(batch_iterator(ds, batch_size=8), log_every=20)

# 4. recover + merge into the ORIGINAL model; inference uses full weights
lora_full, merged = loram.finalize(setup, state.lora, params)

eval_batch = ds.batch(9999, batch_size=16)
for name, p in [("base (untrained)", params), ("LoRAM-merged", merged)]:
    logits, _ = forward(plan, p, jnp.asarray(eval_batch["tokens"]))
    ppl = float(jnp.exp(cross_entropy(logits, jnp.asarray(eval_batch["labels"]),
                                      jnp.asarray(eval_batch["loss_mask"]))))
    print(f"{name:18s} eval ppl = {ppl:.3f}")

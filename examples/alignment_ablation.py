"""Paper §3.5 ablation at smoke scale: does low-cost continual-pretraining
alignment of the pruned model help the recovered full model?  (Fig. 6's
"w/ vs w/o Alignment" comparison.)

  PYTHONPATH=src python examples/alignment_ablation.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import LoRAConfig, LoRAMConfig, TrainConfig, get_smoke
from repro.core import loram
from repro.core.objectives import cross_entropy
from repro.data import AlignmentCorpus, SFTDataset, batch_iterator
from repro.models import forward, init_params, make_plan
from repro.runtime.trainer import Trainer

rng = jax.random.PRNGKey(0)
cfg = dataclasses.replace(get_smoke("llama2-13b"), n_layers=4, d_ff=256)
plan = make_plan(cfg)
params = init_params(plan, rng, jnp.float32)
lora_cfg = LoRAConfig(rank=4)
ds = SFTDataset(cfg.vocab_size, 32)
eval_b = {k: jnp.asarray(v) for k, v in
          SFTDataset(cfg.vocab_size, 32, seed=99).batch(0, batch_size=16).items()}

for align in (False, True):
    corpus = AlignmentCorpus(cfg.vocab_size, 32)
    setup = loram.setup(
        plan, params,
        LoRAMConfig(method="stru", ratio=0.65, keep_first=1, keep_last=1,
                    align=align),
        lora_cfg, rng,
        # low lr, few steps: alignment must stay CLOSE to W₀'s retained
        # coords or the recovered adapters mismatch the original model at
        # merge time (the paper uses a small corpus for the same reason)
        align_batches=batch_iterator(corpus, batch_size=8) if align else None,
        align_steps=20 if align else 0, align_lr=5e-5)
    tc = TrainConfig(global_batch=8, seq_len=32, learning_rate=5e-3,
                     total_steps=50, warmup_steps=5, remat=False)
    trainer = Trainer(setup.small_plan, setup.small_params, setup.lora0, tc,
                      lora_cfg, n_micro=1)
    state = trainer.train(batch_iterator(ds, batch_size=8), log_every=0)
    _, merged = loram.finalize(setup, state.lora, params)
    lg, _ = forward(plan, merged, eval_b["tokens"])
    ppl = float(jnp.exp(cross_entropy(lg, eval_b["labels"], eval_b["loss_mask"])))
    print(f"[ablation] align={align}: merged full-model ppl = {ppl:.3f}")
print("[ablation] OK (expect align=True ≤ align=False, esp. at high ratios)")

"""Multi-adapter serving: one FULL base model, several LoRAM-trained adapters
hot-swapped per request batch (unmerged mode) — the deployment pattern when a
publisher ships one base + many task adapters trained cheaply via LoRAM.

  PYTHONPATH=src python examples/serve_multi_adapter.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LoRAConfig, LoRAMConfig, ServeConfig, TrainConfig, get_smoke
from repro.core import loram
from repro.data import SFTDataset, batch_iterator
from repro.models import init_params, make_plan
from repro.runtime.trainer import Trainer
from repro.serving import ServeEngine

rng = jax.random.PRNGKey(0)
cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
plan = make_plan(cfg)
params = init_params(plan, rng, jnp.float32)
lora_cfg = LoRAConfig(rank=4)

# train two task adapters on the pruned model (different data seeds = "tasks")
adapters = {}
for task, seed in [("math", 11), ("code", 22)]:
    setup = loram.setup(plan, params,
                        LoRAMConfig(method="stru", ratio=0.5, keep_first=0,
                                    keep_last=0),
                        lora_cfg, rng)
    tc = TrainConfig(global_batch=8, seq_len=32, learning_rate=5e-3,
                     total_steps=25, warmup_steps=2, remat=False)
    ds = SFTDataset(cfg.vocab_size, tc.seq_len, seed=seed)
    trainer = Trainer(setup.small_plan, setup.small_params, setup.lora0, tc,
                      lora_cfg, n_micro=1)
    state = trainer.train(batch_iterator(ds, batch_size=8), log_every=0)
    lora_full, _ = loram.finalize(setup, state.lora, params)
    adapters[task] = lora_full
    print(f"[multi-adapter] trained '{task}' adapter "
          f"({sum(x.size for x in jax.tree.leaves(lora_full)):,} params)")

# serve the SAME full base with each adapter, unmerged
prompts = np.random.default_rng(0).integers(2, cfg.vocab_size, (2, 8)).astype(np.int32)
for task, lora in adapters.items():
    eng = ServeEngine(plan, params, ServeConfig(max_seq_len=64,
                                                merge_adapters=False),
                      lora=lora, lora_scale=lora_cfg.scale)
    res = eng.generate(prompts, max_new_tokens=8)
    print(f"[multi-adapter] task={task:5s} tokens={res.tokens[0][:8]}")
print("[multi-adapter] OK")

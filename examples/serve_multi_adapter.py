"""Multi-adapter serving: one FULL base model, several LoRAM-trained adapters
served SIMULTANEOUSLY through the continuous-batching engine — the deployment
pattern when a publisher ships one base + many task adapters trained cheaply
via LoRAM.

Each adapter is trained on the pruned ("train small") model, recovered to
full rank, registered in the adapter bank, and then requests naming different
adapters share every decode step of the big ("infer large") model.

  PYTHONPATH=src python examples/serve_multi_adapter.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LoRAConfig, LoRAMConfig, ServeConfig, TrainConfig, get_smoke
from repro.core import loram
from repro.data import SFTDataset, batch_iterator
from repro.models import init_params, make_plan
from repro.runtime.trainer import Trainer
from repro.serving import AdapterRegistry, ContinuousServeEngine

rng = jax.random.PRNGKey(0)
cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
plan = make_plan(cfg)
params = init_params(plan, rng, jnp.float32)
lora_cfg = LoRAConfig(rank=4)

# train two task adapters on the pruned model (different data seeds = "tasks")
adapters = {}
for task, seed in [("math", 11), ("code", 22)]:
    setup = loram.setup(plan, params,
                        LoRAMConfig(method="stru", ratio=0.5, keep_first=0,
                                    keep_last=0),
                        lora_cfg, rng)
    tc = TrainConfig(global_batch=8, seq_len=32, learning_rate=5e-3,
                     total_steps=25, warmup_steps=2, remat=False)
    ds = SFTDataset(cfg.vocab_size, tc.seq_len, seed=seed)
    trainer = Trainer(setup.small_plan, setup.small_params, setup.lora0, tc,
                      lora_cfg, n_micro=1)
    state = trainer.train(batch_iterator(ds, batch_size=8), log_every=0)
    lora_full, _ = loram.finalize(setup, state.lora, params)
    adapters[task] = lora_full
    print(f"[multi-adapter] trained '{task}' adapter "
          f"({sum(x.size for x in jax.tree.leaves(lora_full)):,} params)")

# register both adapters into one bank; serve the SAME full base for all
registry = AdapterRegistry(adapters["math"], max_adapters=4)
for task, lora in adapters.items():
    registry.add(task, lora)

eng = ContinuousServeEngine(
    plan, params,
    ServeConfig(max_seq_len=64, max_slots=4, max_adapters=4,
                max_new_tokens=16),
    registry, lora_scale=lora_cfg.scale)

# mixed-length, mixed-adapter traffic, all in flight together
rs = np.random.default_rng(0)
t0 = time.perf_counter()
for task, n_prompt, n_new in [
        ("math", 8, 8), ("code", 12, 6), ("math", 5, 8), (None, 8, 4),
        ("code", 5, 8), ("math", 12, 5)]:
    prompt = rs.integers(2, cfg.vocab_size, (n_prompt,)).astype(np.int32)
    eng.submit(prompt, max_new_tokens=n_new, adapter=task)

for res in eng.stream():
    task = res.adapter or "base"
    print(f"[multi-adapter] uid={res.uid} task={task:5s} "
          f"prompt={res.prompt_len:2d} tokens={res.tokens.tolist()}")
dt = time.perf_counter() - t0
total = eng.n_decode_tokens + eng.n_completed
print(f"[multi-adapter] {eng.n_completed} requests, {total} tokens in "
      f"{dt:.2f}s ({total / dt:.1f} tok/s aggregate) — OK")

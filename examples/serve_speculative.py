"""Speculative serving with the LoRAM-pruned draft, end to end:

  1. offline  — prune the full model (P(·)): the "train small" artifact
  2. online   — train task adapters AT PRUNED WIDTHS on the small model
  3. recover  — scatter the adapters to full rank (R(·)) for the target
  4. serve    — the SAME pruned model + the SAME pruned adapters (pre-
                recovery) now draft γ tokens per slot; the full model with
                the recovered adapters verifies them in one batched forward

The verify pass makes the output provably identical in distribution to
serving the full model alone (token-identical under greedy) — the pruned
model only sets the acceptance rate, i.e. how many tokens each round emits.

  PYTHONPATH=src python examples/serve_speculative.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (LoRAConfig, LoRAMConfig, ServeConfig, TrainConfig,
                           get_smoke)
from repro.core import loram, recovery
from repro.data import SFTDataset, batch_iterator
from repro.models import init_params, make_plan
from repro.runtime.trainer import Trainer
from repro.serving import (AdapterRegistry, ContinuousServeEngine,
                           SpeculativeServeEngine, draft_from_setup)

rng = jax.random.PRNGKey(0)
cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
plan = make_plan(cfg)
params = init_params(plan, rng, jnp.float32)
lora_cfg = LoRAConfig(rank=4)

# offline: one pruning pass shared by every adapter AND by the draft
setup = loram.setup(plan, params,
                    LoRAMConfig(method="stru", ratio=0.5, keep_first=0,
                                keep_last=0),
                    lora_cfg, rng)
draft = draft_from_setup(setup, max_adapters=4)

# online: train two task adapters on the small model; register the PRUNED
# weights with the draft and the RECOVERED weights with the target
registry = None
for task, seed in [("math", 11), ("code", 22)]:
    tc = TrainConfig(global_batch=8, seq_len=32, learning_rate=5e-3,
                     total_steps=25, warmup_steps=2, remat=False)
    ds = SFTDataset(cfg.vocab_size, tc.seq_len, seed=seed)
    trainer = Trainer(setup.small_plan, setup.small_params, setup.lora0, tc,
                      lora_cfg, n_micro=1)
    state = trainer.train(batch_iterator(ds, batch_size=8), log_every=0)
    lora_full = recovery.recover_lora(state.lora, setup.spec, plan,
                                      setup.small_plan)
    if registry is None:
        registry = AdapterRegistry(lora_full, max_adapters=4)
    registry.add(task, lora_full)
    draft.add(task, state.lora)
    print(f"[speculative] trained '{task}' adapter at pruned widths "
          f"({sum(x.size for x in jax.tree.leaves(state.lora)):,} params)")

serve_cfg = ServeConfig(max_seq_len=64, max_slots=4, max_adapters=4,
                        max_new_tokens=16, draft_gamma=3)

# mixed-adapter traffic through both engines; identical greedy tokens
work = [("math", 8, 8), ("code", 12, 6), ("math", 5, 8), (None, 8, 4),
        ("code", 5, 8), ("math", 12, 5)]
rs = np.random.default_rng(0)
prompts = [rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)
           for _, n, _ in work]

plain = ContinuousServeEngine(plan, params, serve_cfg, registry,
                              lora_scale=lora_cfg.scale)
spec = SpeculativeServeEngine(plan, params, serve_cfg, registry, draft,
                              lora_scale=lora_cfg.scale)

t0 = time.perf_counter()
up = [plain.submit(p, max_new_tokens=m, adapter=a)
      for p, (a, _, m) in zip(prompts, work)]
rp = plain.run()
t_plain = time.perf_counter() - t0

t0 = time.perf_counter()
us = [spec.submit(p, max_new_tokens=m, adapter=a)
      for p, (a, _, m) in zip(prompts, work)]
rsp = spec.run()
t_spec = time.perf_counter() - t0

for a, b, (task, _, _) in zip(up, us, work):
    assert np.array_equal(rp[a].tokens, rsp[b].tokens), "diverged!"
    print(f"[speculative] uid={b} task={task or 'base':5s} "
          f"tokens={rsp[b].tokens.tolist()}")

tok = sum(r.n_generated for r in rsp.values())
print(f"[speculative] {len(work)} requests, {tok} tokens — identical to the "
      f"plain engine, token for token")
print(f"[speculative] rounds={spec.n_rounds} (vs {plain._n_ticks} plain "
      f"ticks), acceptance={spec.acceptance_rate:.1%}, "
      f"plain {t_plain:.2f}s vs speculative {t_spec:.2f}s — OK")

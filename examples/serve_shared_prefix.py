"""Copy-on-write prefix sharing: K adapter-routed requests, ONE system prompt.

The dominant multi-adapter serving pattern sends every request through the
same system-prompt + adapter template.  Without sharing, each of the K
requests recomputes the prefix's prefill FLOPs and stores an identical copy
of its K/V.  With ``ServeConfig.prefix_sharing`` the first request under a
``prefix_id`` prefills the prefix once; every later request maps those pages
READ-ONLY into its block table (refcounted — eviction decrements instead of
freeing) and prefills only its suffix.  The partially-filled boundary page
forks copy-on-write the moment a request's suffix diverges into it, so
sharing is invisible to the output: tokens are asserted identical to a
fully unshared run below.

``ServeConfig.prefill_chunk`` composes: long prompts stream in page-aligned
chunks interleaved with decode ticks, so a new request's system prompt
never stalls in-flight traffic.

  PYTHONPATH=src python examples/serve_shared_prefix.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LoRAConfig, ServeConfig, get_smoke
from repro.models import init_params, make_plan
from repro.models.model import init_lora
from repro.serving import AdapterRegistry, ContinuousServeEngine

PREFIX_LEN = 40       # the shared system prompt
N_REQUESTS = 8
PAGE = 16


def main():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, jax.random.PRNGKey(0), jnp.float32)
    lora_cfg = LoRAConfig(rank=4)

    def mk_adapter(seed):
        lora = init_lora(plan, lora_cfg, jax.random.PRNGKey(seed))
        return jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), lora)

    def build(shared: bool):
        registry = AdapterRegistry(mk_adapter(11), max_adapters=4)
        registry.add("math", mk_adapter(11))
        registry.add("code", mk_adapter(22))
        return ContinuousServeEngine(
            plan, params,
            ServeConfig(max_seq_len=128, max_slots=4, max_adapters=4,
                        max_new_tokens=32, kv_cache_dtype="float32",
                        kv_paging=True, kv_page_size=PAGE,
                        prefill_chunk=PAGE if shared else 0,
                        prefix_sharing=shared),
            registry, lora_scale=lora_cfg.scale)

    rs = np.random.default_rng(0)
    system = rs.integers(2, cfg.vocab_size, (PREFIX_LEN,)).astype(np.int32)
    jobs = [(rs.integers(2, cfg.vocab_size,
                         (int(rs.integers(4, 12)),)).astype(np.int32),
             ["math", "code"][i % 2]) for i in range(N_REQUESTS)]

    unshared, shared = build(False), build(True)
    for suffix, adapter in jobs:
        prompt = np.concatenate([system, suffix])
        unshared.submit(prompt, max_new_tokens=12, adapter=adapter)
        shared.submit(prompt, max_new_tokens=12, adapter=adapter,
                      prefix_id="system", prefix_len=PREFIX_LEN)
    r_un, r_sh = unshared.run(), shared.run()

    for uid in sorted(r_un):
        np.testing.assert_array_equal(
            r_un[uid].tokens, r_sh[uid].tokens,
            err_msg=f"uid {uid}: shared-prefix output diverged")
    print(f"[shared_prefix] {N_REQUESTS} requests x {PREFIX_LEN}-token "
          f"system prompt, 2 adapters — token-identical to unshared runs")
    saved_tok = unshared.n_prefill_tokens - shared.n_prefill_tokens
    print(f"[shared_prefix] prefill compute: {unshared.n_prefill_tokens} → "
          f"{shared.n_prefill_tokens} tokens "
          f"({saved_tok} saved = {saved_tok / unshared.n_prefill_tokens:.0%};"
          f" {shared.n_prefix_hits} prefix hits)")
    print(f"[shared_prefix] KV pages: peak {unshared.pages.peak_in_use} → "
          f"{shared.pages.peak_in_use} "
          f"({shared.n_prefix_pages_shared} page-mappings served from "
          f"shared pages)")
    print(f"[shared_prefix] knobs: ServeConfig.prefix_sharing=True + "
          f"submit(prefix_id=..., prefix_len=...); "
          f"ServeConfig.prefill_chunk={PAGE} streams long prompts between "
          f"decode ticks ({shared.n_prefill_chunks} chunks, "
          f"{shared.n_ticks_during_prefill} ticks ran during prefill)")
    assert saved_tok >= (N_REQUESTS - 2 - 1) * PREFIX_LEN  # ≥ hits per adapter


if __name__ == "__main__":
    main()

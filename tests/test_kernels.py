"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracle (ref.py), plus hypothesis property tests on the oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import hypothesis, st

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.nf4_matmul import nf4_matmul
from repro.kernels.ref import flash_attention_ref, nf4_matmul_ref, ssd_scan_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.quant import nf4


# ---------------------------------------------------------------------------
# nf4_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (64, 128, 128, 64, 128, 64),
    (128, 256, 512, 128, 256, 128),
    (8, 64, 128, 8, 128, 64),          # decode-like skinny M
    (256, 192, 384, 128, 128, 64),     # non-square, odd multiples
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nf4_matmul_sweep(m, k, n, bm, bn, bk, dtype):
    rng = np.random.default_rng(m + k + n)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.05, jnp.float32)
    q = nf4.quantize(w)
    out = nf4_matmul(x, q.codes, q.scales, bm=bm, bn=bn, bk=bk, interpret=True)
    ref = nf4_matmul_ref(x, q.codes, q.scales)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 8)


def test_nf4_matmul_matches_dequant_dense():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 64)) * 0.1, jnp.float32)
    q = nf4.quantize(w)
    via_kernel = nf4_matmul(x, q.codes, q.scales, interpret=True)
    via_dense = x @ nf4.dequantize(q, jnp.float32)
    np.testing.assert_allclose(np.asarray(via_kernel), np.asarray(via_dense),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,d,bq,bk", [
    (128, 64, 64, 64),
    (256, 128, 128, 64),
    (256, 64, 256, 256),
    (512, 32, 128, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, d, bq, bk, causal, dtype):
    rng = np.random.default_rng(s + d)
    q = jnp.asarray(rng.standard_normal((1, 2, s, d)) * 0.4, dtype)
    k = jnp.asarray(rng.standard_normal((1, 2, s, d)) * 0.4, dtype)
    v = jnp.asarray(rng.standard_normal((1, 2, s, d)) * 0.4, dtype)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@hypothesis.given(scale=st.floats(0.05, 2.0), seed=st.integers(0, 50))
@hypothesis.settings(max_examples=8, deadline=None)
def test_flash_attention_rowsums(scale, seed):
    """Property: output rows are convex combinations of V rows — max(|out|)
    ≤ max(|v|)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 1, 64, 32)) * scale, jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 64, 32)) * scale, jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 64, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-5


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,h,p,n,chunk", [
    (64, 2, 16, 8, 16),
    (128, 3, 32, 16, 32),
    (128, 1, 64, 32, 128),
    (256, 2, 32, 64, 64),
])
def test_ssd_scan_sweep(s, h, p, n, chunk):
    rng = np.random.default_rng(s + h + p)
    B = 2
    x = jnp.asarray(rng.standard_normal((B, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, s, h))) * 0.2 + 0.01,
                     jnp.float32)
    a = -jnp.asarray(np.abs(rng.standard_normal(h)) + 0.2, jnp.float32)
    bm = jnp.asarray(rng.standard_normal((B, s, n)) * 0.4, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((B, s, n)) * 0.4, jnp.float32)
    y, hf = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    yr, hr = ssd_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), rtol=2e-4,
                               atol=2e-4)


@hypothesis.given(seed=st.integers(0, 30))
@hypothesis.settings(max_examples=6, deadline=None)
def test_ssd_chunk_invariance(seed):
    """Property: chunked SSD output is invariant to the chunk size."""
    rng = np.random.default_rng(seed)
    B, S, H, P, N = 1, 64, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, H))) * 0.2 + 0.01,
                     jnp.float32)
    a = -jnp.asarray(np.abs(rng.standard_normal(H)) + 0.2, jnp.float32)
    bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.4, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.4, jnp.float32)
    y16, h16 = ssd_scan(x, dt, a, bm, cm, chunk=16, interpret=True)
    y64, h64 = ssd_scan(x, dt, a, bm, cm, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h16), np.asarray(h64), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------

def test_ops_dispatch_cpu_uses_ref():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 1, 32, 16)), jnp.float32)
    out = ops.flash_attention(q, q, q, causal=True)
    ref = flash_attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6,
                               atol=1e-6)

"""Property-testing shim: real ``hypothesis`` when installed, else a minimal
fixed-seed fallback.

The CI image for this repo does not ship ``hypothesis``; importing it at
module scope made three tier-1 test modules fail at *collection*.  Test
modules import ``hypothesis`` and ``st`` from here instead:

    from _propcheck import hypothesis, st

The fallback implements exactly the surface those modules use —
``hypothesis.given`` / ``hypothesis.settings`` and the ``st.integers`` /
``st.floats`` / ``st.sampled_from`` / ``st.booleans`` strategies — by drawing
``max_examples`` pseudo-random examples from a seed derived from the test
name (stable across runs and processes, so failures are reproducible).
Endpoint values are always exercised first, which is where most of the
real shrink-to-boundary value of hypothesis lives for these tests.
"""
from __future__ import annotations

import functools
import hashlib
import inspect
import random as _random

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A strategy = boundary examples + a random sampler."""

        def __init__(self, boundary, sample):
            self.boundary = list(boundary)
            self.sample = sample

        def example(self, rng: _random.Random):
            return self.sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                (min_value, max_value),
                lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                (min_value, max_value),
                lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                (elements[0], elements[-1]),
                lambda rng: rng.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy((False, True), lambda rng: rng.random() < 0.5)

    class _Hypothesis:
        DEFAULT_MAX_EXAMPLES = 10

        @staticmethod
        def settings(max_examples=None, deadline=None, **_kw):
            def deco(fn):
                fn._propcheck_settings = {"max_examples": max_examples}
                return fn

            return deco

        @staticmethod
        def given(**strategies):
            def deco(fn):
                @functools.wraps(fn)
                def wrapper(*args, **kwargs):
                    cfg = (getattr(wrapper, "_propcheck_settings", None)
                           or getattr(fn, "_propcheck_settings", None) or {})
                    n = cfg.get("max_examples") or _Hypothesis.DEFAULT_MAX_EXAMPLES
                    seed = int.from_bytes(
                        hashlib.sha256(fn.__qualname__.encode()).digest()[:8],
                        "big")
                    rng = _random.Random(seed)
                    names = sorted(strategies)
                    # boundary pass: each strategy pinned to an endpoint while
                    # the others draw randomly
                    cases = []
                    for name in names:
                        for b in strategies[name].boundary:
                            ex = {k: strategies[k].example(rng) for k in names}
                            ex[name] = b
                            cases.append(ex)
                    while len(cases) < max(n, len(cases)):
                        cases.append(
                            {k: strategies[k].example(rng) for k in names})
                    for ex in cases[: max(n, len(strategies) * 2)]:
                        try:
                            fn(*args, **ex, **kwargs)
                        except Exception as e:
                            raise AssertionError(
                                f"propcheck falsifying example "
                                f"{fn.__qualname__}({ex!r})") from e

                # pytest must not mistake the strategy kwargs for fixtures:
                # hide the wrapped signature (it would follow __wrapped__)
                wrapper.__signature__ = inspect.Signature()
                return wrapper

            return deco

    hypothesis = _Hypothesis()
    st = _Strategies()


__all__ = ["hypothesis", "st", "HAVE_HYPOTHESIS"]

"""Pytree/sharding lint for the jitted tick state.

The PR-6 contract: the state threaded through every jitted serving step is
ONE explicit dataclass pytree (``repro.serving.tickstate.TickState``) in
which every field declares its mesh placement up front.  These tests fail
the build if

  * a field is added without a declared ``PartitionSpec`` (or a doc string),
  * the pytree registration drifts (leaf count vs populated fields),
  * anything dict-shaped re-enters a jitted tick signature — the untyped
    ``Dict[str, Array]`` this refactor deleted must not come back.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ServeConfig, get_smoke
from repro.models import init_params, make_plan
from repro.serving import ContinuousServeEngine
from repro.serving.tickstate import TickState

RNG = jax.random.PRNGKey(0)


def _dict_leaves(tree):
    return [x for x in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, dict)) if isinstance(x, dict)]


# ---------------------------------------------------------------------------
# declared placement: every field, no exceptions
# ---------------------------------------------------------------------------

def test_every_field_declares_partition_spec_and_doc():
    fields = dataclasses.fields(TickState)
    assert fields, "TickState lost its fields?"
    declared = TickState.field_specs()
    assert set(declared) == {f.name for f in fields}
    for f in fields:
        assert "pspec" in f.metadata, (
            f"TickState.{f.name} added without a declared PartitionSpec — "
            f"use the _leaf() helper")
        assert isinstance(f.metadata["pspec"], P), f.name
        assert f.metadata.get("doc"), f"TickState.{f.name} has no doc"


def test_specs_mirror_populated_fields_only():
    st = TickState.zeros(4, 8, n_tbl=3, speculative=False)
    sp = st.specs()
    assert isinstance(sp.block_table, P)
    assert sp.spec is None and sp.max_new is None      # absent leaves
    assert all(isinstance(getattr(sp, n), P)
               for n in ("last_tok", "pos", "active", "out_buf"))


def test_shardings_cover_every_populated_leaf():
    mesh = jax.make_mesh((1,), ("model",))
    st = TickState.zeros(2, 4, n_tbl=2, speculative=True)
    sh = st.shardings(mesh)
    n_leaves = len(jax.tree.leaves(st))
    assert len(jax.tree.leaves(
        sh, is_leaf=lambda x: hasattr(x, "mesh"))) == n_leaves
    placed = jax.device_put(st, sh)
    assert isinstance(placed, TickState)
    assert int(placed.pos.shape[0]) == 2


# ---------------------------------------------------------------------------
# pytree registration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_tbl,speculative,extra", [
    (0, False, 0),   # plain dense engine
    (3, False, 1),   # paged
    (0, True, 2),    # speculative dense
    (3, True, 3),    # speculative paged
])
def test_leaf_count_matches_populated_fields(n_tbl, speculative, extra):
    st = TickState.zeros(4, 8, n_tbl=n_tbl, speculative=speculative)
    populated = sum(getattr(st, f.name) is not None
                    for f in dataclasses.fields(TickState))
    leaves, treedef = jax.tree.flatten(st)
    assert len(leaves) == populated == 8 + extra
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, TickState)


def test_replace_traces_under_jit():
    st = TickState.zeros(4, 8)

    @jax.jit
    def tick(s):
        return s.replace(pos=s.pos + 1,
                         out_buf=s.out_buf.at[:, 0].set(s.last_tok))

    out = tick(st)
    assert isinstance(out, TickState)
    assert int(out.pos[0]) == 1
    with pytest.raises(TypeError):
        st.replace(bogus_field=jnp.zeros(4))   # closed field set


# ---------------------------------------------------------------------------
# no dict leaf in any tick signature
# ---------------------------------------------------------------------------

def test_tickstate_has_no_dict_leaves():
    st = TickState.zeros(4, 8, n_tbl=2, speculative=True)
    assert not _dict_leaves(st)


def test_live_engine_state_is_tickstate_not_dict():
    """The lint that bites: a real engine's jitted-tick operand must be a
    TickState with zero dict-shaped leaves."""
    cfg = get_smoke("yi-34b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    eng = ContinuousServeEngine(
        plan, params,
        ServeConfig(max_seq_len=32, max_slots=2, max_new_tokens=8,
                    kv_cache_dtype="float32"))
    assert isinstance(eng._st, TickState)
    assert not _dict_leaves(eng._st)

"""LoRAM core invariants: prune → train → recover → merge, all variants.

Property tests (hypothesis) cover the system's central invariants:
  1. merge-equivalence:   forward(W₀+R(B,A)) == forward(W₀, adapters)
  2. delta support:       recovered delta is zero on pruned coordinates
  3. prune-shapes:        pruned dims are 128-aligned and match the spec
  4. NF4 roundtrip:       |deq(q(w)) - w| ≤ codebook-gap × blockwise absmax
  5. recovery inverse:    scatter(gather(x)) restores kept coords exactly
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import hypothesis, st

from repro.configs import LoRAConfig, LoRAMConfig, get_smoke
from repro.core import loram, pruning, recovery
from repro.core.objectives import sft_loss
from repro.models import forward, init_params, make_plan
from repro.quant import nf4

RNG = jax.random.PRNGKey(0)


def _tiny_plan(d_ff=256, n_layers=4, d_model=64):
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=n_layers,
                              d_ff=d_ff, d_model=d_model)
    return make_plan(cfg)


@pytest.fixture(scope="module")
def tiny():
    plan = _tiny_plan()
    params = init_params(plan, RNG, jnp.float32)
    return plan, params


# ---------------------------------------------------------------------------
# structured variants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["rand", "stru"])
@pytest.mark.parametrize("keep", [(0, 0), (1, 1)])
def test_structured_cycle(tiny, method, keep):
    plan, params = tiny
    cfg = LoRAMConfig(method=method, ratio=0.5, keep_first=keep[0],
                      keep_last=keep[1])
    setup = loram.setup(plan, params, cfg, LoRAConfig(rank=4), RNG)
    # pruned dims are MXU-aligned
    for stg in setup.small_plan.stages:
        assert stg.dims.d_ff % 128 == 0
    # train-free check: perturb adapters, recover, merge, compare paths
    lora = jax.tree.map(
        lambda x: x + 0.02 * jax.random.normal(RNG, x.shape, x.dtype),
        setup.lora0)
    lora_full, merged = loram.finalize(setup, lora, params)
    assert recovery.delta_support_check(setup.spec, plan, lora_full)
    tokens = jax.random.randint(RNG, (2, 8), 0, plan.cfg.vocab_size)
    lg_m, _ = forward(plan, merged, tokens)
    lg_a, _ = forward(plan, params, tokens, lora_full, lora_scale=4.0)
    np.testing.assert_allclose(np.asarray(lg_m), np.asarray(lg_a),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("method", ["semi", "unst"])
def test_nonstructured_cycle(tiny, method):
    plan, params = tiny
    cfg = LoRAMConfig(method=method, ratio=0.5)
    setup = loram.setup(plan, params, cfg, LoRAConfig(rank=4), RNG)
    # masked-dense: plan unchanged, base masked
    assert setup.small_plan is plan
    masks = setup.spec.masks["stages"]
    for stn, stm in masks.items():
        for bn, bm in stm["stacked"].items():
            for pn, m in bm.items():
                w = setup.small_params["stages"][stn]["stacked"][bn][pn]
                assert not bool(jnp.abs(jnp.asarray(w) * (1 - m)).max() > 0)
    if method == "semi":
        # 4:8 pattern: every 8 consecutive along d_in keeps exactly 4
        m = next(iter(next(iter(masks.values()))["stacked"].values()))
        mm = np.asarray(next(iter(m.values())), np.float32)
        g = mm.reshape(mm.shape[0], mm.shape[1] // 8, 8, mm.shape[2]).sum(2)
        assert np.all(g == 4)
    # recovery is identity for non-structured (paper C3)
    rec = recovery.recover_lora(setup.lora0, setup.spec, plan, setup.small_plan)
    assert rec is setup.lora0


def test_qloram_storage_reduction(tiny):
    plan, params = tiny
    cfg = LoRAMConfig(method="stru", ratio=0.65, quantize=True,
                      keep_first=0, keep_last=0)
    setup = loram.setup(plan, params, cfg, LoRAConfig(rank=4), RNG)
    rep = loram.storage_report(params, setup.small_params)
    assert rep["reduction_ratio"] > 1.2
    assert rep["hbm_reduction"] > rep["reduction_ratio"]  # NF4 compounds


@pytest.mark.slow
def test_training_on_pruned_beats_init(tiny):
    plan, params = tiny
    cfg = LoRAMConfig(method="stru", ratio=0.5, keep_first=1, keep_last=1)
    setup = loram.setup(plan, params, cfg, LoRAConfig(rank=4), RNG)
    tokens = jax.random.randint(RNG, (4, 16), 0, plan.cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def loss(l):
        return sft_loss(setup.small_plan, setup.small_params, l, batch,
                        lora_scale=4.0)[0]

    lora = setup.lora0
    l0 = float(loss(lora))
    g = jax.grad(loss)
    for _ in range(8):
        lora = jax.tree.map(lambda p, gg: p - 0.01 * gg, lora, g(lora))
    assert float(loss(lora)) < l0


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

@hypothesis.given(
    d_in=st.sampled_from([64, 128, 192]),
    d_out=st.sampled_from([32, 64, 96]),
    scale=st.floats(0.001, 10.0),
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_nf4_roundtrip_bounded(d_in, d_out, scale):
    w = jax.random.normal(jax.random.PRNGKey(d_in + d_out), (d_in, d_out)) * scale
    q = nf4.quantize(w)
    wd = nf4.dequantize(q, jnp.float32)
    # error bounded by half the max codebook gap × per-block absmax
    gap = float(np.max(np.diff(nf4.NF4_CODEBOOK)))
    wb = np.asarray(w, np.float32).reshape(d_in // 64, 64, d_out)
    absmax = np.abs(wb).max(axis=1, keepdims=True)
    # + 2e-3·absmax: scales are stored fp16 (QLoRA), adding ≤ 2^-11 rel error
    bound = (gap / 2 + 2e-3) * absmax + 1e-6
    err = np.abs(np.asarray(wd).reshape(wb.shape) - wb)
    assert np.all(err <= bound)


@hypothesis.given(
    n=st.integers(2, 6),
    keep=st.integers(1, 5),
    seed=st.integers(0, 100),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_scatter_gather_inverse(n, keep, seed):
    """recovery._scatter_rows is a right-inverse of the prune gather."""
    rng = np.random.default_rng(seed)
    total = n * 16
    k = min(keep * 8, total)
    full = rng.standard_normal((3, total, 5)).astype(np.float32)
    idx = np.sort(np.stack([rng.choice(total, size=k, replace=False)
                            for _ in range(3)]), axis=1)
    gathered = np.take_along_axis(full, idx[:, :, None], axis=1)
    scattered = recovery._scatter_rows(n * 16, jnp.asarray(idx),
                                       jnp.asarray(gathered))
    back = np.take_along_axis(np.asarray(scattered), idx[:, :, None], axis=1)
    np.testing.assert_allclose(back, gathered)
    # zeros elsewhere
    mask = np.ones((3, n * 16), bool)
    np.put_along_axis(mask, idx, False, axis=1)
    assert np.abs(np.asarray(scattered)[mask]).max(initial=0) == 0


@hypothesis.given(ratio=st.floats(0.1, 0.9), seed=st.integers(0, 20))
@hypothesis.settings(max_examples=10, deadline=None)
def test_prune_keep_counts_aligned(ratio, seed):
    plan = _tiny_plan(d_ff=512, n_layers=2)
    cfg = LoRAMConfig(method="rand", ratio=ratio, keep_first=0, keep_last=0,
                      seed=seed)
    scores = pruning.random_scores(plan, seed)
    small_plan, spec = pruning.build_structured_spec(plan, cfg, scores)
    for stg in small_plan.stages:
        assert stg.dims.d_ff % 128 == 0
        assert stg.dims.d_ff >= 128
        assert stg.dims.n_kv_heads >= 1
        assert stg.dims.n_heads == stg.dims.n_kv_heads * (
            plan.cfg.n_heads // plan.cfg.n_kv_heads)

"""Continuous-batching multi-adapter serving:

  1. scheduler policy — FCFS admission into the lowest free slot, one token
     accounted per tick, eviction frees the slot immediately
  2. adapter registry — bank stacking axes, hot-swap, structure validation
  3. token identity — mixed prompt lengths + per-request adapter routing
     through the continuous engine produce EXACTLY the tokens each request
     gets when served alone through the synchronous single-adapter path
  4. slot eviction/readmission — with more requests than slots, later
     requests reuse cache rows previous occupants wrote; isolation means
     their outputs are still identical to solo runs
  5. legacy engine accounting — prefill/decode throughput reported
     separately over the right token counts
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LoRAConfig, ServeConfig, get_smoke
from repro.models import init_params, make_plan
from repro.models.model import init_lora
from repro.serving import (AdapterBankFull, AdapterRegistry,
                           AdapterStructureError, ContinuousServeEngine,
                           Request, Scheduler, ServeEngine)

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# scheduler (pure host-side, no device work)
# ---------------------------------------------------------------------------

def _req(sched, n_prompt=4, max_new=3):
    return Request(uid=sched.new_uid(),
                   prompt=np.ones(n_prompt, np.int32),
                   max_new_tokens=max_new)


def test_scheduler_fcfs_lowest_free_slot():
    s = Scheduler(max_slots=2)
    r0, r1, r2 = _req(s), _req(s), _req(s)
    for r in (r0, r1, r2):
        s.submit(r)
    slot_a, got_a = s.next_admission()
    slot_b, got_b = s.next_admission()
    assert (slot_a, got_a.uid) == (0, r0.uid)
    assert (slot_b, got_b.uid) == (1, r1.uid)
    assert s.next_admission() is None          # full: r2 waits
    assert s.queued == 1 and s.utilization() == 1.0


def test_scheduler_tick_evict_readmit():
    s = Scheduler(max_slots=1)
    r0 = _req(s, max_new=3)
    r1 = _req(s, max_new=1)
    s.submit(r0)
    s.submit(r1)
    slot, _ = s.next_admission()
    assert s.tick() == []                      # 1 of 2 decode steps done
    assert s.tick() == [slot]                  # finished
    assert s.slot_generated(slot) == 3
    s.evict(slot)
    slot2, got = s.next_admission()
    assert slot2 == slot and got.uid == r1.uid
    # max_new_tokens == 1 completes at prefill, before any tick
    assert s.completed_slots() == [slot2]
    s.evict(slot2)
    assert not s.has_work


# ---------------------------------------------------------------------------
# shared tiny model + two adapters
# ---------------------------------------------------------------------------

LORA_CFG = LoRAConfig(rank=4)


@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)

    def mk_adapter(seed):
        lora = init_lora(plan, LORA_CFG, jax.random.PRNGKey(seed))
        # perturb so every adapter produces a distinct nonzero delta
        return jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), lora)

    adapters = {"math": mk_adapter(11), "code": mk_adapter(22)}
    return cfg, plan, params, adapters


def _solo_reference(plan, params, adapters, prompt, adapter, max_new):
    """One request alone through the synchronous single-adapter path."""
    eng = ServeEngine(
        plan, params,
        ServeConfig(max_seq_len=64, merge_adapters=False,
                    kv_cache_dtype="float32"),
        lora=None if adapter is None else adapters[adapter],
        lora_scale=LORA_CFG.scale)
    return eng.generate(prompt[None], max_new_tokens=max_new).tokens[0]


# ---------------------------------------------------------------------------
# adapter registry
# ---------------------------------------------------------------------------

def test_registry_bank_axes_and_hot_swap(served):
    _, _, _, adapters = served
    reg = AdapterRegistry(adapters["math"], max_adapters=3)
    aid = reg.add("math", adapters["math"])
    assert aid == 1                            # 0 is the reserved base route
    assert reg.resolve(None) == 0
    assert reg.resolve("math") == aid == reg.resolve(aid)

    # stacked-block leaves get K at axis 1 (behind n_rep); shared at axis 0
    leaf = jax.tree.leaves(adapters["math"]["stages"])[0]
    bank_leaf = jax.tree.leaves(reg.bank["stages"])[0]
    assert bank_leaf.shape == leaf.shape[:1] + (3,) + leaf.shape[1:]
    if "lm_head" in adapters["math"]:
        assert (reg.bank["lm_head"]["a"].shape
                == (3,) + adapters["math"]["lm_head"]["a"].shape)

    # hot-swap: re-adding a name overwrites its row, id is stable
    assert reg.add("math", adapters["code"]) == aid
    row = jax.tree.leaves(reg.adapter_tree("math"))[0]
    np.testing.assert_array_equal(
        np.asarray(row), np.asarray(jax.tree.leaves(adapters["code"])[0]))

    with pytest.raises(AdapterStructureError):
        reg.add("bad", {"stages": {}})         # structure mismatch


def test_registry_capacity(served):
    """The host tier is unbounded: registration past the device bank's
    capacity SUCCEEDS (the tree waits host-side), but forcing residency
    while every row is pinned raises the typed bank-full error."""
    _, _, _, adapters = served
    reg = AdapterRegistry(adapters["math"], max_adapters=2)
    reg.add("a", adapters["math"])
    b = reg.add("b", adapters["code"])         # host-registered, not resident
    assert not reg.resident("b")
    reg.residency.retain(reg.resolve("a"))     # pin the one adapter row
    with pytest.raises(RuntimeError):          # AdapterBankFull
        reg.upload("b")
    with pytest.raises(AdapterBankFull):
        reg.upload("b")
    reg.residency.release(reg.resolve("a"))
    assert reg.upload("b") == reg.bank_row(b)  # LRU-evicts "a", streams "b"
    assert reg.resident("b") and not reg.resident("a")


# ---------------------------------------------------------------------------
# continuous batching == single-request serving, token for token
# ---------------------------------------------------------------------------

def test_continuous_matches_solo_with_eviction_reuse(served):
    cfg, plan, params, adapters = served
    reg = AdapterRegistry(adapters["math"], max_adapters=4)
    reg.add("math", adapters["math"])
    reg.add("code", adapters["code"])

    # 3 slots < 7 requests → every slot is evicted and re-admitted at least
    # once, with mixed prompt lengths and mixed adapters in flight together
    eng = ContinuousServeEngine(
        plan, params,
        ServeConfig(max_seq_len=64, max_slots=3, max_adapters=4,
                    max_new_tokens=16, kv_cache_dtype="float32"),
        reg, lora_scale=LORA_CFG.scale)

    rs = np.random.default_rng(0)
    spec = [(8, "math", 6), (12, "code", 4), (5, None, 6), (12, "math", 3),
            (8, "code", 6), (5, "math", 5), (12, None, 4)]
    prompts = [rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)
               for n, _, _ in spec]
    uids = [eng.submit(p, max_new_tokens=m, adapter=a)
            for p, (_, a, m) in zip(prompts, spec)]

    results = eng.run()
    assert len(results) == len(spec)
    assert eng.n_completed == len(spec)

    for uid, p, (_, adapter, max_new) in zip(uids, prompts, spec):
        ref = _solo_reference(plan, params, adapters, p, adapter, max_new)
        got = results[uid].tokens
        assert got.shape == (max_new,)
        np.testing.assert_array_equal(
            got, ref,
            err_msg=f"request {uid} (adapter={adapter}) diverged from solo run")

    # per-request adapter routing actually routed: same prompt, different
    # adapters → different continuations
    same_prompt = prompts[0]
    u_m = eng.submit(same_prompt, max_new_tokens=6, adapter="math")
    u_c = eng.submit(same_prompt, max_new_tokens=6, adapter="code")
    u_b = eng.submit(same_prompt, max_new_tokens=6)
    more = eng.run()
    outs = [more[u].tokens for u in (u_m, u_c, u_b)]
    assert not np.array_equal(outs[0], outs[1])
    assert not np.array_equal(outs[0], outs[2])

    # hot-swap AFTER engine construction: decode reads the live bank, so
    # "math" now behaves exactly like "code" (no recompile, no stale rows)
    reg.add("math", adapters["code"])
    u_swap = eng.submit(same_prompt, max_new_tokens=6, adapter="math")
    np.testing.assert_array_equal(eng.run()[u_swap].tokens, outs[1])


def test_registry_capacity_must_match_config(served):
    _, plan, params, adapters = served
    reg = AdapterRegistry(adapters["math"], max_adapters=2)
    with pytest.raises(ValueError):
        ContinuousServeEngine(
            plan, params,
            ServeConfig(max_seq_len=32, max_slots=2, max_adapters=8,
                        max_new_tokens=8), reg)


def test_continuous_moe_free_slots_cannot_displace(served):
    """MoE: free slots decode garbage through the router; with lossless
    decode capacity that garbage must never evict a live request's token
    from an expert buffer (output stays identical to the solo run)."""
    cfg = get_smoke("deepseek-moe-16b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    lora = init_lora(plan, LORA_CFG, jax.random.PRNGKey(3))
    lora = jax.tree.map(
        lambda x: x + 0.05 * jax.random.normal(
            jax.random.PRNGKey(4), x.shape, x.dtype), lora)
    reg = AdapterRegistry(lora, max_adapters=2)
    reg.add("t", lora)
    eng = ContinuousServeEngine(
        plan, params,
        ServeConfig(max_seq_len=48, max_slots=4, max_adapters=2,
                    max_new_tokens=8, kv_cache_dtype="float32"),
        reg, lora_scale=LORA_CFG.scale)
    rs = np.random.default_rng(2)
    p1 = rs.integers(2, cfg.vocab_size, (6,)).astype(np.int32)
    p2 = rs.integers(2, cfg.vocab_size, (9,)).astype(np.int32)
    # only 2 of 4 slots active → 2 slots feed garbage into the router
    u1 = eng.submit(p1, max_new_tokens=5, adapter="t")
    u2 = eng.submit(p2, max_new_tokens=5)
    res = eng.run()

    solo = ServeEngine(plan, params,
                       ServeConfig(max_seq_len=48, merge_adapters=False,
                                   kv_cache_dtype="float32"),
                       lora=lora, lora_scale=LORA_CFG.scale)
    np.testing.assert_array_equal(
        res[u1].tokens, solo.generate(p1[None], max_new_tokens=5).tokens[0])
    base = ServeEngine(plan, params,
                       ServeConfig(max_seq_len=48, kv_cache_dtype="float32"))
    np.testing.assert_array_equal(
        res[u2].tokens, base.generate(p2[None], max_new_tokens=5).tokens[0])


def test_sampling_reproducible_under_scheduling(served):
    """Sampled output depends only on (request seed, generation index) —
    not on which slot or tick the scheduler happened to assign."""
    cfg, plan, params, _ = served
    sc = ServeConfig(max_seq_len=48, max_slots=2, max_new_tokens=8,
                     kv_cache_dtype="float32")
    prompt = np.arange(2, 8, dtype=np.int32)

    eng1 = ContinuousServeEngine(plan, params, sc)
    u_alone = eng1.submit(prompt, max_new_tokens=6, temperature=0.9, seed=5)
    alone = eng1.run()[u_alone].tokens

    eng2 = ContinuousServeEngine(plan, params, sc)
    # other traffic first → same request lands on a different slot/tick
    eng2.submit(np.ones(4, np.int32), max_new_tokens=8)
    u_busy = eng2.submit(prompt, max_new_tokens=6, temperature=0.9, seed=5)
    busy = eng2.run()[u_busy].tokens
    np.testing.assert_array_equal(alone, busy)


def test_streaming_and_submit_validation(served):
    cfg, plan, params, adapters = served
    eng = ContinuousServeEngine(
        plan, params,
        ServeConfig(max_seq_len=32, max_slots=2, max_new_tokens=8,
                    kv_cache_dtype="float32"))
    p = np.ones(4, np.int32)
    with pytest.raises(ValueError):
        eng.submit(p, max_new_tokens=9)        # > out-buffer capacity
    with pytest.raises(ValueError):
        eng.submit(np.ones(30, np.int32), max_new_tokens=8)  # > max_seq_len
    with pytest.raises(ValueError):
        eng.submit(p, adapter="math")          # no registry attached

    uids = [eng.submit(p, max_new_tokens=k) for k in (1, 3, 5)]
    seen = [r.uid for r in eng.stream()]
    assert sorted(seen) == sorted(uids)        # all complete, streamed
    assert eng.pending == 0
    # shortest request finishes first under continuous batching
    assert seen[0] == uids[0]


# ---------------------------------------------------------------------------
# legacy engine throughput accounting
# ---------------------------------------------------------------------------

def test_sync_engine_reports_prefill_and_decode_separately(served):
    cfg, plan, params, _ = served
    eng = ServeEngine(plan, params,
                      ServeConfig(max_seq_len=48, kv_cache_dtype="float32"))
    B, S, N = 2, 8, 4
    res = eng.generate(np.ones((B, S), np.int32), max_new_tokens=N)
    assert res.tokens.shape == (B, N)
    # decode window covers only N-1 steps (token #1 comes from prefill)
    assert res.decode_tokens_per_s == pytest.approx(
        B * (N - 1) / res.decode_s, rel=1e-6)
    assert res.prefill_tokens_per_s == pytest.approx(
        B * S / res.prefill_s, rel=1e-6)
    assert res.tokens_per_s == pytest.approx(
        B * N / (res.prefill_s + res.decode_s), rel=1e-6)

"""Serving observability: instrumentation must WATCH, never TOUCH.

  1. metrics primitives — counter / gauge / histogram semantics, labels,
     registry snapshot shape, constant labels, reset, Prometheus text
  2. latency helpers — percentile matches numpy, latency_summary carries
     the exact BENCH_serving.json field names
  3. tracer + event log — ring capacity, counts survive eviction, JSONL
     stream, derive_ttft, disabled mode records nothing
  4. watchdog serving policy — ``on_alarm`` counts a straggler instead of
     raising; the trainer policy (no callback) still raises
  5. token identity — obs on vs off produces EXACTLY the same tokens
     through the sync, continuous, paged, speculative (and mesh, on a
     multi-device platform) engines
  6. counters vs event log — ``n_completed`` == ``complete`` events,
     admits == submits + preemptions, exactly one ``first_token`` per uid,
     and the event-derived TTFT equals ``RequestResult.ttft_s`` EXACTLY
     (same clock stamps, not re-measured)
  7. first-token stamp survives preempt-then-readmit (the setdefault guard
     regression: a readmitted request must keep its TRUE first-token time)
  8. snapshot export — schema validation round-trip, tamper detection
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LoRAConfig, LoRAMConfig, ServeConfig, get_smoke
from repro.core import loram, recovery
from repro.models import init_params, make_plan
from repro.models.model import init_lora
from repro.obs import (EVENT_KINDS, LATENCY_BUCKETS, EventLog,
                       MetricsRegistry, TickTracer, latency_summary,
                       metric_value, percentile, render_prometheus, snapshot,
                       validate_snapshot, write_snapshot)
from repro.runtime.watchdog import StepWatchdog, StragglerAlarm
from repro.serving import (AdapterRegistry, ContinuousServeEngine,
                           ServeEngine, SpeculativeServeEngine,
                           draft_from_setup)

RNG = jax.random.PRNGKey(0)
LORA_CFG = LoRAConfig(rank=4)
LORAM_CFG = LoRAMConfig(method="stru", ratio=0.5, keep_first=0, keep_last=0)

N_DEV = len(jax.devices())
needs_devices = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs a multi-device platform (run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


# ---------------------------------------------------------------------------
# 1. metrics primitives
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_semantics():
    reg = MetricsRegistry(constant_labels={"engine": "test"})
    c = reg.counter("toks_total", "tokens", unit="tokens",
                    labelnames=("kind",))
    c.inc(3, kind="prefill")
    c.inc(kind="prefill")
    c.inc(2, kind="decode")
    assert c.value(kind="prefill") == 4
    with pytest.raises(AssertionError):
        c.labels(kind="prefill").inc(-1)       # counters are monotonic
    with pytest.raises(ValueError):
        c.inc(1, wrong="x")                    # undeclared label name

    g = reg.gauge("depth", "queue depth")
    g.set(7)
    assert g.value() == 7
    g.labels().set_fn(lambda: 42)              # live binding wins
    assert g.value() == 42

    h = reg.histogram("lat", "latency", buckets=(0.1, 1.0))
    for x in (0.05, 0.5, 5.0):
        h.observe(x)
    v = h.labels().view()
    assert v["count"] == 3
    assert v["buckets"] == [[0.1, 1], [1.0, 2], ["+Inf", 3]]  # cumulative le
    assert v["sum"] == pytest.approx(5.55)

    # get-or-create returns the same instrument; a kind clash raises
    assert reg.counter("toks_total") is c
    with pytest.raises(ValueError):
        reg.gauge("toks_total")
    # bucket edges must be strictly increasing and finite
    with pytest.raises(AssertionError):
        reg.histogram("bad", buckets=(1.0, 0.5))
    with pytest.raises(AssertionError):
        reg.histogram("bad2", buckets=(1.0, float("inf")))

    snap = reg.snapshot()
    assert all(s["labels"]["engine"] == "test"     # constant labels merged
               for s in snap["toks_total"]["samples"])
    assert metric_value(snap, "toks_total", {"kind": "decode"}) == 2
    assert metric_value(snap, "lat")["count"] == 3
    with pytest.raises(KeyError):
        metric_value(snap, "nope")

    # reset: counters zero, callable-backed gauges keep their bindings
    reg.reset()
    assert c.value(kind="prefill") == 0
    assert g.value() == 42
    assert reg.histogram("lat").count() == 0


def test_gauge_collector_dynamic_label_family():
    reg = MetricsRegistry()
    g = reg.gauge("active_slots", labelnames=("adapter",))
    state = {("math",): 2, ("code",): 1}
    g.set_collector(lambda: state)
    snap = reg.snapshot()
    assert metric_value(snap, "active_slots", {"adapter": "math"}) == 2
    assert metric_value(snap, "active_slots", {"adapter": "code"}) == 1
    state[("rag",)] = 5                        # resolved at READ time
    assert metric_value(reg.snapshot(), "active_slots",
                        {"adapter": "rag"}) == 5


def test_render_prometheus_text_format():
    reg = MetricsRegistry(constant_labels={"engine": "paged"})
    reg.counter("serve_ticks_total", "ticks", unit="ticks").labels().inc(7)
    reg.histogram("ttft_seconds", "ttft", buckets=(0.5,)).observe(0.1)
    text = render_prometheus(reg)
    assert "# TYPE serve_ticks_total counter" in text
    assert 'serve_ticks_total{engine="paged"} 7' in text
    assert 'ttft_seconds_bucket{engine="paged",le="0.5"} 1' in text
    assert 'ttft_seconds_bucket{engine="paged",le="+Inf"} 1' in text
    assert 'ttft_seconds_count{engine="paged"} 1' in text


# ---------------------------------------------------------------------------
# 2. latency helpers
# ---------------------------------------------------------------------------

def test_percentile_matches_numpy():
    rs = np.random.default_rng(0)
    xs = rs.random(37).tolist()
    for q in (0, 25, 50, 90, 99, 100):
        assert percentile(xs, q) == pytest.approx(
            float(np.percentile(np.asarray(xs, np.float64), q)))
    assert percentile([4.0], 99) == 4.0
    with pytest.raises(AssertionError):
        percentile([], 50)


def test_latency_summary_bench_field_names():
    out = latency_summary([0.01, 0.02], [0.1, 0.2], suffix="_short")
    assert set(out) == {"ttft_p50_short_ms", "ttft_p99_short_ms",
                        "e2e_p50_short_ms", "e2e_p99_short_ms"}
    assert out["ttft_p50_short_ms"] == pytest.approx(15.0)
    assert set(latency_summary([1.0], [2.0])) == {
        "ttft_p50_ms", "ttft_p99_ms", "e2e_p50_ms", "e2e_p99_ms"}


# ---------------------------------------------------------------------------
# 3. tracer + event log
# ---------------------------------------------------------------------------

def test_tracer_ring_and_summary():
    t = [0.0]
    tr = TickTracer(capacity=2, clock=lambda: t[0])
    with tr.span("tick"):
        t[0] += 0.5
    with tr.span("tick"):
        t[0] += 1.5
    with tr.span("admit"):
        t[0] += 0.25
    assert tr.n_recorded == 3
    assert len(tr.spans()) == 2                # ring evicted the first tick
    sm = tr.summary()
    assert sm["tick"] == {"count": 1, "total_s": 1.5, "max_s": 1.5,
                          "last_s": 1.5, "mean_s": 1.5}
    assert sm["admit"]["count"] == 1 and sm["admit"]["last_s"] == 0.25
    tr.clear()
    assert tr.n_recorded == 0 and tr.spans() == []

    off = TickTracer(enabled=False)
    with off.span("tick"):
        pass
    assert off.n_recorded == 0 and off.spans() == []


def test_tracer_sync_fn_runs_inside_span():
    t = [0.0]
    synced = []
    tr = TickTracer(clock=lambda: t[0], sync_fn=lambda: synced.append(t[0]))
    with tr.span("tick"):
        t[0] += 1.0
    assert synced == [1.0]                     # sync before the span closed
    assert tr.spans("tick")[0].dur_s == 1.0


def test_event_log_ring_jsonl_and_derivations(tmp_path):
    path = tmp_path / "events.jsonl"
    ev = EventLog(capacity=3, path=str(path))
    ev.emit("submit", 1, t=10.0)
    ev.emit("first_token", 1, t=10.5)
    ev.emit("complete", 1, t=11.0, n_generated=4)
    assert ev.derive_ttft(1) == pytest.approx(0.5)
    assert ev.derive_latency(1) == pytest.approx(1.0)
    ev.emit("submit", 2, t=12.0)               # rolls submit#1 off the ring
    assert ev.n_dropped == 1
    assert ev.derive_ttft(1) is None           # submit record gone
    # counts() survives ring eviction — the counter cross-check hook
    assert ev.counts() == {"submit": 2, "first_token": 1, "complete": 1}
    with pytest.raises(AssertionError):
        ev.emit("bogus", 1)                    # kind outside EVENT_KINDS
    ev.close()
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(lines) == 4                     # JSONL kept what the ring lost
    assert lines[0] == {"t": 10.0, "kind": "submit", "uid": 1}
    assert lines[2]["n_generated"] == 4

    off = EventLog(enabled=False)
    off.emit("submit", 1)
    assert off.records() == [] and off.counts() == {}


# ---------------------------------------------------------------------------
# 4. watchdog serving policy
# ---------------------------------------------------------------------------

def test_watchdog_on_alarm_counts_instead_of_raising():
    t = [0.0]
    alarms = []
    wd = StepWatchdog(alpha=0.5, threshold=2.0, warmup_steps=1,
                      clock=lambda: t[0], on_alarm=alarms.append)
    for i in range(3):                         # establish a 1s EWMA
        wd.start()
        t[0] += 1.0
        wd.stop(i)
    assert alarms == []
    wd.start()
    t[0] += 10.0
    wd.stop(3)                                 # straggler: surfaced, not raised
    assert len(alarms) == 1
    assert alarms[0].elapsed == pytest.approx(10.0)
    # the straggler still feeds the EWMA (sustained slowdown → new baseline)
    assert wd.ewma > 1.0

    raising = StepWatchdog(alpha=0.5, threshold=2.0, warmup_steps=1,
                           clock=lambda: t[0])
    for i in range(3):
        raising.start()
        t[0] += 1.0
        raising.stop(i)
    raising.start()
    t[0] += 10.0
    with pytest.raises(StragglerAlarm):        # trainer policy unchanged
        raising.stop(3)


# ---------------------------------------------------------------------------
# shared tiny model + pruned draft + two adapters
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    setup = loram.setup(plan, params, LORAM_CFG, LORA_CFG,
                        jax.random.PRNGKey(1))
    draft = draft_from_setup(setup, max_adapters=4)

    def mk_adapter(seed):
        small = init_lora(setup.small_plan, LORA_CFG, jax.random.PRNGKey(seed))
        small = jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), small)
        full = recovery.recover_lora(small, setup.spec, plan, setup.small_plan)
        return small, full

    registry = None
    for name, seed in [("math", 11), ("code", 22)]:
        small, full = mk_adapter(seed)
        if registry is None:
            registry = AdapterRegistry(full, max_adapters=4)
        registry.add(name, full)
        draft.add(name, small)
    return cfg, plan, params, registry, draft


def _serve_cfg(**kw):
    base = dict(max_seq_len=64, max_slots=3, max_adapters=4,
                max_new_tokens=16, kv_cache_dtype="float32")
    base.update(kw)
    return ServeConfig(**base)


def _mixed_submit(eng, cfg, lens=(8, 12, 5, 11, 7), news=(6, 4, 6, 3, 5)):
    rs = np.random.default_rng(0)
    names = ["math", "code", None]
    return [eng.submit(rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32),
                       max_new_tokens=m, adapter=names[i % 3])
            for i, (n, m) in enumerate(zip(lens, news))]


def _assert_identical(r_on, r_off):
    assert sorted(r_on) == sorted(r_off)
    for u in r_on:
        np.testing.assert_array_equal(
            r_on[u].tokens, r_off[u].tokens,
            err_msg=f"uid {u}: obs on/off changed the tokens")


# ---------------------------------------------------------------------------
# 5. token identity: obs on vs off
# ---------------------------------------------------------------------------

def test_sync_engine_obs_identity_and_counters(served):
    cfg, plan, params, _, _ = served
    prompts = np.random.default_rng(3).integers(
        2, cfg.vocab_size, (2, 8)).astype(np.int32)
    on = ServeEngine(plan, params,
                     ServeConfig(max_seq_len=48, kv_cache_dtype="float32"))
    off = ServeEngine(plan, params,
                      ServeConfig(max_seq_len=48, kv_cache_dtype="float32",
                                  obs=False))
    r_on = on.generate(prompts, max_new_tokens=4)
    r_off = off.generate(prompts, max_new_tokens=4)
    np.testing.assert_array_equal(r_on.tokens, r_off.tokens)

    snap = on.metrics.snapshot()
    assert metric_value(snap, "serve_prefill_tokens_total") == 16   # 2*8
    assert metric_value(snap, "serve_decode_tokens_total") == 6     # 2*(4-1)
    assert metric_value(snap, "serve_requests_completed_total") == 2
    assert {s.name for s in on.tracer.spans()} == {"prefill", "decode"}
    assert off.tracer.n_recorded == 0
    # counters stay live even with obs off (only tracer/events gate)
    assert metric_value(off.metrics.snapshot(),
                        "serve_requests_completed_total") == 2


def test_continuous_and_paged_obs_identity(served):
    cfg, plan, params, registry, _ = served

    def run(obs, **kw):
        eng = ContinuousServeEngine(plan, params,
                                    _serve_cfg(obs=obs, **kw), registry,
                                    lora_scale=LORA_CFG.scale)
        _mixed_submit(eng, cfg)
        return eng, eng.run()

    for paged_kw in ({}, dict(kv_paging=True, kv_page_size=8)):
        on_eng, r_on = run(True, **paged_kw)
        off_eng, r_off = run(False, **paged_kw)
        _assert_identical(r_on, r_off)
        assert on_eng.tracer.n_recorded > 0
        assert on_eng.events.counts()["complete"] == len(r_on)
        # disabled instruments record nothing; counters still count
        assert off_eng.tracer.n_recorded == 0
        assert off_eng.events.records() == []
        assert off_eng.n_completed == len(r_off)


def test_speculative_obs_identity(served):
    cfg, plan, params, registry, draft = served

    def run(obs):
        eng = SpeculativeServeEngine(plan, params,
                                     _serve_cfg(obs=obs, draft_gamma=3),
                                     registry, draft,
                                     lora_scale=LORA_CFG.scale)
        _mixed_submit(eng, cfg)
        return eng, eng.run()

    on_eng, r_on = run(True)
    off_eng, r_off = run(False)
    _assert_identical(r_on, r_off)
    assert on_eng.n_rounds > 0 and on_eng.n_rounds == off_eng.n_rounds
    snap = on_eng.metrics.snapshot()
    assert metric_value(snap, "spec_rounds_total") == on_eng.n_rounds
    assert metric_value(snap, "spec_tokens_proposed_total") > 0
    assert metric_value(snap, "spec_gamma") == 3
    assert "round" in {s.name for s in on_eng.tracer.spans()}


@needs_devices
def test_mesh_obs_identity(served):
    cfg, plan, params, registry, _ = served

    def run(obs):
        eng = ContinuousServeEngine(
            plan, params,
            _serve_cfg(obs=obs, mesh_data=1, mesh_model=2, kv_paging=True,
                       kv_page_size=8),
            registry, lora_scale=LORA_CFG.scale)
        _mixed_submit(eng, cfg)
        return eng, eng.run()

    on_eng, r_on = run(True)
    _, r_off = run(False)
    _assert_identical(r_on, r_off)
    # per-device HBM attribution sees every mesh device
    snap = on_eng.metrics.snapshot()
    devices = {s["labels"]["device"]
               for s in snap["hbm_bytes"]["samples"]
               if s["labels"]["component"] == "weights"}
    assert len(devices) == 2


# ---------------------------------------------------------------------------
# 6 + 7. counters vs event log, exact TTFT, preempt keeps first stamp
# ---------------------------------------------------------------------------

def test_counters_match_events_and_preempt_keeps_ttft(served):
    """One pool-starved paged run covers the consistency contract: the pool
    is too small for the traffic, so slots are preempted mid-decode and
    re-admitted — the event log must still balance, and every request's
    event-derived TTFT must equal its RequestResult EXACTLY (the engines
    pass the same clock stamps to both)."""
    cfg, plan, params, registry, _ = served
    eng = ContinuousServeEngine(
        plan, params,
        _serve_cfg(max_new_tokens=48, kv_paging=True, kv_page_size=8,
                   kv_pages=9, tick_watchdog=True),
        registry, lora_scale=LORA_CFG.scale)
    uids = _mixed_submit(eng, cfg, lens=(8, 12, 5, 11, 7, 13),
                         news=(40, 40, 40, 40, 40, 40))
    results = eng.run()
    assert eng.n_preemptions > 0, "tiny pool must have preempted"

    counts = eng.events.counts()
    assert counts["complete"] == eng.n_completed == len(uids)
    assert counts["submit"] == len(uids)
    # every preemption requeues at the head → exactly one extra admit
    assert counts["admit"] == counts["submit"] + eng.n_preemptions
    assert counts["first_token"] == len(uids)

    preempted = {r["uid"] for r in eng.events.records(kind="preempt")}
    assert preempted
    for u in uids:
        firsts = eng.events.records(uid=u, kind="first_token")
        assert len(firsts) == 1, f"uid {u}: first_token stamped twice"
        # exact equality — same stamps, same clock, no re-derivation slack
        assert eng.events.derive_ttft(u) == results[u].ttft_s
        assert eng.events.derive_latency(u) == results[u].latency_s
        assert 0.0 <= results[u].ttft_s <= results[u].latency_s
    # the regression scenario really happened: some request produced its
    # first token, was then preempted, and kept the ORIGINAL stamp
    survived = [u for u in preempted
                if eng.events.records(uid=u, kind="first_token")[0]["t"]
                < eng.events.records(uid=u, kind="preempt")[0]["t"]]
    assert survived, "no request was preempted after its first token"

    # registry and properties are the same numbers (one source of truth)
    snap = eng.metrics.snapshot()
    assert metric_value(snap, "serve_preemptions_total") == eng.n_preemptions
    assert metric_value(snap, "serve_requests_completed_total") == len(uids)
    assert metric_value(snap, "serve_ttft_seconds")["count"] == len(uids)
    assert metric_value(snap, "serve_e2e_latency_seconds")["count"] == len(uids)
    assert metric_value(snap, "serve_pages_in_use") == 0   # all released
    # watchdog gauge live; straggler count sane on a healthy run
    assert metric_value(snap, "serve_tick_ewma_s") > 0.0
    assert eng.n_stalls == eng.events.counts().get("stall", 0)

    # legacy reset idiom still works (benchmark warm-up), and the full
    # telemetry reset clears spans/events too
    eng.n_preemptions = 0
    assert eng.n_preemptions == 0
    eng.reset_telemetry()
    assert eng.tracer.n_recorded == 0 and eng.events.counts() == {}
    assert eng.n_completed == 0


def test_preempt_before_first_token_stamps_once(served):
    """The other half of the regression: a slot preempted MID-PREFILL (no
    first token yet) re-prefills on readmission — the stamp must be taken
    exactly once, AFTER the preempt, and match the reported ttft_s."""
    cfg, plan, params, _, _ = served
    eng = ContinuousServeEngine(
        plan, params,
        _serve_cfg(kv_paging=True, kv_page_size=8, kv_pages=13,
                   prefill_chunk=8))
    rs = np.random.default_rng(0)
    uids = [eng.submit(rs.integers(2, cfg.vocab_size, (40,)).astype(np.int32),
                       max_new_tokens=8) for _ in range(3)]
    results = eng.run()
    assert eng.n_preemptions > 0
    preempted_mid_prefill = 0
    for u in uids:
        firsts = eng.events.records(uid=u, kind="first_token")
        assert len(firsts) == 1, f"uid {u}: first_token stamped twice"
        assert eng.events.derive_ttft(u) == results[u].ttft_s
        for p in eng.events.records(uid=u, kind="preempt"):
            if p["t"] < firsts[0]["t"]:
                preempted_mid_prefill += 1
    # the pool/chunk sizing above deterministically preempts a slot that
    # has not produced its first token yet — the scenario really ran
    assert preempted_mid_prefill > 0


# ---------------------------------------------------------------------------
# 8. snapshot export
# ---------------------------------------------------------------------------

def test_snapshot_schema_roundtrip_and_tamper(tmp_path, served):
    cfg, plan, params, registry, _ = served
    eng = ContinuousServeEngine(plan, params,
                                _serve_cfg(kv_paging=True, kv_page_size=8),
                                registry, lora_scale=LORA_CFG.scale)
    _mixed_submit(eng, cfg)
    results = eng.run()

    extra = {"requests": {str(u): {"ttft_s": r.ttft_s,
                                   "latency_s": r.latency_s,
                                   "n_generated": r.n_generated}
                          for u, r in results.items()}}
    doc = write_snapshot(str(tmp_path / "snap.json"), eng.metrics,
                         eng.tracer, eng.events, extra=extra)
    ondisk = json.loads((tmp_path / "snap.json").read_text())
    validate_snapshot(ondisk)
    assert ondisk["schema_version"] == doc["schema_version"] == 1
    assert {r["kind"] for r in ondisk["events"]["records"]} <= set(EVENT_KINDS)
    assert ondisk["trace"]["summary"]["tick"]["count"] > 0

    # tampering with the shape must be caught
    bad = dict(doc)
    bad["schema_version"] = 99
    with pytest.raises(AssertionError):
        validate_snapshot(bad)
    bad2 = json.loads(json.dumps(doc))
    bad2["metrics"]["serve_ticks_total"].pop("samples")
    with pytest.raises(AssertionError):
        validate_snapshot(bad2)

    # extras may not shadow the core sections
    with pytest.raises(AssertionError):
        snapshot(eng.metrics, eng.tracer, eng.events,
                 extra={"metrics": {}})

"""Model-substrate behaviour: prefill/decode vs full-forward consistency,
window-attention ring cache, MoE routing, encoder-decoder, SSM streaming."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import (decode_step, forward, init_cache, init_params,
                          make_plan, prefill)
from repro.models.moe import moe_mlp

CONSISTENCY_ARCHS = ["yi-34b", "gemma3-12b", "granite-20b", "zamba2-2.7b",
                     "mamba2-370m", "whisper-tiny", "deepseek-moe-16b",
                     "internvl2-26b", "arctic-480b", "minitron-8b"]


def _frontend(cfg, b, dtype=jnp.float32):
    if cfg.family == "encdec":
        k = jax.random.PRNGKey(7)
        return 0.1 * jax.random.normal(k, (b, cfg.enc_len, cfg.d_model), dtype)
    if cfg.family == "vlm":
        return jnp.ones((b, cfg.n_patches, cfg.d_model), dtype) * 0.02
    return None


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke(arch)
    plan = make_plan(cfg)
    rng = jax.random.PRNGKey(1)
    params = init_params(plan, rng, jnp.float32)
    B, S = 2, 12
    fe = _frontend(cfg, B)
    tokens = jax.random.randint(rng, (B, S + 2), 0, cfg.vocab_size)
    logits_full, _ = forward(plan, params, tokens, frontend=fe)
    cache = init_cache(plan, B, 32, jnp.float32)
    lg, cache, pos = prefill(plan, params, tokens[:, :S], cache, frontend=fe)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, S - 1]),
                               rtol=5e-4, atol=5e-4)
    for i in range(2):
        lg, cache = decode_step(plan, params, tokens[:, S + i], cache, pos + i)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, S + i]),
                                   rtol=5e-3, atol=5e-3)


def test_window_attention_ring_cache():
    """gemma3-style local attention: decode past the window stays exact."""
    cfg = get_smoke("gemma3-12b")   # window=8 in smoke config
    plan = make_plan(cfg)
    rng = jax.random.PRNGKey(3)
    params = init_params(plan, rng, jnp.float32)
    B, S = 1, 20                    # S > 2×window exercises ring wraparound
    tokens = jax.random.randint(rng, (B, S + 4), 0, cfg.vocab_size)
    logits_full, _ = forward(plan, params, tokens)
    cache = init_cache(plan, B, 64, jnp.float32)
    lg, cache, pos = prefill(plan, params, tokens[:, :S], cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, S - 1]),
                               rtol=5e-4, atol=5e-4)
    for i in range(4):
        lg, cache = decode_step(plan, params, tokens[:, S + i], cache, pos + i)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, S + i]),
                                   rtol=5e-3, atol=5e-3)


def test_moe_routing_topk_and_aux():
    d, e, f, topk = 16, 8, 32, 2
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 5)
    p = {
        "router": jax.random.normal(ks[0], (d, e)) * 0.5,
        "we_g": jax.random.normal(ks[1], (e, d, f)) * 0.2,
        "we_u": jax.random.normal(ks[2], (e, d, f)) * 0.2,
        "we_d": jax.random.normal(ks[3], (e, f, d)) * 0.2,
    }
    x = jax.random.normal(ks[4], (2, 16, d)) * 0.5
    out, aux = moe_mlp(x, p, top_k=topk, capacity_factor=2.0)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3   # E·Σ f_e·p_e ≥ 1 (balanced = 1)
    # capacity sensitivity: huge capacity must equal generous capacity
    out2, _ = moe_mlp(x, p, top_k=topk, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-4,
                               atol=1e-5)


def test_moe_grad_flows_to_router():
    d, e, f = 8, 4, 16
    rng = jax.random.PRNGKey(0)
    p = {
        "router": jax.random.normal(rng, (d, e)) * 0.5,
        "we_g": jax.random.normal(rng, (e, d, f)) * 0.2,
        "we_u": jax.random.normal(rng, (e, d, f)) * 0.2,
        "we_d": jax.random.normal(rng, (e, f, d)) * 0.2,
    }
    x = jax.random.normal(rng, (1, 8, d))

    def loss(p):
        out, aux = moe_mlp(x, p, top_k=2, capacity_factor=2.0)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["we_g"]).max()) > 0


def test_encdec_uses_encoder_output():
    cfg = get_smoke("whisper-tiny")
    plan = make_plan(cfg)
    rng = jax.random.PRNGKey(5)
    params = init_params(plan, rng, jnp.float32)
    tokens = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size)
    fe1 = 0.1 * jax.random.normal(rng, (1, cfg.enc_len, cfg.d_model))
    fe2 = -fe1
    l1, _ = forward(plan, params, tokens, frontend=fe1)
    l2, _ = forward(plan, params, tokens, frontend=fe2)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4  # cross-attn is live


def test_vlm_frontend_prefix_changes_text_logits():
    cfg = get_smoke("internvl2-26b")
    plan = make_plan(cfg)
    rng = jax.random.PRNGKey(5)
    params = init_params(plan, rng, jnp.float32)
    tokens = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size)
    fe1 = jnp.ones((1, cfg.n_patches, cfg.d_model)) * 0.05
    fe2 = -fe1
    l1, _ = forward(plan, params, tokens, frontend=fe1)
    l2, _ = forward(plan, params, tokens, frontend=fe2)
    assert l1.shape[1] == tokens.shape[1]  # patch positions stripped
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_causality():
    """Changing a future token never changes past logits (all causal archs)."""
    for arch in ["yi-34b", "mamba2-370m", "zamba2-2.7b", "gemma3-12b"]:
        cfg = get_smoke(arch)
        plan = make_plan(cfg)
        rng = jax.random.PRNGKey(0)
        params = init_params(plan, rng, jnp.float32)
        t1 = jax.random.randint(rng, (1, 10), 0, cfg.vocab_size)
        t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab_size)
        l1, _ = forward(plan, params, t1)
        l2, _ = forward(plan, params, t2)
        np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                                   np.asarray(l2[:, :-1]), rtol=1e-5,
                                   atol=1e-5, err_msg=arch)

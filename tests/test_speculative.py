"""Speculative decoding with the LoRAM-pruned draft:

  1. acceptance-rejection math — property test that the accept/residual rule
     preserves the TARGET distribution exactly (temperature > 0), plus
     deterministic checks of the leading-accept count, the residual at the
     first rejection, and the plain-slot (q ≡ 0) collapse
  2. greedy token identity — the speculative engine emits EXACTLY the tokens
     the non-speculative continuous engine emits, across slot eviction /
     readmission, per-request adapter routing, and per-slot mixed
     speculative/plain traffic (correctness must not depend on draft quality)
  3. plain-slot sampled traffic through the speculative engine is BIT-
     identical to the plain engine (same (seed, gen_idx) key discipline)
  4. speculative sampling depends only on (seed, token index) — never on
     which slots/ticks the scheduler happened to use
  5. family sweep — SSM (state snapshots), hybrid (shared attn), sliding
     window (ring rollback past the window), MoE (lossless verify capacity)
  6. a compressible base (pruned channels exactly zero) makes the draft
     computationally equivalent to the target → acceptance ≈ 100%
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import hypothesis, st
from repro.configs import LoRAConfig, LoRAMConfig, ServeConfig, get_smoke
from repro.core import loram, recovery
from repro.core.pruning import zero_prunable_tail
from repro.models import init_params, make_plan
from repro.models.model import init_lora
from repro.serving import (AdapterRegistry, ContinuousServeEngine,
                           SpeculativeConfig, SpeculativeServeEngine,
                           draft_from_setup, speculative_accept)

RNG = jax.random.PRNGKey(0)
LORA_CFG = LoRAConfig(rank=4)
LORAM_CFG = LoRAMConfig(method="stru", ratio=0.5, keep_first=0, keep_last=0)


def _serve_cfg(gamma=0, **kw):
    base = dict(max_seq_len=64, max_slots=3, max_adapters=4,
                max_new_tokens=16, kv_cache_dtype="float32")
    base.update(kw)
    return ServeConfig(draft_gamma=gamma, **base)


# ---------------------------------------------------------------------------
# 1. acceptance-rejection math
# ---------------------------------------------------------------------------

@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(seed=st.integers(0, 10_000))
def test_accept_rejection_preserves_target_distribution(seed):
    """Emitting (accepted draft | residual sample) must be distributed
    exactly as the target p, for ANY draft distribution q."""
    V, trials = 5, 4000
    rs = np.random.default_rng(seed)
    p = rs.dirichlet(np.ones(V)).astype(np.float32)
    q = rs.dirichlet(np.ones(V)).astype(np.float32)
    drafts = rs.choice(V, size=trials, p=q).astype(np.int32)
    u = rs.random(trials, dtype=np.float64).astype(np.float32)

    pp = jnp.broadcast_to(jnp.asarray(p)[None, None], (trials, 1, V))
    qq = jnp.broadcast_to(jnp.asarray(q)[None, None], (trials, 1, V))
    n, m, resid = speculative_accept(pp, qq, jnp.asarray(drafts)[:, None],
                                     jnp.asarray(u)[:, None])
    n, resid = np.asarray(n), np.asarray(resid)

    # rejected rows sample the residual (inverse-CDF with fresh uniforms)
    r = rs.random(trials)
    cum = np.cumsum(resid, axis=-1)
    corr = (r[:, None] > cum).sum(axis=-1).clip(max=V - 1)
    out = np.where(n == 1, drafts, corr)

    freq = np.bincount(out, minlength=V) / trials
    # 5σ of a binomial bin at worst-case variance
    tol = 5 * np.sqrt(0.25 / trials)
    assert np.abs(freq - p).max() < tol, (freq, p)


def test_leading_accepts_residual_and_plain_collapse():
    V, T = 4, 3
    p = np.full((2, T, V), 0.25, np.float32)
    q = np.zeros((2, T, V), np.float32)
    q[:, :, 0] = 1.0                         # draft always proposes token 0
    drafts = np.zeros((2, T), np.int32)
    # row 0: u small → accept,accept,reject;   p(d)/q(d) = 0.25
    u = np.array([[0.1, 0.2, 0.9], [0.1, 0.1, 0.1]], np.float32)
    n, m, resid = speculative_accept(
        jnp.asarray(p), jnp.asarray(q), jnp.asarray(drafts), jnp.asarray(u),
        spec=jnp.asarray([True, False]))
    assert np.asarray(n).tolist() == [2, 0]  # row 1: plain → rejects all
    assert np.asarray(m).tolist() == [2, 0]
    r = np.asarray(resid)
    # residual = norm(max(p - q, 0)): token 0 is excluded for the spec row
    np.testing.assert_allclose(r[0], [0, 1 / 3, 1 / 3, 1 / 3], atol=1e-6)
    # plain row: q treated as zero → residual IS the target distribution
    np.testing.assert_allclose(r[1], p[1, 0], atol=1e-6)


def test_greedy_accepts_on_exact_match_only():
    V, T = 4, 3
    p = np.zeros((1, T, V), np.float32)
    p[:, :, 1] = 1.0
    q = np.full((1, T, V), 0.25, np.float32)
    drafts = np.array([[1, 2, 1]], np.int32)          # mismatch at position 1
    greedy_ok = jnp.asarray(drafts == 1)
    n, m, _ = speculative_accept(
        jnp.asarray(p), jnp.asarray(q), jnp.asarray(drafts),
        jnp.zeros((1, T), jnp.float32), greedy_ok=greedy_ok,
        temps=jnp.zeros((1,)))
    assert np.asarray(n).tolist() == [1]
    assert np.asarray(m).tolist() == [1]


# ---------------------------------------------------------------------------
# shared tiny model + pruned draft + two adapters
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    setup = loram.setup(plan, params, LORAM_CFG, LORA_CFG,
                        jax.random.PRNGKey(1))
    draft = draft_from_setup(setup, max_adapters=4)

    def mk_adapter(seed):
        small = init_lora(setup.small_plan, LORA_CFG, jax.random.PRNGKey(seed))
        small = jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), small)
        full = recovery.recover_lora(small, setup.spec, plan, setup.small_plan)
        return small, full

    registry = None
    for name, seed in [("math", 11), ("code", 22)]:
        small, full = mk_adapter(seed)
        if registry is None:
            registry = AdapterRegistry(full, max_adapters=4)
        registry.add(name, full)
        draft.add(name, small)
    return cfg, plan, params, registry, draft


# ---------------------------------------------------------------------------
# 2. greedy token identity (incl. eviction/readmission, mixed spec/plain)
# ---------------------------------------------------------------------------

def test_speculative_greedy_identical_to_plain_engine(served):
    cfg, plan, params, registry, draft = served
    plain = ContinuousServeEngine(plan, params, _serve_cfg(),
                                  registry, lora_scale=LORA_CFG.scale)
    spec = SpeculativeServeEngine(plan, params, _serve_cfg(gamma=3),
                                  registry, draft, lora_scale=LORA_CFG.scale)

    # 3 slots < 7 requests → every slot is evicted and re-admitted at least
    # once; mixed adapters AND mixed speculative/plain slots in flight
    rs = np.random.default_rng(0)
    reqs = [(8, "math", 6, True), (12, "code", 4, False), (5, None, 6, True),
            (12, "math", 3, True), (8, "code", 6, False), (5, "math", 5, True),
            (12, None, 4, True)]
    prompts = [rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)
               for n, _, _, _ in reqs]
    up = [plain.submit(p, max_new_tokens=m, adapter=a)
          for p, (_, a, m, _) in zip(prompts, reqs)]
    us = [spec.submit(p, max_new_tokens=m, adapter=a, speculative=sp)
          for p, (_, a, m, sp) in zip(prompts, reqs)]
    rp, rsp = plain.run(), spec.run()
    assert len(rsp) == len(reqs) and spec.n_completed == len(reqs)
    for a, b, (_, adapter, m, sp) in zip(up, us, reqs):
        assert rsp[b].tokens.shape == (m,)
        np.testing.assert_array_equal(
            rp[a].tokens, rsp[b].tokens,
            err_msg=f"uid {b} (adapter={adapter}, spec={sp}) diverged")
    # the speculative rounds really speculated (not everything via correction)
    assert spec.n_proposed > 0 and spec.n_rounds > 0


def test_gamma_one_and_config_validation(served):
    cfg, plan, params, registry, draft = served
    # γ=1 is the degenerate round: 1 proposal, length-1 verify
    plain = ContinuousServeEngine(plan, params, _serve_cfg())
    spec = SpeculativeServeEngine(plan, params, _serve_cfg(gamma=1),
                                  draft=draft)
    p = np.arange(2, 11, dtype=np.int32)
    a = plain.submit(p, max_new_tokens=7)
    b = spec.submit(p, max_new_tokens=7)
    np.testing.assert_array_equal(plain.run()[a].tokens, spec.run()[b].tokens)

    with pytest.raises(ValueError):
        SpeculativeServeEngine(plan, params, _serve_cfg(gamma=2))  # no draft
    with pytest.raises(AssertionError):
        SpeculativeConfig(gamma=0)
    with pytest.raises(AssertionError):
        SpeculativeConfig(draft_stage="merged")
    assert SpeculativeConfig.from_serve(_serve_cfg(gamma=5)).gamma == 5

    # γ may not span more ring slots than the shortest sliding window —
    # commit/rollback scatters would alias (pos+j) % window
    wcfg = get_smoke("gemma3-12b")                      # window = 8
    wplan = make_plan(wcfg)
    wparams = init_params(wplan, RNG, jnp.float32)
    wsetup = loram.setup(wplan, wparams, LORAM_CFG, LORA_CFG,
                         jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="ring"):
        SpeculativeServeEngine(wplan, wparams, _serve_cfg(gamma=9),
                               draft=draft_from_setup(wsetup))


def test_draft_without_adapters_still_serves_adapter_traffic(served):
    """draft_stage="base": one adapter-less draft proposes for every stream;
    acceptance drops but output must stay exactly the target's.  Covers both
    the config knob (ServeConfig.draft_stage) and a registry-less draft."""
    cfg, plan, params, registry, draft = served
    p = np.arange(2, 12, dtype=np.int32)
    plain = ContinuousServeEngine(plan, params, _serve_cfg(),
                                  registry, lora_scale=LORA_CFG.scale)
    a = plain.submit(p, max_new_tokens=6, adapter="math")
    ref = plain.run()[a].tokens

    # the knob: draft has a bank, but draft_stage="base" must never read it
    spec = SpeculativeServeEngine(
        plan, params, _serve_cfg(gamma=2, draft_stage="base"),
        registry, draft, lora_scale=LORA_CFG.scale)
    b = spec.submit(p, max_new_tokens=6, adapter="math")
    np.testing.assert_array_equal(ref, spec.run()[b].tokens)

    # a draft built with no bank at all behaves the same
    bare = dataclasses.replace(draft, registry=None)
    spec2 = SpeculativeServeEngine(plan, params, _serve_cfg(gamma=2),
                                   registry, bare,
                                   lora_scale=LORA_CFG.scale)
    c = spec2.submit(p, max_new_tokens=6, adapter="math")
    np.testing.assert_array_equal(ref, spec2.run()[c].tokens)


# ---------------------------------------------------------------------------
# 3. + 4. sampling
# ---------------------------------------------------------------------------

def test_plain_slots_sampled_bitwise_identical(served):
    """speculative=False requests share rounds with speculative traffic yet
    reproduce the plain engine's sampled stream bit for bit."""
    cfg, plan, params, registry, draft = served
    prompt = np.arange(2, 10, dtype=np.int32)
    plain = ContinuousServeEngine(plan, params, _serve_cfg())
    u0 = plain.submit(prompt, max_new_tokens=8, temperature=0.9, seed=7)
    ref = plain.run()[u0].tokens

    spec = SpeculativeServeEngine(plan, params, _serve_cfg(gamma=3),
                                  draft=draft)
    spec.submit(np.ones(5, np.int32), max_new_tokens=10)  # spec co-traffic
    u1 = spec.submit(prompt, max_new_tokens=8, temperature=0.9, seed=7,
                     speculative=False)
    np.testing.assert_array_equal(ref, spec.run()[u1].tokens)


def test_speculative_sampling_schedule_independent(served):
    cfg, plan, params, registry, draft = served
    prompt = np.arange(2, 10, dtype=np.int32)
    s1 = SpeculativeServeEngine(plan, params, _serve_cfg(gamma=3),
                                draft=draft)
    ua = s1.submit(prompt, max_new_tokens=8, temperature=0.9, seed=5)
    alone = s1.run()[ua].tokens

    s2 = SpeculativeServeEngine(plan, params, _serve_cfg(gamma=3),
                                draft=draft)
    s2.submit(np.ones(4, np.int32), max_new_tokens=12)
    s2.submit(np.ones(6, np.int32), max_new_tokens=3, temperature=0.5, seed=1)
    ub = s2.submit(prompt, max_new_tokens=8, temperature=0.9, seed=5)
    np.testing.assert_array_equal(alone, s2.run()[ub].tokens)
    # and twice through the same engine → same stream (absolute-index keys)
    uc = s2.submit(prompt, max_new_tokens=8, temperature=0.9, seed=5)
    np.testing.assert_array_equal(alone, s2.run()[uc].tokens)


# ---------------------------------------------------------------------------
# 5. family sweep: SSM / hybrid / sliding-window / MoE
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch,lens,news", [
    ("mamba2-370m", (8, 12), (6, 5)),          # pure SSM: snapshot rollback
    ("zamba2-2.7b", (8, 12), (6, 5)),          # hybrid + shared attn blocks
    ("gemma3-12b", (10, 14), (12, 10)),        # window=8: decode past the ring
    ("deepseek-moe-16b", (8, 12), (6, 5)),     # MoE: lossless verify capacity
])
def test_speculative_greedy_identity_families(arch, lens, news):
    cfg = get_smoke(arch)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    setup = loram.setup(plan, params, LORAM_CFG, LORA_CFG,
                        jax.random.PRNGKey(1))
    draft = draft_from_setup(setup)
    sc = dict(max_slots=2, max_adapters=2)
    plain = ContinuousServeEngine(plan, params, _serve_cfg(**sc))
    spec = SpeculativeServeEngine(plan, params, _serve_cfg(gamma=3, **sc),
                                  draft=draft)
    rs = np.random.default_rng(0)
    prompts = [rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)
               for n in lens]
    up = [plain.submit(p, max_new_tokens=m) for p, m in zip(prompts, news)]
    us = [spec.submit(p, max_new_tokens=m) for p, m in zip(prompts, news)]
    rp, rsp = plain.run(), spec.run()
    for a, b in zip(up, us):
        np.testing.assert_array_equal(rp[a].tokens, rsp[b].tokens,
                                      err_msg=f"{arch}: uid {b} diverged")


# ---------------------------------------------------------------------------
# 6. compressible base → draft ≡ target → acceptance ≈ 1
# ---------------------------------------------------------------------------

def test_compressible_base_gives_high_acceptance():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    # zero exactly what magnitude pruning will remove → P(·) is lossless
    params = zero_prunable_tail(params, plan, LORAM_CFG.ratio)
    setup = loram.setup(plan, params, LORAM_CFG, LORA_CFG,
                        jax.random.PRNGKey(1))
    draft = draft_from_setup(setup)
    plain = ContinuousServeEngine(plan, params, _serve_cfg())
    spec = SpeculativeServeEngine(plan, params, _serve_cfg(gamma=3),
                                  draft=draft)
    p = np.arange(2, 12, dtype=np.int32)
    a = plain.submit(p, max_new_tokens=12)
    b = spec.submit(p, max_new_tokens=12)
    np.testing.assert_array_equal(plain.run()[a].tokens, spec.run()[b].tokens)
    # the pruned draft computes the target's function → near-total acceptance
    assert spec.acceptance_rate > 0.9, spec.acceptance_rate
    # and the round count reflects multi-token emission, not 1/tick
    assert spec.n_rounds < 11

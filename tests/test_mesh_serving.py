"""Mesh-sharded serving: token identity + mesh-scope hygiene.

  1. ``sharding.use_mesh`` scope regression — the context is THREAD-LOCAL:
     nested scopes restore on exit (including under exceptions), and a scope
     entered on one thread is invisible on another.  Guards the PR-6 fix of
     the module-global ``_CURRENT`` dict, where a concurrent engine's
     ``__exit__`` could clobber another thread's mesh mid-trace.
  2. token identity — the engines serving over an explicit device mesh
     (weights tensor/expert-parallel on ``model``, KV head-sharded, decode
     batch on ``data``) emit EXACTLY the tokens the single-device engines
     emit, across the dense / sliding-window / MoE families, with slot
     eviction, chunked prefill, copy-on-write shared prefixes, and mixed
     speculative/plain slots in flight.

The identity tests need a multi-device platform; CI forces one on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set BEFORE jax
imports).  On a single device they skip.
"""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LoRAConfig, LoRAMConfig, ServeConfig, get_smoke
from repro.core import loram, recovery
from repro.distributed import sharding
from repro.models import init_params, make_plan
from repro.models.model import init_lora
from repro.serving import (AdapterRegistry, ContinuousServeEngine,
                           SpeculativeServeEngine, draft_from_setup)

RNG = jax.random.PRNGKey(0)
LORA_CFG = LoRAConfig(rank=4)
LORAM_CFG = LoRAMConfig(method="stru", ratio=0.5, keep_first=0, keep_last=0)

N_DEV = len(jax.devices())
needs_devices = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs a multi-device platform (run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# (data, model) shapes to exercise; 2x2 additionally covers the dense-cache
# slot axis actually splitting over ``data``
MESHES = [(1, 2)] + ([(2, 2)] if N_DEV >= 4 else [])


# ---------------------------------------------------------------------------
# 1. thread-local mesh scope (runs on any device count)
# ---------------------------------------------------------------------------

def _mesh(axis="model"):
    return jax.make_mesh((1,), (axis,))


def test_use_mesh_nested_scopes_restore():
    outer, inner = _mesh("model"), _mesh("data")
    assert sharding.current_mesh() is None
    with sharding.use_mesh(outer, head_shard=True):
        assert sharding.current_mesh() is outer
        with sharding.use_mesh(inner):
            assert sharding.current_mesh() is inner
        # inner exit restores the OUTER scope, flags included
        assert sharding.current_mesh() is outer
        assert sharding._ctx()["head_shard"] is True
    assert sharding.current_mesh() is None
    assert sharding._ctx()["head_shard"] is False


def test_use_mesh_restores_on_exception():
    with pytest.raises(RuntimeError, match="boom"):
        with sharding.use_mesh(_mesh()):
            raise RuntimeError("boom")
    assert sharding.current_mesh() is None


def test_use_mesh_scopes_are_thread_local():
    """A second engine's scope on another thread must neither observe nor
    clobber this thread's mesh — the module-global-dict regression."""
    m_main, m_worker = _mesh("model"), _mesh("data")
    entered, release = threading.Event(), threading.Event()
    seen = {}

    def worker():
        seen["before"] = sharding.current_mesh()
        with sharding.use_mesh(m_worker):
            seen["inside"] = sharding.current_mesh()
            entered.set()
            release.wait(10)
        seen["after"] = sharding.current_mesh()

    with sharding.use_mesh(m_main, head_shard=True):
        t = threading.Thread(target=worker)
        t.start()
        assert entered.wait(10)
        # the worker is INSIDE its scope right now — ours must be untouched
        assert sharding.current_mesh() is m_main
        assert sharding._ctx()["head_shard"] is True
        release.set()
        t.join(10)
        assert sharding.current_mesh() is m_main
    assert sharding.current_mesh() is None
    assert seen["before"] is None          # main's scope invisible to worker
    assert seen["inside"] is m_worker
    assert seen["after"] is None


# ---------------------------------------------------------------------------
# shared fixtures
# ---------------------------------------------------------------------------

def _mk_registry(plan, n=4):
    def mk(seed):
        lora = init_lora(plan, LORA_CFG, jax.random.PRNGKey(seed))
        return jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), lora)

    adapters = {"math": mk(11), "code": mk(22)}
    reg = AdapterRegistry(adapters["math"], max_adapters=n)
    for name, lora in adapters.items():
        reg.add(name, lora)
    return reg


def _run(plan, params, registry, cfg_kw, work, draft=None):
    """Serve ``work`` (list of (prompt, submit-kwargs)) through one engine;
    returns tokens in submission order."""
    sc = ServeConfig(**cfg_kw)
    if draft is not None:
        eng = SpeculativeServeEngine(plan, params, sc, registry, draft,
                                     lora_scale=LORA_CFG.scale)
    else:
        eng = ContinuousServeEngine(plan, params, sc, registry,
                                    lora_scale=LORA_CFG.scale)
    uids = [eng.submit(p, **kw) for p, kw in work]
    res = eng.run()
    return [np.asarray(res[u].tokens) for u in uids], eng


def _assert_identical(ref, got, work):
    assert len(ref) == len(got) == len(work)
    for i, (r, g) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(
            g, r, err_msg=f"request #{i} ({work[i][1]}) diverged between "
                          f"single-device and mesh-sharded serving")


# ---------------------------------------------------------------------------
# 2. token identity across families, with eviction
# ---------------------------------------------------------------------------

@needs_devices
@pytest.mark.parametrize("mesh_shape", MESHES)
@pytest.mark.parametrize("arch",
                         ["yi-34b", "gemma3-12b", "deepseek-moe-16b"])
def test_sharded_engine_token_identical_with_eviction(arch, mesh_shape):
    """Dense-cache continuous engine, 6 requests > 2 slots → every slot is
    evicted and re-admitted; mixed adapters and prompt lengths in flight."""
    cfg = get_smoke(arch)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    registry = _mk_registry(plan)

    rs = np.random.default_rng(0)
    spec = [(6, "math", 5), (9, "code", 4), (4, None, 5),
            (9, "math", 3), (6, "code", 5), (4, "math", 4)]
    work = [(rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32),
             dict(max_new_tokens=m, adapter=a)) for n, a, m in spec]

    base = dict(max_seq_len=48, max_slots=2, max_adapters=4,
                max_new_tokens=8, kv_cache_dtype="float32")
    ref, ref_eng = _run(plan, params, registry, base, work)
    assert ref_eng.mesh is None
    data, model = mesh_shape
    got, eng = _run(plan, params, registry,
                    {**base, "mesh_data": data, "mesh_model": model}, work)
    assert eng.mesh is not None and eng.mesh.shape["model"] == model
    _assert_identical(ref, got, work)


@needs_devices
def test_sharded_paged_chunked_prefill_and_shared_prefix_identical():
    """Paged pools + chunked prefill + copy-on-write prefix sharing, all
    mesh-sharded at once — page ids are a global namespace replicated over
    ``data``, so the allocator's decisions (and the tokens) cannot depend
    on the device count."""
    cfg = get_smoke("yi-34b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    registry = _mk_registry(plan)

    rs = np.random.default_rng(3)
    prefix = rs.integers(2, cfg.vocab_size, (10,)).astype(np.int32)
    work = []
    for i in range(5):
        suffix = rs.integers(2, cfg.vocab_size,
                             (int(rs.integers(3, 8)),)).astype(np.int32)
        work.append((np.concatenate([prefix, suffix]),
                     dict(max_new_tokens=4 + i % 3,
                          adapter=("math", "code", None)[i % 3],
                          prefix_id="system", prefix_len=len(prefix))))

    base = dict(max_seq_len=64, max_slots=2, max_adapters=4,
                max_new_tokens=8, kv_cache_dtype="float32",
                kv_paging=True, kv_page_size=8, prefill_chunk=8,
                prefix_sharing=True)
    ref, ref_eng = _run(plan, params, registry, base, work)
    got, eng = _run(plan, params, registry,
                    {**base, "mesh_data": 1, "mesh_model": 2}, work)
    assert eng.mesh is not None
    assert eng.n_prefill_chunks > 0        # chunking actually engaged
    assert eng.n_prefix_hits >= 1          # sharing actually engaged
    # the host allocator is device-count-agnostic: identical page telemetry
    assert eng.pages.peak_in_use == ref_eng.pages.peak_in_use
    _assert_identical(ref, got, work)


@needs_devices
def test_sharded_speculative_token_identical():
    """The pruned draft runs on the SAME mesh as the target; mixed
    speculative/plain slots, greedy — tokens must match the single-device
    speculative engine exactly."""
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    setup = loram.setup(plan, params, LORAM_CFG, LORA_CFG,
                        jax.random.PRNGKey(1))
    draft = draft_from_setup(setup, max_adapters=4)

    registry = None
    for name, seed in [("math", 11), ("code", 22)]:
        small = init_lora(setup.small_plan, LORA_CFG, jax.random.PRNGKey(seed))
        small = jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), small)
        full = recovery.recover_lora(small, setup.spec, plan,
                                     setup.small_plan)
        if registry is None:
            registry = AdapterRegistry(full, max_adapters=4)
        registry.add(name, full)
        draft.add(name, small)

    rs = np.random.default_rng(1)
    spec = [(6, "math", 5, True), (9, "code", 4, False), (4, None, 5, True),
            (9, "math", 3, True), (6, "code", 4, True)]
    work = [(rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32),
             dict(max_new_tokens=m, adapter=a, speculative=sp))
            for n, a, m, sp in spec]

    base = dict(max_seq_len=64, max_slots=2, max_adapters=4,
                max_new_tokens=8, kv_cache_dtype="float32", draft_gamma=3)
    ref, _ = _run(plan, params, registry, base, work, draft=draft)
    got, eng = _run(plan, params, registry,
                    {**base, "mesh_data": 1, "mesh_model": 2}, work,
                    draft=draft)
    assert eng.mesh is not None
    assert eng.n_proposed > 0 and eng.n_rounds > 0
    _assert_identical(ref, got, work)


@needs_devices
def test_sharded_streaming_adapter_bank_identical():
    """bank_slots < K under the mesh: three adapters stream through a
    2-row replicated bank mid-serve.  The host-side residency allocator's
    decisions (and so the tokens) cannot depend on the device count, and
    every row write is a fixed-shape functional update — the sharded tick
    never recompiles across uploads/evictions."""
    cfg = get_smoke("yi-34b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)

    def mk(seed):
        lora = init_lora(plan, LORA_CFG, jax.random.PRNGKey(seed))
        return jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), lora)

    adapters = {"math": mk(11), "code": mk(22), "law": mk(33)}

    def fresh_reg():
        reg = AdapterRegistry(adapters["math"], max_adapters=4, bank_slots=2)
        for name in ("math", "code", "law"):
            reg.add(name, adapters[name])
        return reg

    rs = np.random.default_rng(0)
    spec = [(6, "math", 5), (9, "code", 4), (4, None, 5),
            (9, "law", 3), (6, "math", 4), (5, "code", 3)]
    work = [(rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32),
             dict(max_new_tokens=m, adapter=a)) for n, a, m in spec]

    base = dict(max_seq_len=48, max_slots=2, max_adapters=4,
                adapter_bank_slots=2, max_new_tokens=8,
                kv_cache_dtype="float32")
    ref, ref_eng = _run(plan, params, fresh_reg(), base, work)
    sreg = fresh_reg()
    got, eng = _run(plan, params, sreg,
                    {**base, "mesh_data": 1, "mesh_model": 2}, work)
    assert eng.mesh is not None
    # the 2-row bank really streamed under the mesh
    assert sreg.residency.n_misses > 0 and sreg.residency.n_evictions > 0
    assert all(sreg.residency.refcount(a) == 0
               for a, _ in sreg.residency.assignments())
    _assert_identical(ref, got, work)

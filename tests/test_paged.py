"""Paged KV cache: block-table memory management through serving.

  1. page allocator — free-list discipline, trash-page reservation,
     exhaustion, release accounting
  2. prompt bucketing — power-of-two, page-aligned, O(log) distinct buckets
  3. scheduler preemption — requeue at the HEAD (FCFS preserved)
  4. token identity — the paged engine produces EXACTLY the dense
     continuous engine's tokens, across model families, including slot
     eviction/readmission and windowed (bounded-ring) layers
  5. pool exhaustion → preempt newest → requeue → identical completion
  6. speculative decoding on the paged path (pending K/V commits into
     pages for the accepted prefix only; the draft gets its own pool)
  7. γ auto-tuning controller math
  8. the Pallas paged-attention kernel against its jnp oracle
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LoRAConfig, LoRAMConfig, ServeConfig, get_smoke
from repro.core import loram, recovery
from repro.core.pruning import zero_prunable_tail
from repro.models import init_params, make_plan
from repro.models.model import init_lora
from repro.serving import (AdapterRegistry, ContinuousServeEngine,
                           GammaController, PageAllocator, PoolExhausted,
                           Request, Scheduler, SpeculativeServeEngine,
                           bucket_len, draft_from_setup, pages_for)

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# allocator / bucketing / scheduler (pure host-side)
# ---------------------------------------------------------------------------

def test_page_allocator_basics():
    a = PageAllocator(n_pages=6, page_size=4, max_pages_per_slot=5,
                      max_slots=2)
    assert a.free_pages == 5                  # page 0 is the trash page
    ids = a.alloc(0, 3)
    assert len(ids) == 3 and 0 not in ids     # trash page never handed out
    assert a.pages_in_use == 3 and a.peak_in_use == 3
    more = a.alloc(1, 2)
    assert not (set(ids) & set(more))         # no double allocation
    with pytest.raises(PoolExhausted):
        a.alloc(0, 1)
    assert a.pages_in_use == 5                # failed alloc changed nothing
    assert a.release(1) == 2
    assert a.free_pages == 2
    assert a.ensure(0, 2) == []               # already covered
    grown = a.ensure(0, 5)
    assert len(grown) == 2 and a.n_slot_pages(0) == 5
    assert a.peak_in_use == 5


def test_bucket_len_properties():
    for page in (1, 8, 16):
        seen = set()
        for n in range(1, 129):
            b = bucket_len(n, page, 128)
            assert b >= n and b % page == 0 and b <= 128
            seen.add(b)
        # O(log): at most log2(128)+1 distinct buckets
        assert len(seen) <= 8, (page, sorted(seen))
    assert bucket_len(5, 16, 128) == 16
    assert bucket_len(17, 16, 128) == 32
    assert pages_for(17, 16) == 2


def test_scheduler_preempt_requeues_head():
    s = Scheduler(max_slots=2)
    reqs = [Request(uid=s.new_uid(), prompt=np.ones(4, np.int32),
                    max_new_tokens=4) for _ in range(3)]
    for r in reqs:
        s.submit(r)
    slot0, _ = s.next_admission()
    slot1, _ = s.next_admission()
    got = s.preempt(slot1)
    assert got.uid == reqs[1].uid
    # preempted request is FIRST in line again — ahead of the later submit
    slot, nxt = s.next_admission()
    assert slot == slot1 and nxt.uid == reqs[1].uid
    # admission gate: a vetoed head blocks everything behind it (FCFS)
    s.evict(slot0)
    assert s.next_admission(gate=lambda r: False) is None
    assert s.queued == 1


# ---------------------------------------------------------------------------
# paged == dense, token for token
# ---------------------------------------------------------------------------

LORA_CFG = LoRAConfig(rank=4)


def _mixed_run(plan, params, vocab, *, registry=None, adapters=(),
               lora_scale=2.0, seqlen=64, slots=3, max_new=16,
               lens=(8, 12, 5, 11, 7, 13), news=(6, 4, 6, 3, 6, 5),
               **paged_kw):
    """Submit the same mixed workload through a dense and a paged engine;
    returns (dense results, paged engine, paged results)."""
    base = dict(max_seq_len=seqlen, max_slots=slots, max_new_tokens=max_new,
                kv_cache_dtype="float32", max_adapters=4)

    def build(**kw):
        reg = None
        if registry is not None:
            reg = AdapterRegistry(registry, max_adapters=4)
            for name, tree in adapters:
                reg.add(name, tree)
        return ContinuousServeEngine(plan, params, ServeConfig(**base, **kw),
                                     reg, lora_scale=lora_scale)

    dense = build()
    paged = build(kv_paging=True, **paged_kw)
    rs = np.random.default_rng(0)
    prompts = [rs.integers(2, vocab, (n,)).astype(np.int32) for n in lens]
    names = [a for a, _ in adapters] or [None]
    for eng in (dense, paged):
        for i, (p, m) in enumerate(zip(prompts, news)):
            eng.submit(p, max_new_tokens=m, adapter=names[i % len(names)])
    return dense.run(), paged, paged.run()


def _assert_identical(r1, r2):
    assert sorted(r1) == sorted(r2)
    for u in r1:
        np.testing.assert_array_equal(r1[u].tokens, r2[u].tokens,
                                      err_msg=f"uid {u}")


def test_paged_matches_dense_with_eviction_and_adapters():
    """Dense-family identity with 6 requests through 3 slots (every slot is
    evicted and re-admitted) and per-slot adapter routing."""
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)

    def mk(seed):
        lora = init_lora(plan, LORA_CFG, jax.random.PRNGKey(seed))
        return jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), lora)

    adapters = [("math", mk(11)), ("code", mk(22))]
    r1, paged, r2 = _mixed_run(plan, params, cfg.vocab_size,
                               registry=adapters[0][1], adapters=adapters,
                               lora_scale=LORA_CFG.scale, kv_page_size=8)
    _assert_identical(r1, r2)
    assert paged.pages.pages_in_use == 0      # everything released
    assert paged.pages.peak_in_use > 0


def test_paged_matches_dense_sliding_window():
    """gemma3 (window=8): windowed layers map their ring onto a bounded page
    set — page 4 → 2-page rings that wrap many times over 12 new tokens."""
    cfg = get_smoke("gemma3-12b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    r1, _, r2 = _mixed_run(plan, params, cfg.vocab_size, kv_page_size=4,
                           news=(12, 10, 12, 8, 12, 10))
    _assert_identical(r1, r2)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "zamba2-2.7b"])
def test_paged_matches_dense_families(arch):
    """MoE (lossless capacity under paging) and hybrid (dense SSM state
    beside pooled attention in one cache tree)."""
    cfg = get_smoke(arch)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    r1, _, r2 = _mixed_run(plan, params, cfg.vocab_size, kv_page_size=8)
    _assert_identical(r1, r2)


def test_pool_exhaustion_preempts_and_completes():
    """A pool too small for the traffic: the engine must preempt the newest
    slot, requeue it, and still produce exactly the dense engine's tokens."""
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    # 8 usable pages of 8 tokens vs 6 requests that each grow to ~6 pages
    r1, paged, r2 = _mixed_run(plan, params, cfg.vocab_size, max_new=48,
                               news=(40, 40, 40, 40, 40, 40),
                               kv_page_size=8, kv_pages=9)
    _assert_identical(r1, r2)
    assert paged.n_preemptions > 0, "tiny pool must have preempted"
    assert paged.pages.pages_in_use == 0


def test_paged_pool_too_small_rejected():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    with pytest.raises(ValueError):
        ContinuousServeEngine(
            plan, params,
            ServeConfig(max_seq_len=64, max_slots=2, kv_paging=True,
                        kv_page_size=8, kv_pages=8))   # needs 8 + trash


def test_paged_prefill_compiles_per_bucket_not_per_length():
    """9 distinct prompt lengths land in <= 3 buckets → <= 3 compiled
    prefill steps (the whole point of bucketing)."""
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    eng = ContinuousServeEngine(
        plan, params,
        ServeConfig(max_seq_len=64, max_slots=2, max_new_tokens=4,
                    kv_cache_dtype="float32", kv_paging=True, kv_page_size=8))
    rs = np.random.default_rng(0)
    for n in (3, 5, 7, 8, 9, 12, 15, 17, 25):
        eng.submit(rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32),
                   max_new_tokens=3)
    eng.run()
    assert set(eng._prefill_steps) <= {8, 16, 32}


# ---------------------------------------------------------------------------
# speculative decoding on the paged path
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def spec_setup():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    params = zero_prunable_tail(params, plan, 0.5)
    setup = loram.setup(plan, params,
                        LoRAMConfig(method="stru", ratio=0.5,
                                    keep_first=0, keep_last=0),
                        LORA_CFG, jax.random.PRNGKey(1))
    draft = draft_from_setup(setup, max_adapters=4)
    small = jax.tree.map(
        lambda x: x + 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), x.shape, x.dtype),
        init_lora(setup.small_plan, LORA_CFG, jax.random.PRNGKey(2)))
    full = recovery.recover_lora(small, setup.spec, plan, setup.small_plan)
    draft.add("t", small)
    return cfg, plan, params, draft, full


def test_paged_speculative_greedy_identity(spec_setup):
    """Greedy speculative decoding through the paged engine (pending K/V
    committed into pages for the accepted prefix only, draft pool shared
    with the target's block table) is token-identical to the plain DENSE
    continuous engine — including eviction/readmission (4 requests, 2
    slots)."""
    cfg, plan, params, draft, full = spec_setup
    base = dict(max_seq_len=64, max_slots=2, max_adapters=4,
                max_new_tokens=16, kv_cache_dtype="float32")

    reg1 = AdapterRegistry(full, max_adapters=4)
    reg1.add("t", full)
    plain = ContinuousServeEngine(plan, params, ServeConfig(**base), reg1,
                                  lora_scale=LORA_CFG.scale)
    reg2 = AdapterRegistry(full, max_adapters=4)
    reg2.add("t", full)
    spec = SpeculativeServeEngine(
        plan, params,
        ServeConfig(**base, draft_gamma=3, kv_paging=True, kv_page_size=8),
        reg2, draft, lora_scale=LORA_CFG.scale)

    rs = np.random.default_rng(0)
    jobs = [(9, "t", 8), (6, None, 12), (13, "t", 5), (5, "t", 10)]
    prompts = [rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)
               for n, _, _ in jobs]
    for eng in (plain, spec):
        for p, (_, a, m) in zip(prompts, jobs):
            eng.submit(p, max_new_tokens=m, adapter=a)
    r1, r2 = plain.run(), spec.run()
    _assert_identical(r1, r2)
    assert spec.acceptance_rate > 0.9         # lossless-prune draft
    assert spec.pages.pages_in_use == 0


@pytest.mark.slow
def test_paged_speculative_windowed_rollback():
    """gemma3 windowed rings under paged speculation: rejected draft writes
    roll back from saved pre-write rows inside 2-page rings that wrap."""
    cfg = get_smoke("gemma3-12b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    setup = loram.setup(plan, params,
                        LoRAMConfig(method="stru", ratio=0.5,
                                    keep_first=0, keep_last=0),
                        LORA_CFG, jax.random.PRNGKey(1))
    draft = draft_from_setup(setup, max_adapters=0)
    base = dict(max_seq_len=64, max_slots=2, max_new_tokens=16,
                kv_cache_dtype="float32")
    plain = ContinuousServeEngine(plan, params, ServeConfig(**base))
    spec = SpeculativeServeEngine(
        plan, params,
        ServeConfig(**base, draft_gamma=4, kv_paging=True, kv_page_size=4),
        None, draft)
    rs = np.random.default_rng(0)
    prompts = [rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (9, 6, 13, 5)]
    for eng in (plain, spec):
        for p in prompts:
            eng.submit(p, max_new_tokens=12)
    _assert_identical(plain.run(), spec.run())


@pytest.mark.parametrize("paging", [False, True],
                         ids=["dense-spec", "paged-spec"])
def test_speculative_round_straddles_buffer_end(spec_setup, paging):
    """Requests that fill cache AND output buffer to the brim: the final
    speculative round's writes straddle max_seq_len / max_new_tokens, and
    every straddling scatter row must be DROPPED, never clamped — a clamped
    index duplicates a kept row's index in the same scatter and the winner
    is implementation-defined (observed: the request's last token lost to
    the stale clamped row, on the dense engine too).  Identity with the
    plain engine over full-to-capacity sequences proves the drop paths."""
    cfg, plan, params, draft, full = spec_setup
    base = dict(max_seq_len=32, max_slots=2, max_adapters=4,
                max_new_tokens=24, kv_cache_dtype="float32")
    reg1 = AdapterRegistry(full, max_adapters=4)
    reg1.add("t", full)
    plain = ContinuousServeEngine(plan, params, ServeConfig(**base), reg1,
                                  lora_scale=LORA_CFG.scale)
    reg2 = AdapterRegistry(full, max_adapters=4)
    reg2.add("t", full)
    paged_kw = dict(kv_paging=True, kv_page_size=8) if paging else {}
    spec = SpeculativeServeEngine(
        plan, params,
        ServeConfig(**base, draft_gamma=4, **paged_kw),
        reg2, draft, lora_scale=LORA_CFG.scale)
    rs = np.random.default_rng(2)
    # prompt + max_new == max_seq_len exactly, max_new == buffer width
    jobs = [(9, 23), (10, 22), (8, 24)]
    prompts = [rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)
               for n, _ in jobs]
    for eng in (plain, spec):
        for p, (_, m) in zip(prompts, jobs):
            eng.submit(p, max_new_tokens=m, adapter="t")
    _assert_identical(plain.run(), spec.run())


# ---------------------------------------------------------------------------
# γ auto-tuning controller
# ---------------------------------------------------------------------------

def test_gamma_controller_math():
    ctl = GammaController(gamma_max=8, c_draft=0.3, c_verify=1.75)
    # closed form matches brute force at every alpha
    for alpha in (0.0, 0.3, 0.6, 0.9, 1.0):
        for g in range(1, 9):
            brute = sum(alpha ** i for i in range(g))
            assert ctl.expected_tokens(g, alpha) == pytest.approx(brute)
        best = max(range(1, 9), key=lambda g: ctl.throughput(g, alpha))
        assert ctl.best_gamma(alpha) == best
    # alpha=0: every round emits exactly 1 token → shortest draft wins
    assert ctl.best_gamma(0.0) == 1
    # near-perfect drafts want the longest allowed draft
    assert ctl.best_gamma(1.0) == 8


def test_gamma_controller_adapts_and_hysteresis():
    ctl = GammaController(gamma_max=8, min_samples=16)
    # warm-up: no switching before the estimate has seen enough proposals
    assert ctl.propose(4) == 4
    for _ in range(16):
        ctl.update(accepted=0, proposed=8)    # terrible draft
    assert ctl.acceptance < 0.1
    assert ctl.propose(6) == 1                # collapse to gamma=1
    for _ in range(40):
        ctl.update(accepted=8, proposed=8)    # perfect draft
    assert ctl.propose(1) == 8                # stretch back out
    # hysteresis: tiny predicted gains do not move gamma
    g = ctl.best_gamma()
    assert ctl.propose(g) == g


def test_gamma_autotune_in_engine(spec_setup):
    """End-to-end: with gamma_autotune on and a lossless draft (acceptance
    ~1), the engine should grow gamma from 1 — and stay token-identical."""
    cfg, plan, params, draft, full = spec_setup
    base = dict(max_seq_len=64, max_slots=2, max_adapters=4,
                max_new_tokens=32, kv_cache_dtype="float32")
    reg1 = AdapterRegistry(full, max_adapters=4)
    reg1.add("t", full)
    plain = ContinuousServeEngine(plan, params, ServeConfig(**base), reg1,
                                  lora_scale=LORA_CFG.scale)
    reg2 = AdapterRegistry(full, max_adapters=4)
    reg2.add("t", full)
    spec = SpeculativeServeEngine(
        plan, params,
        ServeConfig(**base, draft_gamma=1, gamma_autotune=True), reg2, draft,
        lora_scale=LORA_CFG.scale)
    rs = np.random.default_rng(1)
    prompts = [rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (9, 6, 13, 5, 8, 7)]
    for eng in (plain, spec):
        for p in prompts:
            eng.submit(p, max_new_tokens=30, adapter="t")
    r1, r2 = plain.run(), spec.run()
    _assert_identical(r1, r2)
    assert spec.gamma > 1, "acceptance ~1 should have grown gamma"


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    # (B, H, K, D, page, R, window)
    (4, 8, 4, 32, 16, 4, 0),       # full attention, GQA 2:1
    (3, 4, 2, 16, 8, 2, 12),       # bounded ring, window inside 2 pages
    (2, 4, 4, 32, 8, 3, 20),       # MHA, ring > window
])
def test_paged_decode_kernel_matches_ref(shape):
    from repro.kernels import ops
    from repro.kernels.ref import paged_decode_attention_ref
    B, H, K, D, page, R, window = shape
    rng = np.random.default_rng(0)
    n_pages = B * R + 1
    q = jnp.asarray(rng.normal(size=(B, H, D)).astype(np.float32))
    pk = jnp.asarray(rng.normal(size=(n_pages, page, K, D)).astype(np.float32))
    pv = jnp.asarray(rng.normal(size=(n_pages, page, K, D)).astype(np.float32))
    table = jnp.asarray(
        rng.permutation(np.arange(1, n_pages))[:B * R]
        .reshape(B, R).astype(np.int32))
    pos = jnp.asarray(rng.integers(0, R * page, size=(B,)).astype(np.int32))
    ref = paged_decode_attention_ref(q, pk, pv, table, pos, window=window)
    pal = ops.paged_decode_attention(q, pk, pv, table, pos, window=window,
                                     force="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), atol=2e-5)

"""Per-architecture smoke tests: a REDUCED config of the same family runs one
forward and one train step on CPU; output shapes + no NaNs (task spec §f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LoRAConfig, SMOKE, TrainConfig, get_smoke
from repro.configs.registry import ARCHS
from repro.models import forward, init_lora, init_params, make_plan
from repro.optim import adamw_init
from repro.runtime.steps import make_train_step

ALL_ARCHS = list(ARCHS)
B, S = 2, 16


def _frontend(cfg, b):
    if cfg.family == "encdec":
        return jnp.ones((b, cfg.enc_len, cfg.d_model), jnp.float32) * 0.01
    if cfg.family == "vlm":
        return jnp.ones((b, cfg.n_patches, cfg.d_model), jnp.float32) * 0.01
    return None


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS if a in SMOKE])
def test_forward_shapes_no_nans(arch, rng):
    cfg = get_smoke(arch)
    plan = make_plan(cfg)
    params = init_params(plan, rng, jnp.float32)
    lora = init_lora(plan, LoRAConfig(rank=4), rng)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    logits, aux = forward(plan, params, tokens, lora, frontend=_frontend(cfg, B))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS if a in SMOKE])
@pytest.mark.slow
def test_train_step_decreases_nothing_nan(arch, rng):
    cfg = get_smoke(arch)
    plan = make_plan(cfg)
    params = init_params(plan, rng, jnp.float32)
    lora_cfg = LoRAConfig(rank=4)
    lora = init_lora(plan, lora_cfg, rng)
    tc = TrainConfig(global_batch=B, seq_len=S, learning_rate=1e-3,
                     total_steps=10, warmup_steps=1, remat=False)
    step = jax.jit(make_train_step(plan, tc, lora_cfg, n_micro=1))
    batch = {
        "tokens": np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
        "labels": np.random.default_rng(1).integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
    }
    if _frontend(cfg, B) is not None:
        batch["frontend"] = np.asarray(_frontend(cfg, B))
    opt = adamw_init(lora)
    # step=1: warmup_cosine(0) is 0 by construction (lr ramps from zero)
    lora2, opt2, metrics = step(params, lora, opt, jnp.asarray(1), batch)
    assert np.isfinite(float(metrics["loss"]))
    # adapters actually moved
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(lora), jax.tree.leaves(lora2)))
    assert delta > 0.0

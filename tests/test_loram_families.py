"""LoRAM structured pruning across every architecture family — the index
math differs per family (FFN channels, GQA KV-groups, MoE experts, SSD
heads, packed Mamba in_proj columns), so each gets its own cycle test:

  prune → (train-free adapter perturbation) → recover → merge
  ⇒ merged-full-model ≡ full-model + recovered adapters (numerically)
  ⇒ pruned model still runs forward/decode
  ⇒ keep-counts respect family constraints
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LoRAConfig, LoRAMConfig, get_smoke
from repro.core import loram, pruning, recovery
from repro.models import forward, init_params, make_plan

RNG = jax.random.PRNGKey(0)

FAMILY_ARCHS = [
    "yi-34b",            # dense GQA: ff + kv-groups
    "granite-20b",       # MQA (kv=1): ff only — kv must never go below 1
    "gemma3-12b",        # local:global superblock (12 blocks / superblock)
    "deepseek-moe-16b",  # routed experts pruned, shared experts kept
    "arctic-480b",       # experts + dense-residual ff
    "mamba2-370m",       # SSD heads (packed in_proj columns)
    "zamba2-2.7b",       # hybrid: mamba heads pruned, shared attn untouched
    "whisper-tiny",      # enc-dec: decoder pruned, cross-attn recovered
]


def _perturbed(lora):
    return jax.tree.map(
        lambda x: x + 0.02 * jax.random.normal(RNG, x.shape, x.dtype), lora)


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_family_prune_recover_merge(arch):
    cfg = get_smoke(arch)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    lcfg = LoRAMConfig(method="rand", ratio=0.5, keep_first=0, keep_last=0)
    setup = loram.setup(plan, params, lcfg, LoRAConfig(rank=4), RNG)

    # family-specific keep-count constraints
    for st in setup.small_plan.stages:
        d = st.dims
        if d.d_ff and d.d_ff != cfg.d_ff:       # pruned → MXU-aligned
            assert d.d_ff % 128 == 0 and d.d_ff >= 128
        if cfg.n_kv_heads == 1:
            assert d.n_kv_heads == 1                      # MQA preserved
        if d.n_experts:
            assert d.n_experts > d.top_k                  # routing stays valid
            assert d.n_shared_experts == cfg.n_shared_experts  # never pruned
        if d.ssm_heads:
            assert d.ssm_heads % 2 == 0                   # 128-aligned channels
            assert d.d_inner == d.ssm_heads * d.ssm_head_dim

    # pruned model runs
    B, S = 2, 8
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.family == "encdec":
        fe = 0.1 * jax.random.normal(RNG, (B, cfg.enc_len, cfg.d_model))
    elif cfg.family == "vlm":
        fe = jnp.ones((B, cfg.n_patches, cfg.d_model)) * 0.02
    lg_small, _ = forward(setup.small_plan, setup.small_params, tokens,
                          setup.lora0, lora_scale=4.0, frontend=fe)
    assert not bool(jnp.isnan(lg_small).any())

    # recover + merge equivalence on the FULL model
    lora = _perturbed(setup.lora0)
    lora_full, merged = loram.finalize(setup, lora, params)
    assert recovery.delta_support_check(setup.spec, plan, lora_full)
    lg_m, _ = forward(plan, merged, tokens, frontend=fe)
    lg_a, _ = forward(plan, params, tokens, lora_full, lora_scale=4.0,
                      frontend=fe)
    np.testing.assert_allclose(np.asarray(lg_m), np.asarray(lg_a),
                               rtol=3e-3, atol=3e-3, err_msg=arch)
    # merging changed the model (adapters non-trivial)
    lg_b, _ = forward(plan, params, tokens, frontend=fe)
    assert float(jnp.abs(lg_m - lg_b).max()) > 1e-5


def test_mamba_inproj_column_map():
    """The packed in_proj layout [z|x|B|C|dt] must gather exactly the kept
    heads' channels in z and x, all of B/C, and kept heads in dt."""
    cfg = get_smoke("mamba2-370m")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    lcfg = LoRAMConfig(method="rand", ratio=0.5, keep_first=0, keep_last=0)
    small_plan, small_params, spec = pruning.prune(plan, params, lcfg)
    st = plan.stages[0]
    d = st.dims
    sd = small_plan.stages[0].dims
    wp = spec.stage_specs[small_plan.stages[0].name]["mamba"]["in_proj"][0]
    idx = np.asarray(wp.idx)
    di, N, H, P = d.d_inner, d.ssm_state, d.ssm_heads, d.ssm_head_dim
    # expected column count: 2·kept_channels + 2N + kept_heads
    kept_ch = sd.d_inner
    assert idx.shape[1] == 2 * kept_ch + 2 * N + sd.ssm_heads
    for li in range(idx.shape[0]):
        cols = idx[li]
        z = cols[:kept_ch]
        x = cols[kept_ch:2 * kept_ch]
        bc = cols[2 * kept_ch:2 * kept_ch + 2 * N]
        dt = cols[2 * kept_ch + 2 * N:]
        assert (z < di).all()
        assert ((x >= di) & (x < 2 * di)).all()
        np.testing.assert_array_equal(x, z + di)          # same channels
        np.testing.assert_array_equal(bc, np.arange(2 * di, 2 * di + 2 * N))
        assert ((dt >= 2 * di + 2 * N) & (dt < 2 * di + 2 * N + H)).all()
        # dt heads correspond to the kept channel blocks
        np.testing.assert_array_equal((z.reshape(-1, P)[:, 0]) // P,
                                      dt - 2 * di - 2 * N)


def test_qloram_train_step_with_nf4_base():
    """jit'd train step through QTensor frozen base (scan-sliced codes)."""
    from repro.configs import TrainConfig
    from repro.optim import adamw_init
    from repro.runtime.steps import make_train_step

    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_model=128,
                              d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    lcfg = LoRAMConfig(method="stru", ratio=0.5, quantize=True,
                       keep_first=0, keep_last=0)
    lora_cfg = LoRAConfig(rank=4)
    setup = loram.setup(plan, params, lcfg, lora_cfg, RNG)
    tc = TrainConfig(global_batch=4, seq_len=16, total_steps=10,
                     warmup_steps=1, remat=True)
    step = jax.jit(make_train_step(setup.small_plan, tc, lora_cfg, n_micro=2))
    batch = {
        "tokens": np.ones((4, 16), np.int32),
        "labels": np.ones((4, 16), np.int32),
    }
    lora, opt, metrics = step(setup.small_params, setup.lora0,
                              adamw_init(setup.lora0), jnp.asarray(1), batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(setup.lora0), jax.tree.leaves(lora)))
    assert moved > 0


def test_expert_prune_keeps_router_consistent():
    cfg = get_smoke("deepseek-moe-16b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    lcfg = LoRAMConfig(method="rand", ratio=0.5, keep_first=0, keep_last=0)
    small_plan, small_params, spec = pruning.prune(plan, params, lcfg)
    st = small_plan.stages[0]
    bp = small_params["stages"][st.name]["stacked"]["moe"]
    e = st.dims.n_experts
    assert bp["router"].shape[-1] == e
    assert bp["we_g"].shape[1] == e
    # router columns match the kept experts' weights
    wp_router = spec.stage_specs[st.name]["moe"]["router"][0]
    wp_exp = spec.stage_specs[st.name]["moe"]["we_g"][0]
    np.testing.assert_array_equal(np.asarray(wp_router.idx),
                                  np.asarray(wp_exp.idx))

"""QLoRAM serving quantization: NF4 base weights + int8 paged KV.

  1. NF4 storage edges — scale-dtype-derived QTensor.dtype, a partial
     trailing block, double-quantized scales, stacked 3-D stage weights
  2. name-keyed engine-load quantization (quantize_by_name) + packed-vs-
     logical byte accounting
  3. the fused NF4 matmul at serving shapes (Pallas interpret vs oracle)
     and the dense() hot-path routing vs dequantize-then-matmul
  4. int8 paged pools: the quantized decode/chunk kernels (interpret) vs
     the quant oracles vs the fp oracle over explicitly dequantized pools
  5. engine-level token compatibility: the int8-KV continuous engine
     reproduces the fp paged engine's greedy streams EXACTLY at a fraction
     of the pool bytes; the nf4-weight engine loads packed and still serves
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantPolicy, ServeConfig, get_smoke
from repro.kernels import ops
from repro.kernels.paged_attention import (paged_chunk_attention,
                                           paged_decode_attention)
from repro.kernels.ref import (paged_chunk_attention_ref,
                               paged_decode_attention_ref)
from repro.models import init_params, make_plan
from repro.models import layers
from repro.models.model import init_paged_cache
from repro.quant import kv as qkv
from repro.quant import nf4
from repro.serving import ContinuousServeEngine

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# NF4 storage edges
# ---------------------------------------------------------------------------

def test_qtensor_dtype_derives_from_scales():
    """Regression: QTensor.dtype follows the stored scale dtype (it was once
    hard-coded bfloat16, which mis-typed f32 serving params downstream)."""
    w = jnp.asarray(np.random.default_rng(0).standard_normal((64, 8)),
                    jnp.float32)
    assert nf4.quantize(w, scale_dtype=jnp.float16).dtype == jnp.float16
    assert nf4.quantize(w, scale_dtype=jnp.float32).dtype == jnp.float32
    qd = nf4.quantize(w, double_quant=True)
    assert isinstance(qd.scales, nf4.DQScales)
    assert qd.dtype == jnp.float32            # DQScales absmax dtype


def test_partial_trailing_block_roundtrip_exact():
    """d_in = 96 with block 64 → one full + one partial block, each with its
    own absmax.  Weights built FROM codebook values × per-block scales (with
    a ±1 entry pinning each block's absmax) must round-trip exactly."""
    rs = np.random.default_rng(1)
    d_in, d_out, block = 96, 16, 64
    nb = 2
    idx = rs.integers(0, 16, (d_in, d_out))
    idx[0, :] = 0          # -1.0 → block 0 absmax == its scale
    idx[64, :] = 15        # +1.0 → partial block absmax == its scale
    scales = rs.uniform(0.05, 2.0, (nb, d_out)).astype(np.float32)
    w = nf4.NF4_CODEBOOK[idx] * np.repeat(scales, block, axis=0)[:d_in]
    q = nf4.quantize(jnp.asarray(w), block=block, scale_dtype=jnp.float32)
    assert q.scales.shape == (nb, d_out)
    np.testing.assert_allclose(np.asarray(q.scales), scales, rtol=1e-6)
    back = nf4.dequantize(q, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), w, rtol=1e-6, atol=1e-7)


def test_double_quant_scales_close_and_smaller():
    rs = np.random.default_rng(2)
    w = jnp.asarray(rs.standard_normal((256, 32)) * 0.1, jnp.float32)
    qp = nf4.quantize(w, scale_dtype=jnp.float32)
    qd = nf4.quantize(w, double_quant=True)
    assert isinstance(qd.scales, nf4.DQScales)
    # int8 secondary quantizer: ≤ ~1% relative scale error end to end
    dp = np.asarray(nf4.dequantize(qp, jnp.float32))
    dd = np.asarray(nf4.dequantize(qd, jnp.float32))
    np.testing.assert_allclose(dd, dp, rtol=0.02, atol=0.02 * np.abs(dp).max())
    # and the scales genuinely shrink: int8 codes + grouped fp32 absmax
    # vs one fp32 per block
    assert qd.nbytes_logical < qp.nbytes_logical
    # both storage forms reconstruct through the one shared helper
    assert nf4._scales_f32(qd.scales).shape == qp.scales.shape


def test_quantize_stacked_matches_per_slice():
    rs = np.random.default_rng(3)
    w = jnp.asarray(rs.standard_normal((3, 128, 32)) * 0.2, jnp.float32)
    qs = nf4.quantize_stacked(w, scale_dtype=jnp.float16)
    assert qs.codes.shape == (3, 64, 32)
    back = nf4.dequantize_stacked(qs, jnp.float32)
    for layer in range(3):
        ql = nf4.quantize(w[layer], scale_dtype=jnp.float16)
        np.testing.assert_array_equal(np.asarray(qs.codes[layer]),
                                      np.asarray(ql.codes))
        np.testing.assert_array_equal(np.asarray(qs.scales[layer]),
                                      np.asarray(ql.scales))
        np.testing.assert_array_equal(np.asarray(back[layer]),
                                      np.asarray(nf4.dequantize(ql,
                                                                jnp.float32)))


# ---------------------------------------------------------------------------
# engine-load quantization + byte accounting
# ---------------------------------------------------------------------------

def test_quantize_by_name_targets_and_bytes():
    rs = np.random.default_rng(4)
    params = {
        "stages": [{
            "wq": jnp.asarray(rs.standard_normal((128, 64)), jnp.float32),
            "wk": jnp.asarray(rs.standard_normal((3, 128, 32)), jnp.float32),
            # contraction dim not block-aligned → must stay fp
            "wd": jnp.asarray(rs.standard_normal((96, 64)), jnp.float32),
            "norm": jnp.asarray(rs.standard_normal((64,)), jnp.float32),
        }],
        "emb": jnp.asarray(rs.standard_normal((256, 64)), jnp.float32),
    }
    q = nf4.quantize_by_name(params)
    st = q["stages"][0]
    assert isinstance(st["wq"], nf4.QTensor) and st["wq"].codes.ndim == 2
    assert isinstance(st["wk"], nf4.QTensor) and st["wk"].codes.ndim == 3
    assert not isinstance(st["wd"], nf4.QTensor)      # 96 % 64 != 0
    assert not isinstance(st["norm"], nf4.QTensor)
    assert not isinstance(q["emb"], nf4.QTensor)      # name not targeted
    assert nf4.param_bytes(q) < nf4.param_bytes(params)
    assert nf4.param_bytes_logical(q) == nf4.param_bytes_logical(params)
    # idempotent: a second pass leaves existing QTensors untouched
    q2 = nf4.quantize_by_name(q)
    np.testing.assert_array_equal(np.asarray(q2["stages"][0]["wq"].codes),
                                  np.asarray(st["wq"].codes))


# ---------------------------------------------------------------------------
# fused NF4 matmul: serving shapes + dense() routing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (4, 128, 512),       # decode tick: slots × d_model → d_ff
    (8, 256, 1024),
    (1, 64, 128),        # single-slot smoke dims
])
def test_fused_matmul_serving_shapes(m, k, n):
    rs = np.random.default_rng(m + k + n)
    x = jnp.asarray(rs.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rs.standard_normal((k, n)) * 0.1, jnp.float32)
    q = nf4.quantize(w, scale_dtype=jnp.float32)
    out = ops.nf4_matmul(x, q.codes, q.scales, force="pallas")
    ref = ops.nf4_matmul(x, q.codes, q.scales)        # CPU → jnp oracle
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_dense_fused_routing_matches_dequant_oracle():
    """layers.dense must route a fusable QTensor through the fused kernel
    and produce the dequantize-then-matmul answer."""
    rs = np.random.default_rng(5)
    x = jnp.asarray(rs.standard_normal((4, 128)), jnp.float32)
    w = jnp.asarray(rs.standard_normal((128, 512)) * 0.1, jnp.float32)
    q = nf4.quantize(w, scale_dtype=jnp.float32)
    assert layers._nf4_fusable(q, 4, None)
    y = layers.dense(x, q)
    oracle = x @ nf4.dequantize(q, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)
    # a sparsity mask disqualifies fusion; the fallback must still agree
    # (an all-ones mask changes nothing)
    mask = jnp.ones_like(w)
    assert not layers._nf4_fusable(q, 4, mask)
    np.testing.assert_allclose(np.asarray(layers.dense(x, q, mask=mask)),
                               np.asarray(oracle), rtol=1e-5, atol=1e-5)
    # double-quantized scales fall back to dequantize-then-matmul too
    qd = nf4.quantize(w, double_quant=True)
    assert not layers._nf4_fusable(qd, 4, None)
    yd = layers.dense(x, qd)
    assert np.isfinite(np.asarray(yd)).all()
    np.testing.assert_allclose(np.asarray(yd), np.asarray(oracle),
                               rtol=0.1, atol=0.05 * np.abs(oracle).max())


# ---------------------------------------------------------------------------
# int8 paged pools: quantized kernels vs oracles
# ---------------------------------------------------------------------------

def _quant_pools(rs, n_pages, page, K, D):
    fp_k = jnp.asarray(rs.standard_normal((n_pages, page, K, D)) * 0.5,
                       jnp.float32)
    fp_v = jnp.asarray(rs.standard_normal((n_pages, page, K, D)) * 0.5,
                       jnp.float32)
    ck, ks = qkv.quantize_rows(fp_k)
    cv, vs = qkv.quantize_rows(fp_v)
    return (qkv.dequantize_rows(ck, ks), qkv.dequantize_rows(cv, vs),
            ck, cv, ks, vs)


@pytest.mark.parametrize("window", [0, 16])
def test_quant_paged_decode_kernel_matches_oracles(window):
    rs = np.random.default_rng(6)
    B, H, K, D, page = 2, 4, 2, 16, 8
    R = 2 if window else 4                    # ring ≥ window when windowed
    n_pages = 9
    dq_k, dq_v, ck, cv, ks, vs = _quant_pools(rs, n_pages, page, K, D)
    q = jnp.asarray(rs.standard_normal((B, H, D)) * 0.5, jnp.float32)
    table = jnp.asarray(rs.choice(n_pages, (B, R), replace=False), jnp.int32)
    pos = jnp.asarray([13, 29], jnp.int32)
    # fp oracle over the EXPLICITLY dequantized pool defines the semantics
    want = paged_decode_attention_ref(q, dq_k, dq_v, table, pos,
                                      window=window)
    got_ref = paged_decode_attention_ref(q, ck, cv, table, pos, k_scale=ks,
                                         v_scale=vs, window=window)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    got_pl = paged_decode_attention(q, ck, cv, table, pos, k_scale=ks,
                                    v_scale=vs, window=window,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [0, 16])
def test_quant_paged_chunk_kernel_matches_oracles(window):
    rs = np.random.default_rng(7)
    B, C, H, K, D, page = 2, 8, 4, 2, 16, 8
    R = 2 if window else 4
    n_pages = 9
    dq_k, dq_v, ck, cv, ks, vs = _quant_pools(rs, n_pages, page, K, D)
    q = jnp.asarray(rs.standard_normal((B, C, H, D)) * 0.5, jnp.float32)
    k_new = jnp.asarray(rs.standard_normal((B, C, K, D)) * 0.5, jnp.float32)
    v_new = jnp.asarray(rs.standard_normal((B, C, K, D)) * 0.5, jnp.float32)
    table = jnp.asarray(rs.choice(n_pages, (B, R), replace=False), jnp.int32)
    pos = jnp.asarray([8, 16], jnp.int32)
    want = paged_chunk_attention_ref(q, k_new, v_new, dq_k, dq_v, table, pos,
                                     window=window)
    got_ref = paged_chunk_attention_ref(q, k_new, v_new, ck, cv, table, pos,
                                        k_scale=ks, v_scale=vs,
                                        window=window)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    got_pl = paged_chunk_attention(q, k_new, v_new, ck, cv, table, pos,
                                   k_scale=ks, v_scale=vs, window=window,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_kv_roundtrip_deterministic_and_exact_on_codes():
    """quantize_rows is the ONE scatter-site quantizer: same fp row → same
    codes from any writer, and code-representable rows round-trip exactly."""
    rs = np.random.default_rng(8)
    x = jnp.asarray(rs.standard_normal((5, 3, 16)), jnp.float32)
    c1, s1 = qkv.quantize_rows(x)
    c2, s2 = qkv.quantize_rows(x)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # a dequantized row re-quantizes to the same codes (idempotent commit)
    back = qkv.dequantize_rows(c1, s1)
    c3, _ = qkv.quantize_rows(back)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c3))


def test_init_paged_cache_quant_layout():
    cfg = get_smoke("llama2-13b")
    plan = make_plan(cfg)
    cache = init_paged_cache(plan, 2, 5, 4, jnp.float32, quant_kv=True)
    for stage_cache in cache.values():
        for bc in stage_cache.values():
            if isinstance(bc, dict) and "k" in bc:
                assert qkv.quant_cache_keys(bc)
                assert bc["k"].dtype == jnp.int8
                assert bc["k_sc"].dtype == qkv.KV_SCALE_DTYPE
                assert bc["k_sc"].shape == bc["k"].shape[:-1] + (1,)
                assert bc["v_sc"].shape == bc["v"].shape[:-1] + (1,)


# ---------------------------------------------------------------------------
# engine-level token compatibility
# ---------------------------------------------------------------------------

def _run_engine(plan, vocab, params, quant, *, lens=(8, 12, 5), news=(6, 4, 6)):
    eng = ContinuousServeEngine(
        plan, params,
        ServeConfig(max_seq_len=64, max_slots=3, max_new_tokens=8,
                    kv_cache_dtype="float32", kv_paging=True, kv_page_size=8,
                    quant=quant))
    rs = np.random.default_rng(0)
    for n, m in zip(lens, news):
        eng.submit(rs.integers(2, vocab, (n,)).astype(np.int32),
                   max_new_tokens=m)
    return eng.run(), eng


def test_int8_kv_engine_matches_fp_exactly():
    """The QLoRAM token-compatibility gate: with a dense-equivalent pool (no
    preemption) the int8-KV engine's greedy streams are EXACTLY the fp paged
    engine's — per-row absmax error never crosses an argmax margin here."""
    cfg = get_smoke("llama2-13b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    r_fp, e_fp = _run_engine(plan, cfg.vocab_size, params, QuantPolicy())
    r_q, e_q = _run_engine(plan, cfg.vocab_size, params,
                           QuantPolicy(kv="int8"))
    assert sorted(r_fp) == sorted(r_q)
    for u in r_fp:
        np.testing.assert_array_equal(r_fp[u].tokens, r_q[u].tokens,
                                      err_msg=f"uid {u}")
    # int8 codes + f32 scales: ≥ 2x fewer pool bytes at equal page count
    assert 2 * e_q.kv_cache_bytes() <= e_fp.kv_cache_bytes()


@pytest.mark.slow
def test_int8_kv_engine_matches_fp_sliding_window():
    """Windowed (bounded-ring) layers: scale pools ride the same ring
    wrap/overwrite discipline as their code pools.  Six stacked windowed
    layers on random-init weights accumulate enough int8 rounding that one
    greedy near-tie may flip mid-stream, so the gate is a strong-but-
    tolerant one (every stream's opening tokens exact, most streams fully
    exact) rather than the dense-pool test's full identity."""
    cfg = get_smoke("gemma3-12b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    kw = dict(lens=(8, 12, 5, 11), news=(8, 6, 8, 5))
    r_fp, _ = _run_engine(plan, cfg.vocab_size, params, QuantPolicy(), **kw)
    r_q, _ = _run_engine(plan, cfg.vocab_size, params,
                         QuantPolicy(kv="int8"), **kw)
    assert sorted(r_fp) == sorted(r_q)
    exact = 0
    for u in r_fp:
        a = np.asarray(r_fp[u].tokens)
        b = np.asarray(r_q[u].tokens)
        np.testing.assert_array_equal(a[:3], b[:3], err_msg=f"uid {u}")
        exact += np.array_equal(a, b)
    assert exact >= 0.7 * len(r_fp), (exact, len(r_fp))


def test_nf4_weight_engine_loads_packed_and_serves():
    """quant.weights='nf4': projections quantize once at engine load,
    embeddings/norms/LoRA banks stay fp, and the engine still decodes end to
    end through the fused dense() routing.  At smoke dims the fp leaves
    (embeddings + adapter banks) dominate, so the whole-tree gate is >= 2x
    packed rather than the full-dims >= 3x the bench asserts."""
    cfg = get_smoke("llama2-13b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    r_q, eng = _run_engine(plan, cfg.vocab_size, params,
                           QuantPolicy(weights="nf4", kv="int8"),
                           lens=(8, 12), news=(4, 4))
    assert all(r.n_generated == 4 for r in r_q.values())
    leaves = jax.tree_util.tree_leaves(
        eng.params, is_leaf=lambda x: isinstance(x, nf4.QTensor))
    assert any(isinstance(x, nf4.QTensor) for x in leaves)
    assert 2 * nf4.param_bytes(eng.params) <= nf4.param_bytes_logical(
        eng.params)


def test_quant_kv_requires_paging():
    cfg = get_smoke("llama2-13b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    with pytest.raises(ValueError, match="kv_paging"):
        ContinuousServeEngine(
            plan, params,
            ServeConfig(max_seq_len=32, max_slots=2, max_new_tokens=4,
                        quant=QuantPolicy(kv="int8")))

"""Chunked prefill + copy-on-write prefix sharing over the paged KV cache.

  1. refcounting page allocator — share / retain / fork (COW) / release
     discipline, plus a randomized interleaving property test against a
     pure-python reference model (no double-free, no leak, no freeing a
     page whose refcount > 0, exact peak accounting)
  2. chunked prefill — token identity with the monolithic paged engine
     across dense / sliding-window (and MoE / hybrid in the slow sweep),
     including eviction/readmission and pool-exhaustion preemption
  3. prefix sharing — token identity with unshared runs incl. COW
     divergence at (and off) a page boundary, adapter-keyed entries,
     eviction of sharers, second-wave reuse, and measured page/FLOP savings
  4. speculative slots — the draft/verify round composes with both:
     shared pages are forked before any commit can touch them
  5. γ-lookahead growth audit — an autosized/exact pool never preempts
     mid-speculative-round at full occupancy (the uncapped reservation did)
  6. the Pallas chunk-attention kernel against its jnp oracle
"""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import hypothesis, st
from repro.configs import LoRAConfig, LoRAMConfig, ServeConfig, get_smoke
from repro.core import loram, recovery
from repro.core.pruning import zero_prunable_tail
from repro.models import init_params, make_plan
from repro.models.model import init_lora
from repro.serving import (AdapterRegistry, ContinuousServeEngine,
                           PageAllocator, PoolExhausted,
                           SpeculativeServeEngine, auto_pool_pages,
                           draft_from_setup, pages_for)

RNG = jax.random.PRNGKey(0)
LORA_CFG = LoRAConfig(rank=4)


# ---------------------------------------------------------------------------
# refcounting allocator (pure host-side)
# ---------------------------------------------------------------------------

def test_refcount_share_fork_release():
    a = PageAllocator(n_pages=8, page_size=4, max_pages_per_slot=7,
                      max_slots=3)
    ids = a.alloc(0, 3)
    a.retain(ids)                       # a prefix entry keeps them alive
    a.share(1, ids)                     # a sharer maps them read-only
    assert all(a.refcount(p) == 3 for p in ids)
    assert a.pages_in_use == 3          # shared pages count ONCE
    # COW fork: slot 1 diverges on its logical page 1
    old, new = a.fork(1, 1)
    assert old == ids[1] and new not in ids and new != 0
    assert a.refcount(old) == 2 and a.refcount(new) == 1
    assert a.slot_pages(1)[1] == new
    # sharer eviction: shared pages survive (entry + slot 0 refs), fork dies
    assert a.release(1) == 1            # only the forked page came back
    assert all(a.refcount(p) >= 2 for p in ids)
    assert a.release(0) == 0            # entry still holds everything
    assert a.pages_in_use == 3
    # dropping the entry frees the pages
    assert a.release_ids(ids) == 3
    assert a.pages_in_use == 0


def test_fork_requires_shared_page_and_respects_exhaustion():
    a = PageAllocator(n_pages=4, page_size=4, max_pages_per_slot=3,
                      max_slots=2)
    ids = a.alloc(0, 2)
    with pytest.raises(AssertionError):
        a.fork(0, 0)                    # refcount 1 — nothing to fork from
    a.share(1, ids)
    a.alloc(0, 1)                       # pool now empty
    with pytest.raises(PoolExhausted):
        a.fork(1, 0)                    # fork needs a free page
    assert a.refcount(ids[0]) == 2      # failed fork changed nothing


@hypothesis.settings(max_examples=12, deadline=None)
@hypothesis.given(seed=st.integers(min_value=0, max_value=10_000),
                  n_pages=st.integers(min_value=4, max_value=24))
def test_allocator_random_interleavings(seed, n_pages):
    """Random alloc / share / COW-fork / retain / release interleavings
    against a reference refcount model: never double-free, never leak,
    never drop a page whose refcount > 0, peak_in_use stays exact."""
    rng = random.Random(seed)
    slots = 3
    a = PageAllocator(n_pages=n_pages, page_size=4,
                      max_pages_per_slot=n_pages, max_slots=slots)
    ref = {p: 0 for p in range(1, n_pages)}   # page → expected refcount
    retained = []                             # entry-held page lists
    peak = 0

    def check():
        nonlocal peak
        in_use = sum(1 for p, r in ref.items() if r > 0)
        peak = max(peak, in_use)
        assert a.pages_in_use == in_use
        assert a.peak_in_use == peak
        free = a.free_pages
        assert free == sum(1 for r in ref.values() if r == 0)
        for p, r in ref.items():
            assert a.refcount(p) == r, (p, r, a.refcount(p))
        for s in range(slots):
            for p in a.slot_pages(s):
                assert ref[p] >= 1, f"slot maps freed page {p}"

    for _ in range(60):
        op = rng.choice(["alloc", "share", "fork", "release", "retain",
                         "drop_entry"])
        s = rng.randrange(slots)
        if op == "alloc":
            n = rng.randint(1, 2)
            if a.can_alloc(n):
                for p in a.alloc(s, n):
                    assert ref[p] == 0
                    ref[p] = 1
            else:
                with pytest.raises(PoolExhausted):
                    a.alloc(s, n)
        elif op == "share":
            donor = rng.randrange(slots)
            pages = a.slot_pages(donor)
            room = a.max_pages_per_slot - a.n_slot_pages(s)
            if pages and donor != s and room > 0:
                take = pages[: rng.randint(1, min(len(pages), room))]
                a.share(s, take)
                for p in take:
                    ref[p] += 1
        elif op == "retain":
            pages = a.slot_pages(s)
            if pages:
                take = pages[: rng.randint(1, len(pages))]
                a.retain(take)
                retained.append(take)
                for p in take:
                    ref[p] += 1
        elif op == "drop_entry" and retained:
            take = retained.pop(rng.randrange(len(retained)))
            a.release_ids(take)
            for p in take:
                ref[p] -= 1
        elif op == "fork":
            pages = a.slot_pages(s)
            shared = [i for i, p in enumerate(pages) if ref[p] > 1]
            if shared and a.can_alloc(1):
                old, new = a.fork(s, rng.choice(shared))
                assert ref[new] == 0
                ref[old] -= 1
                ref[new] = 1
        elif op == "release":
            for p in a.slot_pages(s):
                ref[p] -= 1
            a.release(s)
        check()
    # drain everything: nothing may leak
    for s in range(slots):
        a.release(s)
    for take in retained:
        a.release_ids(take)
    assert a.pages_in_use == 0
    assert a.free_pages == n_pages - 1


# ---------------------------------------------------------------------------
# shared fixtures / helpers
# ---------------------------------------------------------------------------

def _dense_setup():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    return cfg, plan, params


def _adapters(plan, seeds=(11, 22)):
    out = []
    for seed in seeds:
        lora = init_lora(plan, LORA_CFG, jax.random.PRNGKey(seed))
        out.append(jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), lora))
    return out


def _registry(trees, names=("math", "code")):
    reg = AdapterRegistry(trees[0], max_adapters=4)
    for name, tree in zip(names, trees):
        reg.add(name, tree)
    return reg


def _assert_identical(r1, r2):
    assert sorted(r1) == sorted(r2)
    for u in r1:
        np.testing.assert_array_equal(r1[u].tokens, r2[u].tokens,
                                      err_msg=f"uid {u}")


BASE = dict(max_seq_len=64, kv_cache_dtype="float32", max_adapters=4)


def _run_pair(plan, params, vocab, ref_kw, new_kw, jobs, *, registry=None,
              lora_scale=2.0, submit_kw=lambda i: {}, seed=0, slots=3,
              max_new=16):
    """Submit ``jobs`` = [(prompt_len, adapter, n_new)] through two engines;
    returns (ref results, new engine, new results)."""
    def build(**kw):
        reg = _registry(registry) if registry is not None else None
        return ContinuousServeEngine(
            plan, params,
            ServeConfig(**BASE, max_slots=slots, max_new_tokens=max_new,
                        **kw),
            reg, lora_scale=lora_scale)

    ref, new = build(**ref_kw), build(**new_kw)
    rs = np.random.default_rng(seed)
    prompts = [rs.integers(2, vocab, (n,)).astype(np.int32)
               for n, _, _ in jobs]
    for eng, extra in ((ref, False), (new, True)):
        for i, (p, (_, a, m)) in enumerate(zip(prompts, jobs)):
            eng.submit(p, max_new_tokens=m, adapter=a,
                       **(submit_kw(i) if extra else {}))
    return ref.run(), new, new.run()


# ---------------------------------------------------------------------------
# chunked prefill == monolithic, token for token
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_monolithic_with_eviction():
    """6 mixed-length requests (prompts spanning 1–5 chunks) through 3
    slots — every slot is evicted and re-admitted — with per-slot adapter
    routing.  The chunked engine must emit exactly the monolithic paged
    engine's tokens."""
    cfg, plan, params = _dense_setup()
    trees = _adapters(plan)
    jobs = [(20, "math", 6), (33, "code", 4), (5, "math", 6),
            (27, None, 3), (9, "code", 6), (40, "math", 5)]
    r1, chk, r2 = _run_pair(
        plan, params, cfg.vocab_size,
        dict(kv_paging=True, kv_page_size=8),
        dict(kv_paging=True, kv_page_size=8, prefill_chunk=8),
        jobs, registry=trees, lora_scale=LORA_CFG.scale)
    _assert_identical(r1, r2)
    assert chk.n_prefill_chunks > len(jobs), "long prompts must have chunked"
    assert chk.n_ticks_during_prefill > 0, \
        "decode must have ticked between chunks (the whole point)"
    assert chk.pages.pages_in_use == 0


def test_chunked_prefill_sliding_window():
    """gemma3 (window=8, page 4): chunks wrap the bounded 2-page rings —
    last-writer-wins inside a chunk, ring reads across chunk boundaries."""
    cfg = get_smoke("gemma3-12b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    jobs = [(20, None, 10), (33, None, 8), (9, None, 12), (26, None, 10)]
    r1, _, r2 = _run_pair(
        plan, params, cfg.vocab_size,
        dict(kv_paging=True, kv_page_size=4),
        dict(kv_paging=True, kv_page_size=4, prefill_chunk=8),
        jobs)
    _assert_identical(r1, r2)


def test_chunked_prefill_preemption():
    """A pool too small for the traffic: chunked admissions get preempted
    mid-prefill (progress thrown away, request requeued at the head) and
    the output still matches the monolithic engine exactly."""
    cfg, plan, params = _dense_setup()
    jobs = [(20, None, 40), (17, None, 40), (22, None, 40), (19, None, 40)]
    r1, chk, r2 = _run_pair(
        plan, params, cfg.vocab_size,
        dict(kv_paging=True, kv_page_size=8, kv_pages=10),
        dict(kv_paging=True, kv_page_size=8, kv_pages=10, prefill_chunk=8),
        jobs, max_new=48)
    _assert_identical(r1, r2)
    assert chk.n_preemptions > 0, "tiny pool must have preempted"
    assert chk.pages.pages_in_use == 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "zamba2-2.7b"])
def test_chunked_prefill_families(arch):
    """MoE (lossless chunk routing) and hybrid (SSM recurrence continued
    chunk-to-chunk from the slot's dense state)."""
    cfg = get_smoke(arch)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    jobs = [(20, None, 6), (33, None, 4), (9, None, 6), (26, None, 5)]
    r1, _, r2 = _run_pair(
        plan, params, cfg.vocab_size,
        dict(kv_paging=True, kv_page_size=8),
        dict(kv_paging=True, kv_page_size=8, prefill_chunk=8),
        jobs)
    _assert_identical(r1, r2)


def test_config_validation():
    cfg, plan, params = _dense_setup()
    with pytest.raises(ValueError):   # chunking requires paging
        ContinuousServeEngine(plan, params,
                              ServeConfig(**BASE, prefill_chunk=8))
    with pytest.raises(ValueError):   # chunks must be page-aligned
        ContinuousServeEngine(
            plan, params,
            ServeConfig(**BASE, kv_paging=True, kv_page_size=8,
                        prefill_chunk=12))
    with pytest.raises(ValueError):   # sharing requires paging
        ContinuousServeEngine(plan, params,
                              ServeConfig(**BASE, prefix_sharing=True))
    eng = ContinuousServeEngine(
        plan, params, ServeConfig(**BASE, kv_paging=True, kv_page_size=8,
                                  prefix_sharing=True))
    with pytest.raises(ValueError):   # prefix needs a non-empty suffix
        eng.submit(np.ones(8, np.int32), prefix_id="p", prefix_len=8)
    with pytest.raises(ValueError):   # sharing off → prefix_id rejected
        ContinuousServeEngine(
            plan, params, ServeConfig(**BASE, kv_paging=True)
        ).submit(np.ones(8, np.int32), prefix_id="p", prefix_len=4)


# ---------------------------------------------------------------------------
# prefix sharing == unshared, token for token (+ savings)
# ---------------------------------------------------------------------------

def _prefix_jobs(vocab, prefix_len, suffix_lens, seed=1):
    rs = np.random.default_rng(seed)
    prefix = rs.integers(2, vocab, (prefix_len,)).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rs.integers(2, vocab, (n,)).astype(np.int32)])
               for n in suffix_lens]
    return prefix, prompts


@pytest.mark.parametrize("prefix_len", [21, 16],
                         ids=["boundary-page-cow", "page-aligned"])
def test_prefix_sharing_identity_and_savings(prefix_len):
    """K adapter-routed requests over one shared prefix through 2 slots
    (eviction + re-mapping): token-identical to the unshared paged engine,
    with measured prefill-token and peak-page savings.  prefix_len=21 puts
    the boundary mid-page, so every sharer COW-forks the partially-filled
    boundary page on its first divergent (suffix) write; 16 is page-aligned
    — no boundary fork."""
    cfg, plan, params = _dense_setup()
    trees = _adapters(plan)
    _, prompts = _prefix_jobs(cfg.vocab_size, prefix_len, (5, 9, 3, 12, 7))
    adapters = ["math", "math", "code", "math", "math"]

    def build(**kw):
        return ContinuousServeEngine(
            plan, params,
            ServeConfig(**BASE, max_slots=2, max_new_tokens=16, **kw),
            _registry(trees), lora_scale=LORA_CFG.scale)

    ref = build(kv_paging=True, kv_page_size=8)
    shr = build(kv_paging=True, kv_page_size=8, prefix_sharing=True)
    for p, a in zip(prompts, adapters):
        ref.submit(p, max_new_tokens=10, adapter=a)
        shr.submit(p, max_new_tokens=10, adapter=a, prefix_id="sys",
                   prefix_len=prefix_len)
    r1, r2 = ref.run(), shr.run()
    _assert_identical(r1, r2)
    # entries are per (prefix_id, adapter): 4 math requests share one, 2
    # code... the 1 code request builds its own → hits = 5 - 2 builders
    assert shr.n_prefix_hits == 3
    assert shr.n_prefix_tokens_saved == 3 * prefix_len
    assert shr.n_prefill_tokens < ref.n_prefill_tokens
    # peak pages: never worse; strictly better when the suffixes are small
    # relative to the shared span (the mid-page case here — the aligned
    # case's exact-page allocation happens to match the ref's buckets)
    assert shr.pages.peak_in_use <= ref.pages.peak_in_use
    if prefix_len == 21:
        assert shr.pages.peak_in_use < ref.pages.peak_in_use
    # the two entries (one per adapter) survive the drain, refcounted
    assert shr.pages.pages_in_use == 2 * pages_for(prefix_len, 8)
    # second wave: reuse proves no sharer's writes corrupted the entries
    for p, a in zip(prompts, adapters):
        ref.submit(p, max_new_tokens=10, adapter=a)
        shr.submit(p, max_new_tokens=10, adapter=a, prefix_id="sys",
                   prefix_len=prefix_len)
    _assert_identical(ref.run(), shr.run())
    assert shr.n_prefix_hits == 3 + 5      # every wave-2 request hits
    # explicit release drains the entries completely
    assert shr.release_prefix("sys")
    assert shr.pages.pages_in_use == 0
    with pytest.raises(ValueError):        # mismatched prefix tokens
        shr.submit(np.zeros(30, np.int32), prefix_id="sys",
                   prefix_len=prefix_len)


def test_prefix_sharing_with_chunked_suffix_and_window():
    """Sliding-window family (gemma3) with BOTH features on: the windowed
    rings wrap onto the shared prefix pages during the suffix chunks and
    decode, forcing COW forks of ring entries — identity must survive."""
    cfg = get_smoke("gemma3-12b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    _, prompts = _prefix_jobs(cfg.vocab_size, 21, (5, 9, 3, 12))

    def build(**kw):
        return ContinuousServeEngine(
            plan, params,
            ServeConfig(**BASE, max_slots=2, max_new_tokens=16,
                        kv_paging=True, kv_page_size=4, **kw))

    ref = build()
    shr = build(prefix_sharing=True, prefill_chunk=8)
    for p in prompts:
        ref.submit(p, max_new_tokens=10)
        shr.submit(p, max_new_tokens=10, prefix_id="sys", prefix_len=21)
    _assert_identical(ref.run(), shr.run())
    assert shr.n_prefix_hits == 3


@pytest.mark.slow
def test_prefix_sharing_hybrid_state_clone():
    """zamba2: the prefix entry snapshots the SSM/conv state at the prefix
    boundary and clones it into every sharer's slot — recurrence has no
    pages to share, state cloning is what makes SSM prefixes reusable."""
    cfg = get_smoke("zamba2-2.7b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    _, prompts = _prefix_jobs(cfg.vocab_size, 21, (5, 9, 3, 12))

    def build(**kw):
        return ContinuousServeEngine(
            plan, params,
            ServeConfig(**BASE, max_slots=2, max_new_tokens=16,
                        kv_paging=True, kv_page_size=8, **kw))

    ref, shr = build(), build(prefix_sharing=True)
    for p in prompts:
        ref.submit(p, max_new_tokens=10)
        shr.submit(p, max_new_tokens=10, prefix_id="sys", prefix_len=21)
    _assert_identical(ref.run(), shr.run())
    assert shr.n_prefix_hits == 3


def test_prefix_sharing_under_pool_pressure():
    """Tiny pool: sharers get preempted, idle prefix entries get dropped
    and rebuilt — FCFS and token identity must survive all of it."""
    cfg, plan, params = _dense_setup()
    _, prompts = _prefix_jobs(cfg.vocab_size, 13, (5, 8, 4, 9))

    def build(**kw):
        return ContinuousServeEngine(
            plan, params,
            ServeConfig(**BASE, max_slots=3, max_new_tokens=48,
                        kv_paging=True, kv_page_size=8, kv_pages=10, **kw))

    ref, shr = build(), build(prefix_sharing=True)
    for p in prompts:
        ref.submit(p, max_new_tokens=40)
        shr.submit(p, max_new_tokens=40, prefix_id="sys", prefix_len=13)
    _assert_identical(ref.run(), shr.run())
    assert shr.n_preemptions > 0


# ---------------------------------------------------------------------------
# speculative decoding composes with both
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def spec_setup():
    cfg, plan, params = _dense_setup()
    params = zero_prunable_tail(params, plan, 0.5)
    setup = loram.setup(plan, params,
                        LoRAMConfig(method="stru", ratio=0.5,
                                    keep_first=0, keep_last=0),
                        LORA_CFG, jax.random.PRNGKey(1))
    draft = draft_from_setup(setup, max_adapters=4)
    small = jax.tree.map(
        lambda x: x + 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), x.shape, x.dtype),
        init_lora(setup.small_plan, LORA_CFG, jax.random.PRNGKey(2)))
    full = recovery.recover_lora(small, setup.spec, plan, setup.small_plan)
    draft.add("t", small)
    return cfg, plan, params, draft, full


def test_speculative_chunked_shared_identity(spec_setup):
    """The speculative engine with chunked prefill AND prefix sharing on:
    draft+target chunks fuse into one dispatch, the draft pool shares the
    prefix pages through the same block table, and verify commits never
    write a shared page (the pre-round COW sweep forks first).  Output is
    token-identical to the plain dense engine — including a second wave
    that reuses the cached prefix, which would expose any corruption the
    first wave's rounds left behind."""
    cfg, plan, params, draft, full = spec_setup
    base = dict(max_seq_len=64, max_slots=2, max_adapters=4,
                max_new_tokens=16, kv_cache_dtype="float32")
    reg1 = AdapterRegistry(full, max_adapters=4)
    reg1.add("t", full)
    plain = ContinuousServeEngine(plan, params, ServeConfig(**base), reg1,
                                  lora_scale=LORA_CFG.scale)
    reg2 = AdapterRegistry(full, max_adapters=4)
    reg2.add("t", full)
    spec = SpeculativeServeEngine(
        plan, params,
        ServeConfig(**base, draft_gamma=3, kv_paging=True, kv_page_size=8,
                    prefill_chunk=8, prefix_sharing=True),
        reg2, draft, lora_scale=LORA_CFG.scale)
    rs = np.random.default_rng(0)
    prefix = rs.integers(2, cfg.vocab_size, (19,)).astype(np.int32)
    jobs = [(5, "t"), (9, None), (3, "t"), (12, "t"), (7, "t")]
    prompts = [np.concatenate(
        [prefix, rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)])
        for n, _ in jobs]
    for wave in range(2):
        for p, (_, a) in zip(prompts, jobs):
            plain.submit(p, max_new_tokens=10, adapter=a)
            spec.submit(p, max_new_tokens=10, adapter=a, prefix_id="sys",
                        prefix_len=19)
        _assert_identical(plain.run(), spec.run())
    assert spec.acceptance_rate > 0.9
    assert spec.n_prefix_hits >= 3
    assert spec.release_prefix("sys")
    assert spec.pages.pages_in_use == 0


@pytest.mark.slow
def test_speculative_hybrid_chunked_shared():
    """zamba2 speculative with chunking + sharing: the draft's recurrent
    state streams through the same side channel as the target's (the draft
    loop garbage-advances every slot's dense state each round, so a
    half-prefilled slot's draft recurrence must live outside the cache
    too)."""
    cfg = get_smoke("zamba2-2.7b")
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    setup = loram.setup(plan, params,
                        LoRAMConfig(method="stru", ratio=0.5,
                                    keep_first=0, keep_last=0),
                        LORA_CFG, jax.random.PRNGKey(1))
    draft = draft_from_setup(setup, max_adapters=0)
    base = dict(max_seq_len=64, max_slots=2, max_new_tokens=16,
                kv_cache_dtype="float32")
    plain = ContinuousServeEngine(plan, params, ServeConfig(**base))
    spec = SpeculativeServeEngine(
        plan, params,
        ServeConfig(**base, draft_gamma=3, kv_paging=True, kv_page_size=8,
                    prefill_chunk=8, prefix_sharing=True), None, draft)
    _, prompts = _prefix_jobs(cfg.vocab_size, 21, (12, 3, 9, 5), seed=0)
    for p in prompts:
        plain.submit(p, max_new_tokens=10)
        spec.submit(p, max_new_tokens=10, prefix_id="sys", prefix_len=21)
    _assert_identical(plain.run(), spec.run())
    assert spec.n_prefix_hits == 3


# ---------------------------------------------------------------------------
# γ-lookahead pool-sizing audit (regression)
# ---------------------------------------------------------------------------

class _UncappedGrowth(SpeculativeServeEngine):
    """The PRE-audit growth formula: per-slot reservation uncapped by the
    request's final length — kept here so the regression stays legible."""

    def _ensure_growth(self, lookahead):
        for slot in sorted(self._sched.active_slots(),
                           key=lambda s: self._admit_seq[s]):
            if self._sched.slot_request(slot) is None:
                continue
            need = pages_for(min(self._slot_pos[slot] + lookahead,
                                 self.cfg.max_seq_len), self._page)
            while True:
                try:
                    new = self.pages.ensure(slot, need)
                    break
                except PoolExhausted:
                    self._reclaim()
                    if self._sched.slot_request(slot) is None:
                        new = []
                        break
            if new:
                self._set_table_row(slot, self.pages.slot_pages(slot))


def test_gamma_lookahead_never_preempts_exact_pool(spec_setup):
    """kv_pages_auto audit: a pool that exactly fits the workload's true
    final footprint must never preempt mid-speculative-round at full
    occupancy.  The k·γ growth lookahead used to reserve pages past
    ``prompt + max_new_tokens`` (rows that land on the trash page anyway)
    and preempted live traffic to back garbage — the capped reservation
    doesn't, and the uncapped variant demonstrably still does."""
    cfg, plan, params, draft, full = spec_setup
    page, n_prompt, n_new, gamma = 4, 10, 20, 6
    tight = 2 * pages_for(n_prompt + n_new, page) + 1
    base = dict(max_seq_len=64, max_slots=2, max_adapters=4,
                max_new_tokens=32, kv_cache_dtype="float32")
    rs = np.random.default_rng(0)
    prompts = [rs.integers(2, cfg.vocab_size, (n_prompt,)).astype(np.int32)
               for _ in range(2)]
    reg = AdapterRegistry(full, max_adapters=4)
    reg.add("t", full)
    plain = ContinuousServeEngine(plan, params, ServeConfig(**base), reg,
                                  lora_scale=LORA_CFG.scale)
    for p in prompts:
        plain.submit(p, max_new_tokens=n_new, adapter="t")
    r1 = plain.run()

    results = {}
    for cls in (SpeculativeServeEngine, _UncappedGrowth):
        reg = AdapterRegistry(full, max_adapters=4)
        reg.add("t", full)
        eng = cls(plan, params,
                  ServeConfig(**base, draft_gamma=gamma, kv_paging=True,
                              kv_page_size=page, kv_pages=tight),
                  reg, draft, lora_scale=LORA_CFG.scale)
        for p in prompts:
            eng.submit(p, max_new_tokens=n_new, adapter="t")
        _assert_identical(r1, eng.run())     # correct either way…
        results[cls] = eng.n_preemptions
    assert results[SpeculativeServeEngine] == 0, \
        "capped growth must not preempt when the pool fits the footprint"
    assert results[_UncappedGrowth] > 0, \
        "regression guard gone stale: the uncapped formula no longer " \
        "over-reserves — retune this scenario"


def test_auto_pool_pages_floor():
    # floor: one max-length request + trash page, whatever the reduction
    assert auto_pool_pages(1, 64, 8, reduction=100.0) == 9
    n = auto_pool_pages(8, 128, 16)
    assert n > pages_for(128, 16) + 1
    assert n - 1 < 8 * pages_for(128, 16) / 2   # genuinely below dense


# ---------------------------------------------------------------------------
# Pallas chunk kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    # (B, C, H, K, D, page, R, window)
    (2, 8, 8, 4, 32, 16, 4, 0),    # full attention, GQA 2:1
    (3, 16, 4, 2, 16, 8, 2, 12),   # bounded ring, chunk wraps the window
    (2, 4, 4, 4, 32, 8, 3, 20),    # MHA, ring > window
])
def test_paged_chunk_kernel_matches_ref(shape):
    from repro.kernels import ops
    from repro.kernels.ref import paged_chunk_attention_ref
    B, C, H, K, D, page, R, window = shape
    rng = np.random.default_rng(0)
    n_pages = B * R + 1
    q = jnp.asarray(rng.normal(size=(B, C, H, D)).astype(np.float32))
    kn = jnp.asarray(rng.normal(size=(B, C, K, D)).astype(np.float32))
    vn = jnp.asarray(rng.normal(size=(B, C, K, D)).astype(np.float32))
    pk = jnp.asarray(rng.normal(size=(n_pages, page, K, D)).astype(np.float32))
    pv = jnp.asarray(rng.normal(size=(n_pages, page, K, D)).astype(np.float32))
    table = jnp.asarray(
        rng.permutation(np.arange(1, n_pages))[:B * R]
        .reshape(B, R).astype(np.int32))
    pos = jnp.asarray(rng.integers(0, R * page, size=(B,)).astype(np.int32))
    ref = paged_chunk_attention_ref(q, kn, vn, pk, pv, table, pos,
                                    window=window)
    pal = ops.paged_chunk_attention(q, kn, vn, pk, pv, table, pos,
                                    window=window, force="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), atol=2e-5)

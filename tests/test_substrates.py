"""Substrate behaviour: checkpoint atomicity/validation/resume, watchdog,
elastic re-sharding, gradient compression, data determinism, optimizer."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import hypothesis, st

from repro.checkpoint import CheckpointManager
from repro.data import AlignmentCorpus, SFTDataset, index_for
from repro.distributed.compression import (compressed_psum, dequantize_int8,
                                           quantize_int8)
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.runtime.elastic import plan_transition, shard_rows
from repro.runtime.watchdog import StepWatchdog, StragglerAlarm


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(6, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t)
    step, restored = mgr.restore_latest(_tree(99))
    assert step == 10
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(t["b"]["c"]))


def test_checkpoint_retention_and_keep_period(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, keep_period=100)
    for s in [100, 150, 200, 250, 300]:
        mgr.save(s, _tree(s))
    steps = mgr.steps()
    assert 100 in steps and 200 in steps and 300 in steps  # keep_period
    assert 250 in steps and 300 in steps                   # newest 2
    assert 150 not in steps


def test_checkpoint_corruption_skipped(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    # corrupt the newest
    npz = os.path.join(str(tmp_path), "step_00000002", "proc_0", "tensors.npz")
    with open(npz, "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 64)
    step, restored = mgr.restore_latest(_tree(0))
    assert step == 1  # fell back to the older valid checkpoint


def test_checkpoint_async_does_not_block(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    big = {"x": jnp.zeros((512, 512))}
    t0 = time.perf_counter()
    mgr.save_async(5, big)
    t_submit = time.perf_counter() - t0
    mgr.wait()
    step, _ = mgr.restore_latest(big)
    assert step == 5
    assert t_submit < 5.0


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_alarm_with_fake_clock():
    t = [0.0]

    def clock():
        return t[0]

    wd = StepWatchdog(threshold=3.0, warmup_steps=2, clock=clock)
    for step, dt in enumerate([1.0, 1.0, 1.0, 1.0]):
        wd.start()
        t[0] += dt
        wd.stop(step)
    wd.start()
    t[0] += 10.0  # 10× slower than EWMA → straggler
    with pytest.raises(StragglerAlarm):
        wd.stop(99)


# ---------------------------------------------------------------------------
# elastic re-sharding
# ---------------------------------------------------------------------------

@hypothesis.given(
    n_old=st.sampled_from([2, 4, 8]),
    n_new=st.sampled_from([2, 4, 8, 16]),
)
@hypothesis.settings(max_examples=12, deadline=None)
def test_elastic_rows_partition(n_old, n_new):
    gb = 32
    # every row owned exactly once under both topologies
    for n in (n_old, n_new):
        owned = [r for h in range(n) for r in shard_rows(gb, h, n).rows]
        assert sorted(owned) == list(range(gb))
    moves = plan_transition(gb, n_old, n_new)
    # all moved rows land at their new owner
    for h, lst in moves.items():
        new_rows = set(shard_rows(gb, h, n_new).rows)
        for src, row in lst:
            assert row in new_rows
            assert row in shard_rows(gb, src, n_old).rows


def test_data_stateless_and_elastic():
    ds = SFTDataset(vocab=128, seq_len=16, seed=3)
    a = ds.batch(step=7, host=0, n_hosts=2, batch_size=4)
    b = ds.batch(step=7, host=0, n_hosts=2, batch_size=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # deterministic
    c = ds.batch(step=7, host=1, n_hosts=2, batch_size=4)
    assert not np.array_equal(a["tokens"], c["tokens"])      # host-disjoint
    d = ds.batch(step=8, host=0, n_hosts=2, batch_size=4)
    assert not np.array_equal(a["tokens"], d["tokens"])      # step-disjoint
    # loss mask covers answers only and is non-degenerate
    assert 0.0 < a["loss_mask"].mean() < 1.0


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_quantize_roundtrip_bound():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(256), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback, the accumulated compressed sum tracks the true
    sum far better than without."""
    rng = np.random.default_rng(0)
    g_stream = [jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)
                for _ in range(50)]
    err = jnp.zeros(64)
    acc_ef = jnp.zeros(64)
    acc_nf = jnp.zeros(64)
    for g in g_stream:
        q, s = quantize_int8(g + err)
        deq = dequantize_int8(q, s)
        err = g + err - deq
        acc_ef += deq
        q2, s2 = quantize_int8(g)
        acc_nf += dequantize_int8(q2, s2)
    true = sum(g_stream)
    assert float(jnp.abs(acc_ef - true).max()) <= float(jnp.abs(acc_nf - true).max()) + 1e-6


def test_compressed_psum_under_shard_map():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("pod",))
    g = jnp.asarray(np.random.default_rng(1).standard_normal((n, 32)) * 0.1,
                    jnp.float32)
    e = jnp.zeros((n, 32))

    f = shard_map(lambda gg, ee: compressed_psum(gg[0], ee[0], "pod"),
                  mesh=mesh, in_specs=(P("pod"), P("pod")),
                  out_specs=(P(), P("pod")), check_rep=False)
    mean_g, new_e = f(g, e)
    np.testing.assert_allclose(np.asarray(mean_g), np.asarray(g.mean(0)),
                               atol=2e-3)


# ---------------------------------------------------------------------------
# optimizer / schedule
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    p = {"w": jnp.asarray([5.0, -3.0])}
    st_ = adamw_init(p)
    for _ in range(300):
        g = jax.tree.map(lambda x: 2 * x, p)
        p, st_ = adamw_update(p, g, st_, lr=0.1)
    assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(0, peak_lr=1e-3, warmup_steps=10, total_steps=100))
    lr10 = float(warmup_cosine(10, peak_lr=1e-3, warmup_steps=10, total_steps=100))
    lr100 = float(warmup_cosine(100, peak_lr=1e-3, warmup_steps=10, total_steps=100))
    assert lr0 == 0.0 and abs(lr10 - 1e-3) < 1e-9 and lr100 < 2e-4


def test_grad_clip():
    from repro.optim.adamw import clip_by_global_norm

    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5

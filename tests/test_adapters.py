"""Paged adapter bank (two-tier store + LRU residency):

  1. residency allocator — property-checked random interleavings of
     register / hot-swap / acquire+poll / retain / release / evict /
     remove against a shadow model: row maps stay bijective, committed
     rows hold exactly the (padded) host tree, evicted and never-assigned
     rows are ZEROS, refcounts never leak, stale ids fail typed
  2. rank buckets — mixed-rank adapters share one bank through zero-padded
     buckets; padding is exactly zero-delta through the serving einsum
     (a rank-2 adapter in a rank-4 bank is token-identical to its solo run)
  3. streaming token identity — ``bank_slots < K`` serves MORE adapters
     than device rows by streaming host↔HBM under the admission gate, yet
     every request completes with EXACTLY the tokens the dense-equivalent
     bank (``bank_slots >= K``, the PR-1 behavior) emits — across the
     continuous, paged and speculative engines
  4. engine interleavings — property-checked register / hot-swap / submit
     / cancel / step sequences on a live 2-row engine: every uid reaches
     exactly one typed terminal, active slots only ever gather their own
     resident row, and the bank never holds a stale row after drain
"""
import dataclasses
import functools
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import hypothesis, st
from repro.configs import LoRAConfig, LoRAMConfig, ServeConfig, get_smoke
from repro.core import loram, recovery
from repro.models import init_params, make_plan
from repro.models.model import init_lora
from repro.serving import (AdapterError, AdapterRegistry,
                           ContinuousServeEngine, ServeEngine,
                           SpeculativeServeEngine, StaleAdapter)
from repro.serving.adapters import BASE_ROW, bucket_rank
from repro.serving.draft import build_draft

RNG = jax.random.PRNGKey(0)
LORA_CFG = LoRAConfig(rank=4)
LORAM_CFG = LoRAMConfig(method="stru", ratio=0.5, keep_first=0, keep_last=0)


# ---------------------------------------------------------------------------
# 1. residency allocator vs. a shadow model (host-only, tiny template)
# ---------------------------------------------------------------------------

def _tiny_template(rank=4):
    """Minimal tree with every bank-layout case: stacked (row axis 1),
    shared and lm_head (row axis 0)."""
    return {
        "stages": {"s0": {
            "stacked": {"wq": {"a": jnp.ones((2, rank, 8)),
                               "b": jnp.ones((2, 8, rank))}},
            "shared": {"wo": {"a": jnp.ones((rank, 8)),
                              "b": jnp.ones((8, rank))}},
        }},
        "lm_head": {"a": jnp.ones((rank, 8)), "b": jnp.ones((8, rank))},
    }


def _fill(template, value):
    return jax.tree.map(lambda x: jnp.full_like(x, value), template)


def _check_bank_rows(reg):
    """Every committed row holds its padded host tree; every other adapter
    row is zeros (base-route fallback — never a stale adapter)."""
    res = reg.residency
    committed = dict(res.assignments())          # aid → row
    leaves = jax.tree.leaves(reg.bank)
    axes = jax.tree.leaves(reg._axes)
    for aid, row in committed.items():
        want = jax.tree.leaves(reg.adapter_tree(aid))
        for leaf, ax, w in zip(leaves, axes, want):
            got = np.asarray(jnp.take(leaf, row, axis=ax))
            np.testing.assert_array_equal(got, np.asarray(w), err_msg=(
                f"bank row {row} does not hold adapter {aid}'s tree"))
    used = set(committed.values()) | set(
        res._row_of[a] for a in res._uploading)
    for row in range(1, res.bank_slots):
        if row in used:
            continue
        for leaf, ax in zip(leaves, axes):
            assert not np.asarray(jnp.take(leaf, row, axis=ax)).any(), (
                f"unassigned row {row} is not zeroed")


def _check_residency_invariants(reg, shadow_ref):
    res = reg.residency
    assert res.free_rows + res.in_use == res.bank_slots - 1
    rows = [r for _, r in res.assignments()]
    assert len(rows) == len(set(rows)), "row map is not injective"
    assert all(BASE_ROW < r < res.bank_slots for r in rows)
    assert not (set(res._free) & set(res._aid_of)), "free row still mapped"
    for aid, n in shadow_ref.items():
        assert res.refcount(aid) == n, (aid, n, res.refcount(aid))
    _check_bank_rows(reg)


@hypothesis.settings(max_examples=12, deadline=None)
@hypothesis.given(seed=st.integers(0, 10_000), bank_slots=st.integers(2, 5))
def test_residency_interleavings_match_shadow_model(seed, bank_slots):
    rng = random.Random(seed)
    reg = AdapterRegistry(_tiny_template(), max_adapters=bank_slots,
                          bank_slots=bank_slots)
    names, version, removed = [], {}, []
    shadow_ref = {}
    n_added = 0

    for step in range(30):
        op = rng.choice(["add", "hotswap", "acquire", "retain", "release",
                         "evict", "remove", "poll"])
        if op == "add":
            name = f"a{n_added}_{seed}"
            n_added += 1
            v = rng.randint(1, 99)
            aid = reg.add(name, _fill(_tiny_template(), v))
            names.append(name)
            version[name] = v
            assert reg.name_of(aid) == name          # O(1) reverse map
            assert reg.resolve(name) == aid
        elif op == "hotswap" and names:
            name = rng.choice(names)
            v = rng.randint(100, 199)
            aid_before = reg.resolve(name)
            assert reg.add(name, _fill(_tiny_template(), v)) == aid_before
            version[name] = v
        elif op == "acquire" and names:
            aid = reg.resolve(rng.choice(names))
            if reg.residency.acquire(aid):
                assert reg.residency.resident(aid)
            reg.residency.poll()                     # commit staged uploads
        elif op == "retain" and names:
            aid = reg.resolve(rng.choice(names))
            if reg.residency.resident(aid):
                reg.residency.retain(aid)
                shadow_ref[aid] = shadow_ref.get(aid, 0) + 1
        elif op == "release":
            held = [a for a, n in shadow_ref.items() if n]
            if held:
                aid = rng.choice(held)
                reg.residency.release(aid)
                shadow_ref[aid] -= 1
        elif op == "evict" and names:
            aid = reg.resolve(rng.choice(names))
            if shadow_ref.get(aid, 0):
                with pytest.raises(AdapterError):
                    reg.residency.evict(aid)         # pinned: typed refusal
            else:
                reg.residency.evict(aid)
                assert not reg.residency.resident(aid)
        elif op == "remove" and names:
            name = rng.choice(names)
            aid = reg.resolve(name)
            if shadow_ref.get(aid, 0):
                with pytest.raises(AdapterError):
                    reg.remove(name)
            else:
                reg.remove(name)
                names.remove(name)
                removed.append(aid)
                shadow_ref.pop(aid, None)
        elif op == "poll":
            reg.residency.poll()
        _check_residency_invariants(reg, shadow_ref)

    # stale ids stay typed-dead forever (KeyError subclass: satellite 2)
    for aid in removed:
        with pytest.raises(StaleAdapter):
            reg.resolve(aid)
        with pytest.raises(KeyError):
            reg.resolve(aid)
        assert reg.name_of(aid) is None


def test_rank_bucket_geometry():
    assert bucket_rank(3, 8, 2) == 4
    assert bucket_rank(5, 8, 2) == 8
    assert bucket_rank(1, 8, 1) == 8
    assert bucket_rank(8, 8, 4) == 8
    # a rank-2 tree in a rank-4 bank with 2 buckets pads only to rank 2
    reg = AdapterRegistry(_tiny_template(rank=4), max_adapters=2,
                          rank_buckets=2)
    aid = reg.add("half", _fill(_tiny_template(rank=2), 7))
    padded = reg.adapter_tree(aid)
    assert padded["lm_head"]["a"].shape == (2, 8)    # bucketed, not template
    # with one bucket everything pads to the template rank, tail zeroed
    reg1 = AdapterRegistry(_tiny_template(rank=4), max_adapters=2)
    t1 = reg1.adapter_tree(reg1.add("half", _fill(_tiny_template(rank=2), 7)))
    assert t1["lm_head"]["a"].shape == (4, 8)
    np.testing.assert_array_equal(np.asarray(t1["lm_head"]["a"][2:]), 0.0)
    np.testing.assert_array_equal(np.asarray(t1["lm_head"]["a"][:2]), 7.0)


# ---------------------------------------------------------------------------
# shared tiny model, pruned draft, three full-rank adapters + one rank-2
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _served():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    setup = loram.setup(plan, params, LORAM_CFG, LORA_CFG,
                        jax.random.PRNGKey(1))

    def mk_adapter(seed, rank=LORA_CFG.rank):
        lcfg = LoRAConfig(rank=rank)
        small = init_lora(setup.small_plan, lcfg, jax.random.PRNGKey(seed))
        small = jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), small)
        full = recovery.recover_lora(small, setup.spec, plan,
                                     setup.small_plan)
        return small, full

    adapters = {name: mk_adapter(seed)
                for name, seed in [("math", 11), ("code", 22), ("law", 33)]}
    return cfg, plan, params, setup, adapters


@pytest.fixture(scope="module")
def served():
    return _served()


WORK = [(8, "math", 5), (12, "code", 4), (5, None, 5), (9, "law", 4),
        (12, "math", 3), (7, "code", 5), (10, "law", 3), (6, "math", 4)]


def _workload(cfg):
    rs = np.random.default_rng(0)
    return [rs.integers(2, cfg.vocab_size, (n,)).astype(np.int32)
            for n, _, _ in WORK]


def _serve(plan, params, setup, adapters, *, bank_slots, paged=False,
           speculative=False, prompts=None, cfg=None):
    """One full run of WORK through a freshly built engine; returns
    (uid → result, registry)."""
    _, full0 = adapters["math"]
    reg = AdapterRegistry(full0, max_adapters=4, bank_slots=bank_slots)
    kw = dict(max_seq_len=64, max_slots=3, max_adapters=4,
              adapter_bank_slots=bank_slots, max_new_tokens=16,
              kv_cache_dtype="float32",
              draft_gamma=3 if speculative else 0)
    if paged:
        kw.update(kv_paging=True, kv_page_size=8, kv_pages=28)
    sc = ServeConfig(**kw)
    if speculative:
        draft = build_draft(setup.small_plan, setup.small_params,
                            adapter_template=setup.lora0, max_adapters=4,
                            bank_slots=bank_slots)
        eng = SpeculativeServeEngine(plan, params, sc, reg, draft,
                                     lora_scale=LORA_CFG.scale)
        for name in ("math", "code", "law"):
            eng.register_adapter(name, adapters[name][1],
                                 draft_lora=adapters[name][0])
    else:
        eng = ContinuousServeEngine(plan, params, sc, reg,
                                    lora_scale=LORA_CFG.scale)
        for name in ("math", "code", "law"):
            eng.register_adapter(name, adapters[name][1])
    uids = [eng.submit(p, max_new_tokens=m, adapter=a)
            for p, (_, a, m) in zip(prompts, WORK)]
    results = eng.run()
    assert sorted(results) == sorted(uids)
    return results, reg


@pytest.mark.parametrize("flavor", ["continuous", "paged", "speculative"])
def test_streaming_bank_token_identical_to_dense(served, flavor):
    """K=3 adapters through bank_slots=2 (ONE adapter row): every request
    completes and emits exactly the dense-bank (bank_slots >= K) tokens,
    while the residency layer demonstrably streamed (misses + evictions)."""
    cfg, plan, params, setup, adapters = served
    prompts = _workload(cfg)
    kw = dict(paged=flavor == "paged", speculative=flavor == "speculative",
              prompts=prompts)
    dense, dreg = _serve(plan, params, setup, adapters, bank_slots=4, **kw)
    stream, sreg = _serve(plan, params, setup, adapters, bank_slots=2, **kw)

    # dense-equivalent regime never misses: every adapter stayed resident
    assert dreg.residency.n_misses == 0 and dreg.residency.n_evictions == 0
    # the 2-row bank actually streamed
    assert sreg.residency.n_misses > 0 and sreg.residency.n_evictions > 0
    assert sreg.residency.upload_bytes > 0
    for uid in dense:
        assert dense[uid].status == stream[uid].status == "ok"
        np.testing.assert_array_equal(
            stream[uid].tokens, dense[uid].tokens,
            err_msg=f"uid {uid} ({flavor}) diverged under streaming")
    # no slot left holding a reference after drain
    assert all(sreg.residency.refcount(reg_aid) == 0
               for reg_aid, _ in sreg.residency.assignments())


def test_rank_bucket_zero_delta_through_engine(served):
    """A rank-2 adapter served out of a rank-4 bank row (zero-padded tail)
    emits exactly the tokens of its solo rank-2 run: padding is zero-delta
    through the gather + einsum."""
    cfg, plan, params, setup, adapters = served
    lcfg2 = LoRAConfig(rank=2)
    small2 = init_lora(setup.small_plan, lcfg2, jax.random.PRNGKey(55))
    small2 = jax.tree.map(
        lambda x: x + 0.05 * jax.random.normal(
            jax.random.PRNGKey(56), x.shape, x.dtype), small2)
    full2 = recovery.recover_lora(small2, setup.spec, plan, setup.small_plan)

    reg = AdapterRegistry(adapters["math"][1], max_adapters=3)
    reg.add("thin", full2)
    eng = ContinuousServeEngine(
        plan, params,
        ServeConfig(max_seq_len=64, max_slots=2, max_adapters=3,
                    max_new_tokens=16, kv_cache_dtype="float32"),
        reg, lora_scale=LORA_CFG.scale)
    prompt = _workload(cfg)[0]
    uid = eng.submit(prompt, max_new_tokens=6, adapter="thin")
    got = eng.run()[uid].tokens

    solo = ServeEngine(
        plan, params,
        ServeConfig(max_seq_len=64, merge_adapters=False,
                    kv_cache_dtype="float32"),
        lora=full2, lora_scale=LORA_CFG.scale)
    np.testing.assert_array_equal(
        got, solo.generate(prompt[None], max_new_tokens=6).tokens[0])


# ---------------------------------------------------------------------------
# 4. live-engine interleavings (register / hot-swap / submit / cancel)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _stream_eng():
    """ONE 2-row engine shared across propcheck examples (same shapes →
    the tick jit-caches once); each example registers fresh names into the
    unbounded host tier.  A module-level cache rather than a fixture: the
    no-hypothesis propcheck shim can't inject pytest fixtures."""
    cfg, plan, params, _, adapters = _served()
    _, full0 = adapters["math"]
    reg = AdapterRegistry(full0, max_adapters=4, bank_slots=2)
    eng = ContinuousServeEngine(
        plan, params,
        ServeConfig(max_seq_len=64, max_slots=3, max_adapters=4,
                    adapter_bank_slots=2, max_new_tokens=16,
                    kv_cache_dtype="float32"),
        reg, lora_scale=LORA_CFG.scale)
    return cfg, eng, reg, full0


def _check_active_rows(eng, reg):
    """No stale-row gathers: every active slot's TickState row is exactly
    the row residency assigned to its (resident) adapter."""
    st_rows = np.asarray(eng._st.adapter_ids)
    for slot in eng._sched.active_slots():
        req = eng._sched.slot_request(slot)
        if req is None:
            continue
        aid = req.adapter_id
        row = int(st_rows[slot])
        if aid == 0:
            assert row == BASE_ROW, (slot, row)
        else:
            assert reg.residency.resident(aid), (slot, aid)
            assert reg.residency._row_of[aid] == row, (slot, aid, row)
            assert reg.residency.refcount(aid) >= 1, (slot, aid)


@hypothesis.settings(max_examples=4, deadline=None)
@hypothesis.given(seed=st.integers(0, 10_000))
def test_engine_interleavings_lose_nothing(seed):
    cfg, eng, reg, full0 = _stream_eng()
    rng = random.Random(seed)
    rs = np.random.default_rng(seed)
    names = ["math", "code", "law"]          # registered by earlier tests?
    for n in list(names):
        if n not in reg.names:
            reg.add(n, jax.tree.map(lambda x: x * 0.9, full0))

    live, results, expect_failed = {}, [], set()
    for step in range(14):
        op = rng.choice(["submit", "submit", "step", "step", "cancel",
                         "register", "hotswap", "ghost"])
        if op == "submit":
            adapter = rng.choice(names + [None])
            p = rs.integers(2, cfg.vocab_size, (rng.randint(4, 10),))
            uid = eng.submit(p.astype(np.int32),
                             max_new_tokens=rng.randint(2, 5),
                             adapter=adapter)
            live[uid] = adapter
        elif op == "ghost":
            # unresolvable at submit: typed terminal through the PR-9
            # choke point, never an exception out of submit()
            p = rs.integers(2, cfg.vocab_size, (5,)).astype(np.int32)
            uid = eng.submit(p, max_new_tokens=3,
                             adapter=f"ghost{seed}_{step}")
            live[uid] = "ghost"
            expect_failed.add(uid)
        elif op == "cancel" and live:
            res = eng.cancel(rng.choice(sorted(live)))
            if res is not None:
                results.append(res)
        elif op == "register":
            name = f"n{seed}_{step}"
            eng.register_adapter(
                name, jax.tree.map(lambda x: x * rng.uniform(0.5, 1.5),
                                   full0))
            names.append(name)
        elif op == "hotswap":
            eng.register_adapter(
                rng.choice(names),
                jax.tree.map(lambda x: x * rng.uniform(0.5, 1.5), full0))
        else:
            results.extend(eng.step())
        _check_active_rows(eng, reg)
    results.extend(eng.run().values())

    # exactly one typed terminal per submitted uid
    got = {}
    for r in results:
        assert r.uid not in got, f"uid {r.uid} finalized twice"
        got[r.uid] = r.status
    assert sorted(got) == sorted(live), (sorted(got), sorted(live))
    for uid, status in got.items():
        if uid in expect_failed:
            assert status == "failed", (uid, status)
        else:
            assert status in ("ok", "cancelled"), (uid, status)
    # refcounts never leak; the drained bank holds no pinned rows
    assert all(reg.residency.refcount(a) == 0
               for a, _ in reg.residency.assignments())
    reg.residency.poll()
    _check_bank_rows(reg)


def test_bank_too_small_for_any_adapter_fails_typed(served):
    """bank_slots=1 is base-row only: adapter traffic can NEVER run —
    submit must fail typed (terminal status), not hang the queue."""
    cfg, plan, params, _, adapters = served
    reg = AdapterRegistry(adapters["math"][1], max_adapters=2, bank_slots=1)
    eng = ContinuousServeEngine(
        plan, params,
        ServeConfig(max_seq_len=32, max_slots=2, max_adapters=2,
                    adapter_bank_slots=1, max_new_tokens=8,
                    kv_cache_dtype="float32"),
        reg, lora_scale=LORA_CFG.scale)
    eng.register_adapter("t", adapters["math"][1])   # host tier: fine
    p = np.ones(4, np.int32)
    uid = eng.submit(p, max_new_tokens=3, adapter="t")
    u_base = eng.submit(p, max_new_tokens=3)
    res = eng.run()
    assert res[uid].status == "failed"
    assert res[u_base].status == "ok"                # base traffic unharmed

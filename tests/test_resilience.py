"""Serving resilience: admission control, deadlines, the degradation
ladder, snapshot/restore, and deterministic fault injection.

  1. controller math — hysteresis debounce, dead band, force_up
  2. fault plans — per-site seeded streams: same seed → same fires,
     bounded by max_fires, at-schedules exact
  3. OFF == identical — an engine with the resilience layer armed but
     idle produces EXACTLY the baseline's tokens (dense / paged /
     speculative), the standing invariant behind every other test here
  4. admission control — bounded queue (reject vs shed-oldest) with
     typed statuses; cancel of queued and in-flight requests
  5. deadlines — expired requests terminate as status="timeout", queued
     or mid-decode, with zero page leaks
  6. livelock — a head request that can never fit in the free pool while
     idle retained pages exist fails TYPED within bounded steps instead
     of stalling admission forever (regression for preempt-newest spin)
  7. faults — seeded tick/alloc/stall injections: every request still
     terminates typed, survivors token-identical, allocator clean
  8. snapshot/restore — a mid-flight snapshot JSON-round-trips into a
     FRESH engine and completes token-identical to the uninterrupted run
  9. property — random submit/cancel/deadline-expiry/restart
     interleavings never leak pages or prefix refcounts, and every
     submitted uid gets exactly one typed result (tests/_propcheck.py)
"""
import dataclasses
import functools
import json
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import hypothesis, st

from repro.configs import ResilienceConfig, ServeConfig, get_smoke
from repro.models import init_params, make_plan
from repro.runtime.watchdog import StragglerAlarm
from repro.serving import (ContinuousServeEngine, DegradationController,
                           Request, Scheduler, engine_restore,
                           engine_snapshot)
from repro.serving.resilience import (DEGRADE_HEALTHY, DEGRADE_MAX, STATUSES,
                                      TERMINAL_EVENT)
from repro.testing.faults import FaultPlan, TransientFault

RNG = jax.random.PRNGKey(0)

# an armed-but-idle policy: every subsystem on, no limit ever reached
IDLE_RESIL = ResilienceConfig(queue_limit=100, deadline_s=100.0,
                              ttft_deadline_s=100.0, degradation=True)


# ---------------------------------------------------------------------------
# controller + fault plan (pure host-side)
# ---------------------------------------------------------------------------

def test_degradation_controller_hysteresis():
    c = DegradationController(high=0.8, low=0.4, up_ticks=2, down_ticks=3)
    assert c.observe(0.9) == 0                 # debounce: 1 of 2
    assert c.observe(0.9) == 1                 # step up
    assert c.observe(0.6) == 1                 # dead band holds...
    assert c.observe(0.9) == 1                 # ...and reset the debounce
    assert c.observe(0.9) == 2
    for _ in range(2):
        assert c.observe(0.1) == 2             # down debounce: 2 of 3
    assert c.observe(0.1) == 1                 # step down
    assert c.peak_level == 2
    # never past the rails
    for _ in range(40):
        c.observe(1.0)
    assert c.level == DEGRADE_MAX
    for _ in range(40):
        c.observe(0.0)
    assert c.level == DEGRADE_HEALTHY


def test_degradation_controller_force_up():
    c = DegradationController()
    assert c.force_up() == 1
    assert c.force_up(3) == 4
    assert c.force_up(9) == DEGRADE_MAX        # clamped
    assert c.peak_level == DEGRADE_MAX


def test_fault_plan_deterministic_and_bounded():
    mk = lambda: FaultPlan(7, tick={"p": 0.5, "max_fires": 3},
                           alloc={"at": [2, 5]})
    a, b = mk(), mk()
    pattern = [a.fire("tick") for _ in range(40)]
    assert pattern == [b.fire("tick") for _ in range(40)]  # same seed, same run
    assert sum(pattern) == 3                               # max_fires bound
    # at-schedules fire on exact consult ordinals (1-based: "the 2nd and
    # 5th allocation attempt")
    allocs = [b.fire("alloc") for _ in range(8)]
    assert [i + 1 for i, f in enumerate(allocs) if f] == [2, 5]
    # an unconfigured site never fires but still counts consults
    assert not any(b.fire("stall") for _ in range(10))
    rep = b.report()
    assert rep["fires"]["alloc"] == 2 and rep["consults"]["stall"] == 10
    # different seed, different tick pattern (overwhelmingly)
    c = FaultPlan(8, tick={"p": 0.5, "max_fires": 3})
    assert pattern != [c.fire("tick") for _ in range(40)]
    # JSON round-trip through the launcher entry point
    d = FaultPlan.from_json(json.dumps(
        {"seed": 7, "tick": {"p": 0.5, "max_fires": 3}}))
    assert [d.fire("tick") for _ in range(40)] == pattern


def test_scheduler_evict_fires_on_event():
    """Regression: EVERY terminal transition (completion included) must
    pass through ``evict`` and fire the hook — the engines hang their
    typed terminal accounting off it."""
    seen = []
    s = Scheduler(max_slots=1,
                  on_event=lambda kind, slot, req: seen.append(
                      (kind, slot, req.uid)))
    r = Request(uid=s.new_uid(), prompt=np.ones(4, np.int32),
                max_new_tokens=1)
    s.submit(r)
    s.next_admission()
    assert ("admit", 0, r.uid) in seen
    s.evict(0)                                 # completion path
    assert ("evict", 0, r.uid) in seen


# ---------------------------------------------------------------------------
# tiny shared model + engine builders
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _model():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    return cfg, plan, params


def _engine(*, resil=None, **kw):
    cfg, plan, params = _model()
    base = dict(max_seq_len=64, max_slots=2, max_new_tokens=16,
                kv_cache_dtype="float32")
    if resil is not None:
        base["resilience"] = resil
    base.update(kw)
    return ContinuousServeEngine(plan, params, ServeConfig(**base))


def _submit_mixed(eng, *, lens=(8, 12, 5, 11), news=(6, 4, 6, 3),
                  temperature=0.0, seed=0):
    cfg, _, _ = _model()
    rs = np.random.default_rng(seed)
    uids = []
    for i, (n, m) in enumerate(zip(lens, news)):
        uids.append(eng.submit(rs.integers(2, cfg.vocab_size,
                                           (n,)).astype(np.int32),
                               max_new_tokens=m, temperature=temperature,
                               seed=100 + i))
    return uids


def _assert_identical(r1, r2):
    assert sorted(r1) == sorted(r2)
    for u in r1:
        assert r1[u].status == r2[u].status, u
        np.testing.assert_array_equal(r1[u].tokens, r2[u].tokens,
                                      err_msg=f"uid {u}")


PAGED_KW = dict(kv_paging=True, kv_page_size=8, kv_pages=17)


# ---------------------------------------------------------------------------
# the standing invariant: resilience off (or idle) changes nothing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [{}, PAGED_KW],
                         ids=["dense", "paged"])
@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_idle_resilience_is_token_identical(kw, temperature):
    base = _engine(**kw)
    _submit_mixed(base, temperature=temperature)
    ref = base.run()
    armed = _engine(resil=IDLE_RESIL, **kw)
    _submit_mixed(armed, temperature=temperature)
    got = armed.run()
    _assert_identical(ref, got)
    assert all(r.status == "ok" for r in got.values())
    assert armed._degrade_level == DEGRADE_HEALTHY


def test_idle_resilience_is_token_identical_speculative(spec_engines):
    plain, armed = spec_engines
    _submit_mixed(plain)
    _submit_mixed(armed)
    _assert_identical(plain.run(), armed.run())


@pytest.fixture()
def spec_engines():
    """A speculative pair (baseline vs armed-idle) over the LoRAM-pruned
    draft — built per test; the draft setup dominates, so only one
    speculative identity case runs."""
    from repro.configs import LoRAConfig, LoRAMConfig
    from repro.core import loram
    from repro.serving import SpeculativeServeEngine, draft_from_setup
    cfg, plan, params = _model()
    setup = loram.setup(plan, params,
                        LoRAMConfig(method="stru", ratio=0.5, keep_first=0,
                                    keep_last=0),
                        LoRAConfig(rank=4), RNG)

    def build(resil):
        base = dict(max_seq_len=64, max_slots=2, max_new_tokens=16,
                    kv_cache_dtype="float32", draft_gamma=2)
        if resil is not None:
            base["resilience"] = resil
        return SpeculativeServeEngine(plan, params, ServeConfig(**base),
                                      None, draft_from_setup(setup))

    return build(None), build(IDLE_RESIL)


# ---------------------------------------------------------------------------
# admission control, cancellation, deadlines
# ---------------------------------------------------------------------------

def test_queue_limit_reject_sheds_newcomers():
    eng = _engine(resil=ResilienceConfig(queue_limit=1))
    uids = _submit_mixed(eng)
    res = eng.run()
    # nothing stepped between submits: uid0 queued, the rest found the
    # queue full and were rejected
    assert [res[u].status for u in uids] == ["ok", "shed", "shed", "shed"]
    assert all(res[u].n_generated == 0 for u in uids[1:])
    assert eng.events.counts()["shed"] == 3
    counts = eng.events.counts()
    assert counts["submit"] == 4 and counts["complete"] == 1


def test_queue_limit_shed_oldest_keeps_newcomers():
    eng = _engine(
        resil=ResilienceConfig(queue_limit=1, queue_policy="shed-oldest"))
    uids = _submit_mixed(eng)
    res = eng.run()
    # each newcomer evicted the then-oldest queued request
    assert [res[u].status for u in uids] == ["shed", "shed", "shed", "ok"]


def test_cancel_queued_and_inflight():
    base = _engine(**PAGED_KW)
    _submit_mixed(base)
    ref = base.run()

    eng = _engine(**PAGED_KW)
    uids = _submit_mixed(eng)
    done = {r.uid: r for r in eng.step()}      # admits 2 of 4
    inflight = next(s for s in eng._sched.occupied_slots())
    victim_in = eng._sched.slot_request(inflight).uid
    victim_q = eng._sched.queued_requests()[0].uid
    r_q = eng.cancel(victim_q)
    assert r_q.status == "cancelled" and r_q.n_generated == 0
    r_in = eng.cancel(victim_in)
    assert r_in.status == "cancelled"
    assert eng.cancel(9999) is None            # unknown uid: no-op
    done.update({r_q.uid: r_q, r_in.uid: r_in})
    done.update(eng.run())
    assert sorted(done) == sorted(uids)
    # the survivors still produce exactly their baseline tokens
    for u in uids:
        if done[u].status == "ok":
            np.testing.assert_array_equal(done[u].tokens, ref[u].tokens)
    assert eng.pages.pages_in_use == 0
    counts = eng.events.counts()
    assert counts["cancel"] == 2
    assert counts["complete"] + counts["cancel"] == 4


def test_deadline_expired_while_queued_and_inflight():
    # (a) an immediate deadline: everything times out before admission
    eng = _engine(resil=ResilienceConfig(deadline_s=1e-6), **PAGED_KW)
    uids = _submit_mixed(eng)
    res = eng.run()
    assert all(res[u].status == "timeout" for u in uids)
    assert all(res[u].n_generated == 0 for u in uids)
    assert eng.pages.pages_in_use == 0
    assert eng.events.counts()["timeout"] == 4

    # (b) a deadline expiring MID-DECODE ships the partial tokens
    eng = _engine(resil=ResilienceConfig(deadline_s=100.0), **PAGED_KW)
    uids = _submit_mixed(eng)
    done = {r.uid: r for r in eng.step()}
    victim = next(s for s in eng._sched.occupied_slots())
    victim = eng._sched.slot_request(victim).uid
    eng._deadline_abs[victim] = 0.0            # force expiry, no wall clock
    done.update(eng.run())
    assert done[victim].status == "timeout"
    assert done[victim].n_generated >= 1       # partial stream shipped
    assert sum(r.status == "ok" for r in done.values()) == len(uids) - 1
    assert eng.pages.pages_in_use == 0


def test_ttft_deadline_times_out_unstarted_requests():
    eng = _engine(resil=ResilienceConfig(ttft_deadline_s=1e-6), **PAGED_KW)
    uids = _submit_mixed(eng)
    res = eng.run()
    assert all(res[u].status == "timeout" for u in uids)
    assert eng.pages.pages_in_use == 0


# ---------------------------------------------------------------------------
# admission livelock breaker
# ---------------------------------------------------------------------------

def _leak_pages(eng, n_each=(8, 2)):
    """Retain pages outside any slot (as an idle prefix cache would) so
    the free pool shrinks while no slot is occupied."""
    for slot, n in enumerate(n_each):
        ids = eng.pages.alloc(slot, n)
        eng.pages.retain(ids)
        eng.pages.release(slot)


def test_admission_livelock_breaker_fails_typed():
    """Regression: with the pool mostly retained and NO occupied slot to
    preempt, a too-big head request used to spin admission forever.  It
    must fail typed within bounded steps, and smaller work behind it must
    still complete."""
    cfg, _, _ = _model()
    eng = _engine(resil=ResilienceConfig(deadline_s=100.0),
                  max_new_tokens=32, **PAGED_KW)
    _leak_pages(eng)                           # 10 of 16 usable pages gone
    rs = np.random.default_rng(0)
    # 30 prompt + 26 new = 56 tokens → 7 pages, but only 6 remain free and
    # there is never an occupied slot to preempt for it
    big = eng.submit(rs.integers(2, cfg.vocab_size, (30,)).astype(np.int32),
                     max_new_tokens=26)
    small = eng.submit(rs.integers(2, cfg.vocab_size, (8,)).astype(np.int32),
                       max_new_tokens=4)
    res = {}
    for _ in range(8):                         # bounded: no spinning
        for r in eng.step():
            res[r.uid] = r
        if not eng.pending:
            break
    assert sorted(res) == sorted([big, small])
    assert res[small].status == "ok"
    # the big request either ran (pool barely fit it) or failed typed —
    # with 10 pages retained it cannot: 6 free < 8 pages for 30+16 tokens
    assert res[big].status == "failed"
    assert eng.events.counts()["failed"] == 1
    assert eng.pages.pages_in_use == 10        # only the leak remains


# ---------------------------------------------------------------------------
# fault injection end to end
# ---------------------------------------------------------------------------

def test_tick_faults_absorbed_and_token_identical():
    base = _engine(**PAGED_KW)
    _submit_mixed(base, temperature=0.7)
    ref = base.run()

    eng = _engine(resil=ResilienceConfig(deadline_s=100.0, tick_retries=1),
                  **PAGED_KW)
    eng.install_faults(FaultPlan(3, tick={"p": 1.0, "max_fires": 4}))
    _submit_mixed(eng, temperature=0.7)
    res = eng.run()
    _assert_identical(ref, res)                # retries + restarts: no drift
    assert eng._faults.report()["fires"]["tick"] == 4
    # retries=1 against p=1.0 exhausts at least once → snapshot-restart
    assert eng.events.counts().get("restore", 0) >= 1
    assert eng.pages.pages_in_use == 0


def test_alloc_faults_preempt_and_complete_identical():
    base = _engine(**PAGED_KW)
    _submit_mixed(base)
    ref = base.run()

    eng = _engine(resil=ResilienceConfig(deadline_s=100.0), **PAGED_KW)
    eng.install_faults(FaultPlan(5, alloc={"at": [1, 3]}))
    _submit_mixed(eng)
    res = eng.run()
    _assert_identical(ref, res)
    assert eng.n_preemptions >= 2              # injected PoolExhausted
    assert eng.pages.pages_in_use == 0


def test_stall_streak_escalates_degrade_then_restart():
    eng = _engine(resil=ResilienceConfig(degradation=True,
                                         stall_degrade_after=2,
                                         stall_restart_after=3))
    alarm = StragglerAlarm(step=0, elapsed=1.0, ewma=0.01)
    eng._on_stall(alarm)
    assert eng._degrade_level == DEGRADE_HEALTHY
    eng._on_stall(alarm)                       # 2nd stall: force-degrade
    assert eng._degrade_level == 1
    assert not eng._want_restart
    eng._on_stall(alarm)                       # 3rd: schedule restart
    assert eng._want_restart
    assert eng.events.counts()["stall"] == 3
    # the scheduled restart is a no-op on an idle engine but must clear
    eng.step()
    assert not eng._want_restart
    assert eng.events.counts().get("restore", 0) == 1


# ---------------------------------------------------------------------------
# snapshot / restore
# ---------------------------------------------------------------------------

def test_snapshot_restore_into_fresh_engine_token_identical():
    base = _engine(**PAGED_KW)
    _submit_mixed(base, temperature=0.7)
    ref = base.run()

    eng = _engine(resil=IDLE_RESIL, **PAGED_KW)
    uids = _submit_mixed(eng, temperature=0.7)
    done = {}
    for _ in range(2):
        done.update({r.uid: r for r in eng.step()})
    snap = json.loads(json.dumps(engine_snapshot(eng)))  # wire format
    assert snap["version"] == 1
    assert len(snap["requests"]) + len(done) == len(uids)

    fresh = _engine(resil=IDLE_RESIL, **PAGED_KW)
    n = engine_restore(fresh, snap)
    assert n == len(snap["requests"])
    done.update(fresh.run())
    _assert_identical(ref, done)
    assert fresh.pages.pages_in_use == 0
    # restored requests keep their original submit stamps → sane TTFT
    for u in uids:
        assert done[u].ttft_s >= 0.0
    assert fresh.events.counts()["restore"] == 1


def test_restore_refuses_mismatched_geometry():
    eng = _engine(**PAGED_KW)
    _submit_mixed(eng)
    snap = engine_snapshot(eng)
    other = _engine(max_slots=3, **PAGED_KW)
    with pytest.raises(AssertionError):
        engine_restore(other, snap)
    eng.run()


# ---------------------------------------------------------------------------
# property: interleavings never leak, every uid terminates typed
# ---------------------------------------------------------------------------

_PROP_ENGINE = []


def _prop_engine():
    """One shared engine across examples (fresh construction re-jits the
    tick; the harness drains it to idle between examples)."""
    if not _PROP_ENGINE:
        _PROP_ENGINE.append(_engine(
            resil=ResilienceConfig(queue_limit=6, deadline_s=100.0,
                                   degradation=True),
            prefix_sharing=True, **PAGED_KW))
    eng = _PROP_ENGINE[0]
    assert not eng.pending
    return eng


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(seed=st.integers(min_value=0, max_value=10_000))
def test_random_interleavings_never_leak(seed):
    cfg, _, _ = _model()
    rng = random.Random(seed)
    rs = np.random.default_rng(seed)
    eng = _prop_engine()
    prefix = rs.integers(2, cfg.vocab_size, (8,)).astype(np.int32)
    live, results = [], {}

    def note(rlist):
        for r in rlist:
            assert r.uid not in results, f"uid {r.uid} finished twice"
            results[r.uid] = r

    for _ in range(rng.randint(6, 14)):
        op = rng.choice(("submit", "submit", "step", "cancel", "deadline",
                         "restart"))
        if op == "submit":
            if rng.random() < 0.4:
                prompt = np.concatenate(
                    [prefix, rs.integers(2, cfg.vocab_size, (
                        rng.randint(2, 6),)).astype(np.int32)])
                # per-example id: prefix TOKENS differ per seed, and ids
                # must register byte-identical tokens for their lifetime
                kw = dict(prefix_id=f"sys{seed}", prefix_len=len(prefix))
            else:
                prompt = rs.integers(2, cfg.vocab_size, (
                    rng.randint(3, 20),)).astype(np.int32)
                kw = {}
            live.append(eng.submit(prompt,
                                   max_new_tokens=rng.randint(1, 8),
                                   temperature=rng.choice((0.0, 0.8)),
                                   seed=rng.randint(0, 999), **kw))
        elif op == "step":
            note(eng.step())
        elif op == "cancel" and live:
            r = eng.cancel(rng.choice(live))
            if r is not None:
                note([r])
        elif op == "deadline" and eng._deadline_abs:
            u = rng.choice(sorted(eng._deadline_abs))
            eng._deadline_abs[u] = 0.0         # expire it at the next step
        elif op == "restart":
            eng._want_restart = True
    note(list(eng.run().values()))

    assert sorted(results) == sorted(live), "requests lost or invented"
    assert all(r.status in STATUSES for r in results.values())
    # idle prefix entries legitimately retain pages; past them, zero leaks
    while eng._drop_one_idle_prefix():
        pass
    assert not eng._prefix and eng.pages.pages_in_use == 0
    assert not eng.pending


def test_terminal_events_partition_submits():
    """Counter/event-log consistency under a mixed outcome run: one
    terminal event per submitted uid, statuses partition exactly."""
    eng = _engine(resil=ResilienceConfig(queue_limit=2, deadline_s=100.0),
                  **PAGED_KW)
    uids = _submit_mixed(eng, lens=(8, 12, 5, 11, 7), news=(6, 4, 6, 3, 5))
    done = {r.uid: r for r in eng.step()}
    for u in uids:
        if u not in done and eng._deadline_abs.get(u):
            eng._deadline_abs[u] = 0.0         # every survivor times out
            break
    done.update(eng.run())
    counts = eng.events.counts()
    n_term = sum(counts.get(TERMINAL_EVENT[s], 0) for s in STATUSES)
    assert n_term == counts["submit"] == len(uids)
    tally = {}
    for r in done.values():
        tally[r.status] = tally.get(r.status, 0) + 1
    assert sum(tally.values()) == len(uids)
    for s, n in tally.items():
        assert counts.get(TERMINAL_EVENT[s], 0) == n, (s, counts)

"""End-to-end behaviour tests for the LoRAM system (paper Algorithm 1):

  offline:  prune → align → quantize
  online:   LoRA-train the pruned base (loss ↓)
  inference: recover → merge into FULL model → generate

plus fault-tolerance: kill mid-run, resume from checkpoint, same trajectory.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (LoRAConfig, LoRAMConfig, ServeConfig, TrainConfig,
                           get_smoke)
from repro.core import loram, pruning, recovery
from repro.core.objectives import cross_entropy
from repro.data import AlignmentCorpus, SFTDataset, batch_iterator
from repro.models import forward, init_params, make_plan
from repro.runtime.trainer import Trainer
from repro.serving import ServeEngine

RNG = jax.random.PRNGKey(0)

# end-to-end pipeline runs dominate suite wall-time (120s+ worst case)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def base():
    cfg = dataclasses.replace(get_smoke("yi-34b"), n_layers=2, d_ff=256)
    plan = make_plan(cfg)
    params = init_params(plan, RNG, jnp.float32)
    return cfg, plan, params


def test_full_loram_pipeline(base, tmp_path):
    cfg, plan, params = base
    lora_cfg = LoRAConfig(rank=4)
    loram_cfg = LoRAMConfig(method="stru", ratio=0.5, keep_first=0,
                            keep_last=0, quantize=False, align=True)

    corpus = AlignmentCorpus(cfg.vocab_size, 24)
    setup = loram.setup(
        plan, params, loram_cfg, lora_cfg, RNG,
        align_batches=batch_iterator(corpus, batch_size=4),
        align_steps=3, align_lr=1e-4)

    tc = TrainConfig(global_batch=8, seq_len=24, learning_rate=5e-3,
                     total_steps=15, warmup_steps=2, remat=False)
    ds = SFTDataset(cfg.vocab_size, tc.seq_len)
    trainer = Trainer(setup.small_plan, setup.small_params, setup.lora0, tc,
                      lora_cfg, n_micro=2, checkpoint_dir=str(tmp_path))
    state = trainer.train(batch_iterator(ds, batch_size=tc.global_batch),
                          steps=15, log_every=0)
    losses = [m["loss"] for m in trainer.metrics_log]
    assert losses[-1] < losses[0]

    # inference on the FULL model with recovered adapters
    lora_full, merged = loram.finalize(setup, state.lora, params)
    assert recovery.delta_support_check(setup.spec, plan, lora_full)
    eng = ServeEngine(plan, merged, ServeConfig(max_seq_len=48))
    res = eng.generate(np.ones((2, 8), np.int32), max_new_tokens=4)
    assert res.tokens.shape == (2, 4)

    # fine-tuning actually moved full-model behaviour
    tokens = jax.random.randint(RNG, (2, 12), 0, cfg.vocab_size)
    lg_base, _ = forward(plan, params, tokens)
    lg_merged, _ = forward(plan, merged, tokens)
    assert float(jnp.abs(lg_base - lg_merged).max()) > 1e-4


def test_crash_resume_same_trajectory(base, tmp_path):
    """Checkpoint/restart determinism: run 10 steps straight vs 5+restart+5."""
    cfg, plan, params = base
    lora_cfg = LoRAConfig(rank=4)
    setup = loram.setup(plan, params,
                        LoRAMConfig(method="rand", ratio=0.5, keep_first=0,
                                    keep_last=0),
                        lora_cfg, RNG)
    tc = TrainConfig(global_batch=4, seq_len=16, learning_rate=1e-3,
                     total_steps=10, warmup_steps=1, remat=False)
    ds = SFTDataset(cfg.vocab_size, tc.seq_len)

    def fresh_trainer(ckpt):
        return Trainer(setup.small_plan, setup.small_params, setup.lora0, tc,
                       lora_cfg, n_micro=1, checkpoint_dir=ckpt,
                       checkpoint_every=5)

    # straight run
    t1 = fresh_trainer(str(tmp_path / "a"))
    s1 = t1.train(batch_iterator(ds, batch_size=4), steps=10, log_every=0)

    # interrupted run
    t2 = fresh_trainer(str(tmp_path / "b"))
    t2.train(batch_iterator(ds, batch_size=4), steps=5, log_every=0)
    t3 = fresh_trainer(str(tmp_path / "b"))   # "new process"
    s_resumed = t3.restore_or_init()
    assert s_resumed.step == 5
    s2 = t3.train(batch_iterator(ds, batch_size=4, start_step=5),
                  steps=10, state=s_resumed, log_every=0)

    for a, b in zip(jax.tree.leaves(s1.lora), jax.tree.leaves(s2.lora)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_recovery_helps_full_model(base):
    """Fig. 6 direction: training on the pruned model, recovering and merging
    improves the FULL model over its untrained baseline."""
    cfg, plan, params = base
    lora_cfg = LoRAConfig(rank=4)
    setup = loram.setup(plan, params,
                        LoRAMConfig(method="stru", ratio=0.5, keep_first=0,
                                    keep_last=0),
                        lora_cfg, RNG)
    ds = SFTDataset(cfg.vocab_size, 24, seed=5)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0, batch_size=8).items()}

    from repro.core.objectives import sft_loss
    from repro.optim import adamw_init, adamw_update

    lora = setup.lora0
    opt = adamw_init(lora)
    for i in range(25):
        loss, g = jax.value_and_grad(
            lambda l: sft_loss(setup.small_plan, setup.small_params, l,
                               batch, lora_scale=lora_cfg.scale)[0])(lora)
        lora, opt = adamw_update(lora, g, opt, lr=5e-3)

    lora_full, merged = loram.finalize(setup, lora, params)
    lg_rec, _ = forward(plan, merged, batch["tokens"])
    loss_rec = cross_entropy(lg_rec, batch["labels"], batch["loss_mask"])
    lg_base, _ = forward(plan, params, batch["tokens"])
    loss_base = cross_entropy(lg_base, batch["labels"], batch["loss_mask"])
    assert float(loss_rec) < float(loss_base)

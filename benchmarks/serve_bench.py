"""Continuous-batching vs synchronous vs speculative serving under
mixed-length, mixed-adapter traffic.

The synchronous :class:`ServeEngine` can only run ONE adapter and ONE prompt
length per batch, and must decode every batch to its LONGEST request — so a
realistic workload (two adapters, three prompt lengths, varying
max_new_tokens) shatters into sequential per-(adapter, length) groups with
head-of-line blocking inside each.  The continuous engine keeps all slots
busy across adapters, lengths and completion times.  ``--speculative`` adds
the draft-then-verify engine: the LoRAM-pruned model proposes γ tokens per
slot and the full model verifies them in one batched forward.

The base weights use a *compressible* construction — the channels that
magnitude pruning removes are exactly zero — so the pruned draft is
computationally equivalent to the target and the measured acceptance rate
reflects a well-aligned draft (a trained LoRAM checkpoint behaves the same
way by design: pruning removes what mattered least).

The PAGED engine runs the same traffic against a page-pool KV cache sized
well below the dense engine's ``max_slots × max_seq_len`` reservation
(``--kv-pages``; the default targets > 2× fewer cache bytes) — mixed-length
requests only ever back the tokens they actually hold, so the pool covers
the same concurrency with less HBM.  The bench reports both engines'
reserved KV bytes and the paged allocator's true high-water page count.

Results are printed AND written to ``BENCH_serving.json`` (see ``--json``)
so the serving-perf trajectory is tracked across PRs.  ``--smoke`` is the
CI guard: a seconds-scale run of the dense + paged engines that
schema-checks the emitted JSON.

  PYTHONPATH=src python benchmarks/serve_bench.py [--requests 24] [--slots 8]
  PYTHONPATH=src python benchmarks/serve_bench.py --speculative [--gamma 6]
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LoRAConfig, LoRAMConfig, ServeConfig, get_smoke
from repro.core import loram, recovery
from repro.core.pruning import zero_prunable_tail
from repro.models import init_params, make_plan
from repro.models.model import init_lora
from repro.serving import (AdapterRegistry, ContinuousServeEngine,
                           ServeEngine, SpeculativeServeEngine,
                           draft_from_setup, pages_for)

PROMPT_LENS = (8, 16, 24)
NEW_TOKENS = (24, 40, 56)   # decode-bound, like real serving
MAX_SEQ_LEN = 128           # shared by every engine AND the pool auto-sizer


def make_workload(n_requests, vocab, seed=0):
    """i.i.d. mixed traffic: real requests don't arrive pre-grouped by
    length, adapter, or generation budget."""
    rs = np.random.default_rng(seed)
    work = []
    for _ in range(n_requests):
        n_prompt = int(rs.choice(PROMPT_LENS))
        n_new = int(rs.choice(NEW_TOKENS))
        adapter = str(rs.choice(["math", "code"]))
        prompt = rs.integers(2, vocab, (n_prompt,)).astype(np.int32)
        work.append((prompt, adapter, n_new))
    return work


def run_synchronous(plan, params, adapters, work, lora_scale):
    """Best-effort batching for the old engine: group by (adapter, prompt
    length), decode each group to its longest request."""
    engines = {
        name: ServeEngine(
            plan, params,
            ServeConfig(max_seq_len=MAX_SEQ_LEN, merge_adapters=False,
                        kv_cache_dtype="float32"),
            lora=lora, lora_scale=lora_scale)
        for name, lora in adapters.items()
    }
    groups = defaultdict(list)
    for prompt, adapter, n_new in work:
        groups[(adapter, len(prompt))].append((prompt, n_new))

    def one_pass():
        n_tokens = 0
        for (adapter, _), items in sorted(groups.items()):
            prompts = np.stack([p for p, _ in items])
            n_max = max(n for _, n in items)
            engines[adapter].generate(prompts, max_new_tokens=n_max)
            # only the tokens each request asked for count as useful output
            n_tokens += sum(n for _, n in items)
        return n_tokens

    return _time_passes(one_pass)


def _time_passes(one_pass, n_timed=3):
    """Warm-up once (compiles), then best-of-n timed passes (host timing at
    this scale is noisy; best-of is the standard noise filter)."""
    one_pass()
    best = float("inf")
    for _ in range(n_timed):
        t0 = time.perf_counter()
        n_tokens = one_pass()
        best = min(best, time.perf_counter() - t0)
    return n_tokens, best


def _submit_and_drain(eng, work):
    for prompt, adapter, n_new in work:
        eng.submit(prompt, max_new_tokens=n_new, adapter=adapter)
    done = eng.run()
    return sum(r.n_generated for r in done.values())


def run_continuous(plan, params, registry, work, slots, lora_scale,
                   n_timed=3, **cfg_kw):
    """One timed continuous-engine pass; ``cfg_kw`` selects the cache layout
    (empty → dense, kv_paging=True + pool knobs → paged) so the dense/paged
    comparison can never diverge in the shared ServeConfig."""
    eng = ContinuousServeEngine(
        plan, params,
        ServeConfig(max_seq_len=MAX_SEQ_LEN, max_slots=slots,
                    max_adapters=registry.max_adapters, max_new_tokens=64,
                    kv_cache_dtype="float32", **cfg_kw),
        registry, lora_scale=lora_scale)
    tok, s = _time_passes(lambda: _submit_and_drain(eng, work), n_timed)
    return tok, s, eng


REQUIRED_ENGINE_KEYS = {"tokens", "seconds", "tok_s"}


def validate_results(results):
    """Schema guard for BENCH_serving.json — CI runs ``--smoke`` and fails
    the build if the trajectory file's shape silently drifts."""
    assert results.get("bench") == "serving", results.get("bench")
    assert isinstance(results.get("config"), dict)
    engines = results.get("engines")
    assert isinstance(engines, dict) and engines, "no engines recorded"
    for name, stats in engines.items():
        missing = REQUIRED_ENGINE_KEYS - set(stats)
        assert not missing, f"engine {name} missing {sorted(missing)}"
    if "paged" in engines:
        mem = results.get("memory")
        assert mem is not None, "paged run must report memory"
        for key in ("dense_kv_bytes", "paged_kv_bytes", "reduction",
                    "peak_pages_used", "pool_pages"):
            assert key in mem, f"memory missing {key}"
        # the >= 2x memory claim is enforced on the auto-sized CI guard run
        # only — a user sweeping --page-size / --kv-pages may legitimately
        # configure a smaller reduction and should still get their numbers
        if (results["config"].get("smoke")
                and results["config"].get("kv_pages_auto", True)):
            assert mem["reduction"] >= 2.0, (
                f"paged KV reservation must be >= 2x smaller than dense "
                f"(got {mem['reduction']:.2f}x)")
    assert isinstance(results.get("speedups"), dict)


def run_speculative(plan, params, registry, draft, work, slots, gamma,
                    lora_scale):
    eng = SpeculativeServeEngine(
        plan, params,
        ServeConfig(max_seq_len=MAX_SEQ_LEN, max_slots=slots,
                    max_adapters=registry.max_adapters, max_new_tokens=64,
                    kv_cache_dtype="float32", draft_gamma=gamma),
        registry, draft, lora_scale=lora_scale)
    tok, s = _time_passes(lambda: _submit_and_drain(eng, work))
    return tok, s, eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--requests", type=int, default=36)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--speculative", action="store_true",
                    help="also benchmark the pruned-draft speculative engine")
    ap.add_argument("--gamma", type=int, default=6,
                    help="draft tokens per speculative round")
    ap.add_argument("--ratio", type=float, default=0.75,
                    help="LoRAM structured pruning ratio for the draft")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged engine: tokens per KV page")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="paged engine: page-pool capacity (0 → auto-size "
                         "to ~2.5x below the dense reservation)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI guard: tiny model, dense + paged "
                         "engines only, schema-check the emitted JSON")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable results path ('' to skip)")
    args = ap.parse_args()
    if get_smoke(args.arch).family != "dense":
        ap.error(f"--arch {args.arch}: the lossless-prune draft construction "
                 "covers dense families only (mlp + attn blocks)")
    if args.smoke and args.speculative:
        ap.error("--smoke is the seconds-scale dense+paged CI guard; drop "
                 "--speculative (the full bench covers it)")
    if args.smoke:
        args.requests = min(args.requests, 8)
        args.slots = min(args.slots, 4)
        if args.json == "BENCH_serving.json":
            # never let a local smoke run clobber the committed cross-PR
            # trajectory file with tiny-model numbers
            args.json = "BENCH_smoke.json"

    # compute-visible dims: big enough that weight streaming (which verify
    # amortizes over γ tokens) dominates per-dispatch overhead on CPU.
    # The lossless-prune construction below covers dense blocks only, so the
    # speculative bench (and its ~100%-acceptance claim) is dense-family.
    dims = (dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                 head_dim=16, d_ff=128, vocab_size=512) if args.smoke else
            dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                 head_dim=32, d_ff=1024, vocab_size=2048))
    cfg = dataclasses.replace(get_smoke(args.arch), **dims)
    plan = make_plan(cfg)
    params = init_params(plan, jax.random.PRNGKey(0), jnp.float32)
    lora_cfg = LoRAConfig(rank=4)

    # LoRAM offline stage: magnitude-structured pruning of a compressible
    # base → the draft model.  Adapters are trained at pruned widths (stood
    # in by perturbed inits) and recovered to full rank for the target.
    loram_cfg = LoRAMConfig(method="stru", ratio=args.ratio,
                            keep_first=0, keep_last=0)
    params = zero_prunable_tail(params, plan, args.ratio)
    setup = loram.setup(plan, params, loram_cfg, lora_cfg,
                        jax.random.PRNGKey(1))
    draft = draft_from_setup(setup, max_adapters=4)

    def mk_adapter(seed):
        small = init_lora(setup.small_plan, lora_cfg, jax.random.PRNGKey(seed))
        small = jax.tree.map(
            lambda x: x + 0.02 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), x.shape, x.dtype), small)
        full = recovery.recover_lora(small, setup.spec, plan, setup.small_plan)
        return small, full

    registry = None
    adapters = {}
    for name, seed in [("math", 11), ("code", 22)]:
        small, full = mk_adapter(seed)
        adapters[name] = full
        if registry is None:
            registry = AdapterRegistry(full, max_adapters=4)
        registry.add(name, full)
        draft.add(name, small)

    work = make_workload(args.requests, cfg.vocab_size)
    print(f"[serve_bench] {args.requests} requests, prompt lens "
          f"{sorted({len(p) for p, _, _ in work})}, new-token mix "
          f"{sorted({n for _, _, n in work})}, 2 adapters")

    n_timed = 1 if args.smoke else 3
    cont_tok, cont_s, cont_eng = run_continuous(
        plan, params, registry, work, args.slots, lora_cfg.scale, n_timed)
    cont_tps = cont_tok / cont_s

    # paged pool auto-sizing: n_tbl pages back one max-length sequence; aim
    # ~2.2x below the dense max_slots × max_seq_len reservation — above the
    # workload's mean concurrent footprint (preemptions stay rare) but well
    # under worst-case (floor: one max-length request + trash, or the engine
    # refuses the pool)
    n_tbl = pages_for(MAX_SEQ_LEN, args.page_size)
    kv_pages = args.kv_pages or max(n_tbl + 1,
                                    int(args.slots * n_tbl / 2.2) + 1)
    paged_tok, paged_s, paged_eng = run_continuous(
        plan, params, registry, work, args.slots, lora_cfg.scale, n_timed,
        kv_paging=True, kv_page_size=args.page_size, kv_pages=kv_pages)
    paged_tps = paged_tok / paged_s
    dense_kv = cont_eng.kv_cache_bytes()
    paged_kv = paged_eng.kv_cache_bytes()

    print(f"[serve_bench] continuous  : {cont_tok:4d} tok in {cont_s:6.2f}s "
          f"→ {cont_tps:7.1f} tok/s  ({args.slots} slots)")
    print(f"[serve_bench] paged       : {paged_tok:4d} tok in "
          f"{paged_s:6.2f}s → {paged_tps:7.1f} tok/s  "
          f"({kv_pages} pages × {args.page_size} tok, "
          f"{paged_eng.n_preemptions} preemptions)")
    print(f"[serve_bench] KV cache HBM: dense {dense_kv / 1e6:.2f} MB → "
          f"paged {paged_kv / 1e6:.2f} MB "
          f"({dense_kv / paged_kv:.2f}x smaller; peak "
          f"{paged_eng.pages.peak_in_use}/{kv_pages - 1} pages used)")

    results = {
        "bench": "serving",
        "config": {
            "arch": cfg.name, "n_layers": cfg.n_layers,
            "d_model": cfg.d_model, "d_ff": cfg.d_ff,
            "vocab_size": cfg.vocab_size, "requests": args.requests,
            "slots": args.slots, "adapters": 2, "smoke": args.smoke,
            "prompt_lens": list(PROMPT_LENS), "new_tokens": list(NEW_TOKENS),
            "page_size": args.page_size, "kv_pages": kv_pages,
            "kv_pages_auto": args.kv_pages == 0,
        },
        "engines": {
            "continuous": {"tokens": cont_tok, "seconds": round(cont_s, 4),
                           "tok_s": round(cont_tps, 1)},
            "paged": {"tokens": paged_tok, "seconds": round(paged_s, 4),
                      "tok_s": round(paged_tps, 1),
                      "preemptions": paged_eng.n_preemptions},
        },
        "memory": {
            "dense_kv_bytes": dense_kv,
            "paged_kv_bytes": paged_kv,
            "reduction": round(dense_kv / paged_kv, 3),
            "peak_pages_used": paged_eng.pages.peak_in_use,
            "pool_pages": kv_pages,
        },
        "speedups": {"paged_vs_continuous": round(paged_tps / cont_tps, 3)},
    }

    if not args.smoke:
        sync_tok, sync_s = run_synchronous(plan, params, adapters, work,
                                           lora_cfg.scale)
        sync_tps = sync_tok / sync_s
        print(f"[serve_bench] synchronous : {sync_tok:4d} tok in "
              f"{sync_s:6.2f}s → {sync_tps:7.1f} tok/s")
        print(f"[serve_bench] speedup: {cont_tps / sync_tps:.2f}x aggregate "
              f"tokens/s (continuous vs synchronous)")
        results["engines"]["synchronous"] = {
            "tokens": sync_tok, "seconds": round(sync_s, 4),
            "tok_s": round(sync_tps, 1)}
        results["speedups"]["continuous_vs_sync"] = round(
            cont_tps / sync_tps, 3)

    if args.speculative and not args.smoke:
        spec_tok, spec_s, eng = run_speculative(
            plan, params, registry, draft, work, args.slots, args.gamma,
            lora_cfg.scale)
        spec_tps = spec_tok / spec_s
        acc = eng.acceptance_rate
        print(f"[serve_bench] speculative : {spec_tok:4d} tok in "
              f"{spec_s:6.2f}s → {spec_tps:7.1f} tok/s  "
              f"(γ={args.gamma}, acceptance {acc:.1%}, "
              f"{eng.n_rounds} rounds)")
        print(f"[serve_bench] speculative speedup: "
              f"{spec_tps / cont_tps:.2f}x vs continuous")
        results["config"].update(gamma=args.gamma, prune_ratio=args.ratio,
                                 draft_stage="trained")
        results["engines"]["speculative"] = {
            "tokens": spec_tok, "seconds": round(spec_s, 4),
            "tok_s": round(spec_tps, 1), "acceptance_rate": round(acc, 4),
            "gamma": args.gamma, "rounds": eng.n_rounds,
        }
        results["speedups"]["speculative_vs_continuous"] = round(
            spec_tps / cont_tps, 3)

    validate_results(results)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
            f.write("\n")
        # re-read and re-validate what actually landed on disk — this is the
        # file CI guards
        with open(args.json) as f:
            validate_results(json.load(f))
        print(f"[serve_bench] wrote {args.json} (schema OK)")


if __name__ == "__main__":
    main()
